"""Prefill-deflection policies: should a prompt prefill on the decode pool?

In a disaggregated fleet the prefill pool is the TTFT bottleneck under
bursty prompt-heavy load while decode servers idle between steps. Load-aware
prefill deflection (Microsoft, PAPERS.md) diverts *short* prompts to
underutilized decode servers when the prefill pool is under pressure: a
short prompt barely perturbs a decode server's step time, and a deflected
request skips the cross-server KV handoff entirely (its KV is already where
decode happens).

Policies consume the fleet view `repro.serving.disagg.DisaggSession`:

    fleet.prefill_pool / fleet.decode_pool   worker views, each exposing
        queue_len                queued-or-prefilling requests on the worker
        pending_prefill_tokens   prompt tokens not yet prefilled there
        mu                       the server's prefill-throughput estimate
        free_slots               free decode slots (decode workers)
    fleet.decode_has_capacity()  any decode worker has a free slot and a
                                 below-watermark deflected backlog

`decide(fleet, request, prompt) -> bool` is a deterministic pure function of
that view, so disagg runs replay bit-for-bit on a `ManualClock` — the same
property the router policies protect.

Registered under the fourth registry side (`@register_deflection`);
`make_deflection("prefill-pressure")` builds them anywhere a name works.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence

from repro.core.request import Request
from repro.policies.registry import register_deflection


def _pool_prefill_backlog(fleet: Any) -> int:
    """Pool-total token backlog: the pressure signal. The *sum* (not the
    per-worker minimum) is what predicts TTFT risk — with join-shortest
    placement one idle worker keeps the minimum pinned at zero right through
    a burst, while the pool total rises with every queued prompt."""
    return sum(w.pending_prefill_tokens for w in fleet.prefill_pool)


@register_deflection("never")
@dataclass
class NeverDeflect:
    """All prefills stay on the prefill pool — the pure-disaggregation
    baseline every aware policy must beat (and the 1P:1D parity anchor)."""

    name: str = "never"

    def decide(self, fleet: Any, request: Request,
               prompt: Sequence[int]) -> bool:
        return False


@register_deflection("short-prompt-threshold")
@dataclass
class ShortPromptDeflect:
    """Deflect every prompt at or under ``short_tokens`` whenever the decode
    pool has capacity, regardless of prefill-pool load. Load-blind: the
    baseline that shows *unconditional* deflection steals decode step time
    even when the prefill pool was idle anyway."""

    name: str = "short-prompt-threshold"
    short_tokens: int = 8

    def decide(self, fleet: Any, request: Request,
               prompt: Sequence[int]) -> bool:
        return request.input_len <= self.short_tokens and fleet.decode_has_capacity()


@register_deflection("prefill-pressure")
@dataclass
class PrefillPressureDeflect:
    """The paper's load-aware rule: deflect short prompts only while the
    prefill pool is *pressured* — the pool-total pending-token backlog is at
    or above ``watermark_tokens`` — and some decode worker has capacity.
    The default watermark is calibrated to the miniature engine twin
    (prompts of 2-24 tokens, 100x-compressed arrivals), where any standing
    backlog at all marks a burst the pool is not absorbing."""

    name: str = "prefill-pressure"
    short_tokens: int = 8
    watermark_tokens: int = 2

    def decide(self, fleet: Any, request: Request,
               prompt: Sequence[int]) -> bool:
        if request.input_len > self.short_tokens:
            return False
        if _pool_prefill_backlog(fleet) < self.watermark_tokens:
            return False
        return fleet.decode_has_capacity()


@register_deflection("slack-aware")
@dataclass
class SlackAwareDeflect:
    """Deflect when the prefill pool cannot clear this prompt inside its
    TTFT budget but the decode pool can: compare the best prefill worker's
    predicted completion (backlog + input_len) / mu against ``margin`` x the
    request's TTFT SLO, and require the best decode worker to beat it."""

    name: str = "slack-aware"
    margin: float = 0.8

    def decide(self, fleet: Any, request: Request,
               prompt: Sequence[int]) -> bool:
        def eta(w: Any) -> float:
            return (w.pending_prefill_tokens + request.input_len) / max(w.mu, 1e-9)

        eta_p = min(eta(w) for w in fleet.prefill_pool)
        if eta_p <= request.slo.ttft * self.margin:
            return False  # prefill pool still makes the deadline
        if not fleet.decode_has_capacity():
            return False
        eta_d = min(eta(w) for w in fleet.decode_pool)
        return eta_d < eta_p
