"""Autoscaler policies: how many replicas should the fleet be running?

The fifth registry side. An `AutoscalerPolicy` consumes `repro.obs.slo.
windowed_slo` output — per-window attainment fractions, queue-depth and
in-flight-transfer gauges, decode-time-vs-TPOT-budget series — and returns
the desired live-replica count. Deliberately *telemetry-driven*: the
controller (`repro.serving.fleetctl.AutoscaleController`) hands policies the
same windowed series an operator's dashboard would show, never session
internals, so a policy that works here works against any backend that emits
the unified event stream (DESIGN.md §obs).

Decisions are clamped to ``[n_min, n_max]`` by the controller and applied at
most one replica per control interval (scale thrash is worse than a slow
ramp); policies therefore return a *target*, not a delta. All three built-ins
are deterministic functions of the telemetry (the PID variant keeps an
integral accumulator — stateful like the decode schedulers' ``observe``, but
still replayable bit-for-bit on a `ManualClock`).

Registered under `@register_autoscaler`; `make_autoscaler("queue-threshold")`
builds them anywhere a name works.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Mapping, Optional

from repro.policies.registry import register_autoscaler


def _windows(slo: Mapping[str, Any]) -> List[Mapping[str, Any]]:
    return list(slo.get("windows") or [])


@register_autoscaler("static")
@dataclass
class StaticAutoscaler:
    """Never scales: the fixed-fleet baseline every reactive policy must
    beat on SLO attainment under churn. ``n`` pins an explicit size;
    the default (None) holds whatever the fleet currently runs."""

    name: str = "static"
    n: Optional[int] = None

    def decide(self, slo: Mapping[str, Any], n_replicas: int,
               n_min: int, n_max: int) -> int:
        return n_replicas if self.n is None else self.n


@register_autoscaler("queue-threshold")
@dataclass
class QueueThresholdAutoscaler:
    """Classic watermark rule on the admission-queue gauge: grow while the
    latest window's peak queue depth sits at or above ``high``, shrink only
    once the queue has fully drained (peak at or below ``low`` *and* empty at
    the window edge) for ``cool_windows`` consecutive windows. The queue
    gauge leads attainment by a full window — a flash crowd shows up as
    standing queue before a single SLO miss is scored — which is exactly why
    this beats waiting for attainment to dip."""

    name: str = "queue-threshold"
    high: int = 4
    low: int = 0
    cool_windows: int = 2

    def decide(self, slo: Mapping[str, Any], n_replicas: int,
               n_min: int, n_max: int) -> int:
        windows = _windows(slo)
        if not windows:
            return n_replicas
        last = windows[-1]
        if last["queue_depth_max"] >= self.high:
            return n_replicas + 1
        tail = windows[-self.cool_windows:]
        drained = len(tail) >= self.cool_windows and all(
            w["queue_depth_max"] <= self.low and w["queue_depth_last"] == 0
            for w in tail
        )
        if drained:
            return n_replicas - 1
        return n_replicas


@register_autoscaler("slo-attainment-pid")
@dataclass
class SLOAttainmentPIDAutoscaler:
    """P+I control on the windowed e2e attainment deficit: error is
    ``target - e2e`` over the most recent scored window (windows with no
    terminals are skipped — an empty window is no evidence either way), the
    integral accumulates it with anti-windup at ``i_clamp``, and the fleet
    grows when the control signal crosses ``up`` or shrinks below ``down``.
    Attainment *lags* the queue gauge (a request scores only at its
    terminal), so this is the smoother, slower sibling of queue-threshold —
    the comparison the churn harness exists to measure."""

    name: str = "slo-attainment-pid"
    target: float = 0.95
    kp: float = 4.0
    ki: float = 1.0
    up: float = 0.5
    down: float = -0.5
    i_clamp: float = 2.0
    _integral: float = field(default=0.0, repr=False)

    def decide(self, slo: Mapping[str, Any], n_replicas: int,
               n_min: int, n_max: int) -> int:
        scored = [w for w in _windows(slo) if (w["done"] + w["shed"]) > 0]
        if not scored:
            return n_replicas
        err = self.target - scored[-1]["e2e"]
        self._integral = max(-self.i_clamp, min(self.i_clamp, self._integral + err))
        signal = self.kp * err + self.ki * self._integral
        if signal > self.up:
            return n_replicas + 1
        if signal < self.down:
            return n_replicas - 1
        return n_replicas
