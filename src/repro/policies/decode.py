"""Decode-side policies: Kairos slack-guided adaptive batching (paper
Algorithm 3) + the continuous-batching baseline (DistServe).

Each decode step the policy partitions the active set D into a batch B to
execute now and a delayed set R_delay that idles this step. Kairos packs
short requests whenever every active request still has enough TPOT slack.

Three registered names, two classes: ``kairos-slack-greedy`` is the
beyond-paper greedy-fill variant of ``SlackDecodeScheduler`` (see the
``require_throughput_gain`` note below), registered with different
construction defaults. Both backends construct these via ``make_decode`` —
see ``repro.policies.registry``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.core.lut import StepTimeLUT
from repro.core.request import Request
from repro.policies.registry import Partition, register_decode


@register_decode("kairos-slack")
@dataclass
class SlackDecodeScheduler:
    """Paper Algorithm 3: slack-guided adaptive decode scheduling.

    Two production refinements over the printed formulas (both default-on,
    disable for the verbatim paper semantics; see DESIGN.md §5):

    * `slo_margin`: schedule against margin*TPOT. Eq. 2 paces delayed
      requests at *exactly* the SLO boundary, so any jitter (admission gap,
      LUT error, step granularity) tips their mean ITL just over target; a
      ~10% margin absorbs it.
    * *actionable slack*: Eq. 2 measures elapsed from the prefill-emitted
      first token, so time spent in KV transfer + admission queueing becomes
      unrecoverable "debt" that drives s_min permanently negative and
      disables packing for the whole batch. We instead pace each request's
      decode-side tokens against its decode admission time
      (`Request.decode_start`); the metric still measures the true TTFT/TPOT
      including the gap — the margin covers the amortized gap.
    """

    lut: StepTimeLUT
    name: str = "kairos-slack"
    slo_margin: float = 0.9
    actionable_slack: bool = True

    def slack(self, r: Request, t_now: float) -> float:
        """Eq. 2: remaining budget before the next token must be delivered."""
        assert r.first_token_time is not None
        if self.actionable_slack and r.decode_start is not None:
            base, n = r.decode_start, r.n_decoded
        else:
            base, n = r.first_token_time, r.n_generated
        elapsed = t_now - base
        return (
            r.slo.tpot * self.slo_margin * (n + 1)
            - elapsed
            - self.lut.lookup(1, r.seq_len)
        )

    # require_throughput_gain=True is the paper's Alg. 3 line 13 condition.
    # False ("greedy-fill", beyond-paper, registered as kairos-slack-greedy)
    # admits any request that still fits the s_min budget: mid-length
    # requests are no longer pinned to the SLO pace when capacity allows, at
    # a small cost in short-request latency.
    require_throughput_gain: bool = True

    def select(self, active: Sequence[Request], t_now: float) -> Partition:
        if not active:
            return [], []
        slacks = np.array([self.slack(r, t_now) for r in active])
        s_min = float(np.min(slacks))

        # ascending seq_len (rid tiebreak)
        order = sorted(range(len(active)), key=lambda i: (active[i].seq_len, active[i].rid))
        batch: List[Request] = []
        delayed: List[Request] = []
        t_cur = 0.0
        for i in order:
            r = active[i]
            t_step = self.lut.lookup(len(batch) + 1, r.seq_len)
            improves = (
                (not batch)
                or not self.require_throughput_gain
                or (len(batch) + 1) / t_step > len(batch) / t_cur
            )
            if t_step <= s_min and improves:
                batch.append(r)
                t_cur = t_step
            else:
                delayed.append(r)
        if not batch:  # no slack to exploit; decode everything (Alg. 3 l.19-21)
            return list(active), []
        return batch, delayed

    def observe(self, batch: Sequence[Request], actual: float) -> None:
        """Post-step LUT update (Alg. 3 lines 23-24)."""
        if not batch:
            return
        self.lut.update(len(batch), max(r.seq_len for r in batch), actual)


@register_decode("continuous")
@dataclass
class ContinuousBatchingScheduler:
    """DistServe baseline: decode every active request each step."""

    lut: StepTimeLUT
    name: str = "continuous"

    def select(self, active: Sequence[Request], t_now: float) -> Partition:
        return list(active), []

    def observe(self, batch: Sequence[Request], actual: float) -> None:
        if batch:
            self.lut.update(len(batch), max(r.seq_len for r in batch), actual)


# Beyond-paper greedy-fill variant: same class, different construction
# defaults. The registry stamps instances with the registered name.
register_decode("kairos-slack-greedy", require_throughput_gain=False)(
    SlackDecodeScheduler
)
