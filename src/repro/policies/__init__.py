"""Scheduling-policy registry: protocols, factories, and the paper's
policies (plus baselines), shared by the simulator and the live engine.

Importing this package registers every built-in policy. Public surface:

    PolicySpec           name + kwargs, the unit both backends consume
    PrefillPolicy        protocol: select(queue, t_now, mu, budget)
    DecodePolicy         protocol: select(active, t_now) / observe(batch, t)
    register_prefill     class decorator, @register_prefill("my-policy")
    register_decode      class decorator (ctor takes the StepTimeLUT first)
    make_prefill         spec|name -> PrefillPolicy
    make_decode          spec|name, lut -> DecodePolicy
    available_policies   {"prefill": names, "decode": names}
"""
from repro.policies.decode import (
    ContinuousBatchingScheduler,
    SlackDecodeScheduler,
)
from repro.policies.prefill import (
    EDFPrefillScheduler,
    FCFSPrefillScheduler,
    SJFPrefillScheduler,
    UrgencyPlusPrefillScheduler,
    UrgencyPrefillScheduler,
)
from repro.policies.registry import (
    DecodePolicy,
    Partition,
    PolicySpec,
    PrefillPolicy,
    Selection,
    available_decode_policies,
    available_policies,
    available_prefill_policies,
    make_decode,
    make_prefill,
    register_decode,
    register_prefill,
)

__all__ = [
    "ContinuousBatchingScheduler",
    "SlackDecodeScheduler",
    "EDFPrefillScheduler",
    "FCFSPrefillScheduler",
    "SJFPrefillScheduler",
    "UrgencyPlusPrefillScheduler",
    "UrgencyPrefillScheduler",
    "DecodePolicy",
    "Partition",
    "PolicySpec",
    "PrefillPolicy",
    "Selection",
    "available_decode_policies",
    "available_policies",
    "available_prefill_policies",
    "make_decode",
    "make_prefill",
    "register_decode",
    "register_prefill",
]
