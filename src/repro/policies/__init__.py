"""Scheduling-policy registry: protocols, factories, and the paper's
policies (plus baselines), shared by the simulator and the live engine.

Importing this package registers every built-in policy. Public surface:

    PolicySpec           name + kwargs, the unit both backends consume
    PrefillPolicy        protocol: select(queue, t_now, mu, budget)
    DecodePolicy         protocol: select(active, t_now) / observe(batch, t)
    RouterPolicy         protocol: select(replicas, request, prompt) -> idx
    DeflectionPolicy     protocol: decide(fleet, request, prompt) -> bool
    AutoscalerPolicy     protocol: decide(slo, n, n_min, n_max) -> target n
    register_prefill     class decorator, @register_prefill("my-policy")
    register_decode      class decorator (ctor takes the StepTimeLUT first)
    register_router      class decorator, @register_router("my-router")
    register_deflection  class decorator, @register_deflection("my-rule")
    register_autoscaler  class decorator, @register_autoscaler("my-scaler")
    make_prefill         spec|name -> PrefillPolicy
    make_decode          spec|name, lut -> DecodePolicy
    make_router          spec|name -> RouterPolicy
    make_deflection      spec|name -> DeflectionPolicy
    make_autoscaler      spec|name -> AutoscalerPolicy
    available_policies   {"prefill": ..., "decode": ..., "router": ...,
                          "deflection": ..., "autoscaler": ...}
"""
from repro.policies.autoscale import (
    QueueThresholdAutoscaler,
    SLOAttainmentPIDAutoscaler,
    StaticAutoscaler,
)
from repro.policies.decode import (
    ContinuousBatchingScheduler,
    SlackDecodeScheduler,
)
from repro.policies.deflection import (
    NeverDeflect,
    PrefillPressureDeflect,
    ShortPromptDeflect,
    SlackAwareDeflect,
)
from repro.policies.prefill import (
    EDFPrefillScheduler,
    FCFSPrefillScheduler,
    SJFPrefillScheduler,
    UrgencyPlusPrefillScheduler,
    UrgencyPrefillScheduler,
)
from repro.policies.registry import (
    AutoscalerPolicy,
    DecodePolicy,
    DeflectionPolicy,
    Partition,
    PolicySpec,
    PrefillPolicy,
    RouterPolicy,
    Selection,
    available_autoscaler_policies,
    available_decode_policies,
    available_deflection_policies,
    available_policies,
    available_prefill_policies,
    available_router_policies,
    make_autoscaler,
    make_decode,
    make_deflection,
    make_prefill,
    make_router,
    register_autoscaler,
    register_decode,
    register_deflection,
    register_prefill,
    register_router,
)
from repro.policies.router import (
    LeastQueuedRouter,
    PrefixAffinityRouter,
    RoundRobinRouter,
    SlackAwareRouter,
)

__all__ = [
    "ContinuousBatchingScheduler",
    "SlackDecodeScheduler",
    "EDFPrefillScheduler",
    "FCFSPrefillScheduler",
    "SJFPrefillScheduler",
    "UrgencyPlusPrefillScheduler",
    "UrgencyPrefillScheduler",
    "LeastQueuedRouter",
    "PrefixAffinityRouter",
    "RoundRobinRouter",
    "SlackAwareRouter",
    "NeverDeflect",
    "PrefillPressureDeflect",
    "ShortPromptDeflect",
    "SlackAwareDeflect",
    "QueueThresholdAutoscaler",
    "SLOAttainmentPIDAutoscaler",
    "StaticAutoscaler",
    "AutoscalerPolicy",
    "DecodePolicy",
    "DeflectionPolicy",
    "Partition",
    "PolicySpec",
    "PrefillPolicy",
    "RouterPolicy",
    "Selection",
    "available_autoscaler_policies",
    "available_decode_policies",
    "available_deflection_policies",
    "available_policies",
    "available_prefill_policies",
    "available_router_policies",
    "make_autoscaler",
    "make_decode",
    "make_deflection",
    "make_prefill",
    "make_router",
    "register_autoscaler",
    "register_decode",
    "register_deflection",
    "register_prefill",
    "register_router",
]
