"""Scheduling-policy registry: protocols, factories, and the paper's
policies (plus baselines), shared by the simulator and the live engine.

Importing this package registers every built-in policy. Public surface:

    PolicySpec           name + kwargs, the unit both backends consume
    PrefillPolicy        protocol: select(queue, t_now, mu, budget)
    DecodePolicy         protocol: select(active, t_now) / observe(batch, t)
    RouterPolicy         protocol: select(replicas, request, prompt) -> idx
    register_prefill     class decorator, @register_prefill("my-policy")
    register_decode      class decorator (ctor takes the StepTimeLUT first)
    register_router      class decorator, @register_router("my-router")
    make_prefill         spec|name -> PrefillPolicy
    make_decode          spec|name, lut -> DecodePolicy
    make_router          spec|name -> RouterPolicy
    available_policies   {"prefill": names, "decode": names, "router": names}
"""
from repro.policies.decode import (
    ContinuousBatchingScheduler,
    SlackDecodeScheduler,
)
from repro.policies.prefill import (
    EDFPrefillScheduler,
    FCFSPrefillScheduler,
    SJFPrefillScheduler,
    UrgencyPlusPrefillScheduler,
    UrgencyPrefillScheduler,
)
from repro.policies.registry import (
    DecodePolicy,
    Partition,
    PolicySpec,
    PrefillPolicy,
    RouterPolicy,
    Selection,
    available_decode_policies,
    available_policies,
    available_prefill_policies,
    available_router_policies,
    make_decode,
    make_prefill,
    make_router,
    register_decode,
    register_prefill,
    register_router,
)
from repro.policies.router import (
    LeastQueuedRouter,
    PrefixAffinityRouter,
    RoundRobinRouter,
    SlackAwareRouter,
)

__all__ = [
    "ContinuousBatchingScheduler",
    "SlackDecodeScheduler",
    "EDFPrefillScheduler",
    "FCFSPrefillScheduler",
    "SJFPrefillScheduler",
    "UrgencyPlusPrefillScheduler",
    "UrgencyPrefillScheduler",
    "LeastQueuedRouter",
    "PrefixAffinityRouter",
    "RoundRobinRouter",
    "SlackAwareRouter",
    "DecodePolicy",
    "Partition",
    "PolicySpec",
    "PrefillPolicy",
    "RouterPolicy",
    "Selection",
    "available_decode_policies",
    "available_policies",
    "available_prefill_policies",
    "available_router_policies",
    "make_decode",
    "make_prefill",
    "make_router",
    "register_decode",
    "register_prefill",
    "register_router",
]
