"""Routing policies: which replica does a request land on?

Kairos schedules *within* one prefill/decode pair; at fleet scale the prior
question is placement — a request routed to an overloaded replica has lost
its TTFT before urgency scheduling ever sees it (the load-aware prefill
deflection argument, PAPERS.md). These policies consume the per-replica
view the `RouterSession` maintains (`repro.serving.router.ReplicaState`):

    in_flight               requests routed there and not yet terminal
    pending_prefill_tokens  prompt tokens routed there whose prefill hasn't
                            finished (the prefill backlog)
    mu                      the replica's online prefill-throughput estimate
    prefix_match(prompt)    longest prefix (tokens) the router has already
                            sent to that replica

All four are deterministic pure functions of that view (plus internal
counters), so routed runs replay bit-for-bit on a `ManualClock` — the
failover/determinism property the slot-allocator snapshot fix protects.

Registered in the shared `repro.policies` registry (`@register_router`);
`make_router("slack-aware")` builds them anywhere a name is accepted.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

from repro.core.request import Request
from repro.policies.registry import register_router


def _least_loaded(replicas: Sequence[Any]) -> int:
    """Lowest in-flight count; index breaks ties so replay is stable."""
    return min(range(len(replicas)), key=lambda i: (replicas[i].in_flight, i))


@register_router("round-robin")
@dataclass
class RoundRobinRouter:
    """Load-blind rotation — the baseline every aware policy must beat."""

    name: str = "round-robin"
    _next: int = field(default=0, init=False, repr=False)

    def select(self, replicas: Sequence[Any], request: Request,
               prompt: Sequence[int]) -> int:
        i = self._next % len(replicas)
        self._next += 1
        return i


@register_router("least-queued")
@dataclass
class LeastQueuedRouter:
    """Join the shortest queue: fewest routed-and-not-yet-terminal requests."""

    name: str = "least-queued"

    def select(self, replicas: Sequence[Any], request: Request,
               prompt: Sequence[int]) -> int:
        return _least_loaded(replicas)


@register_router("slack-aware")
@dataclass
class SlackAwareRouter:
    """Route by predicted prefill completion: the replica whose prefill
    backlog plus this prompt clears soonest at its observed throughput
    (backlog_tokens + input_len) / mu — TTFT-slack preserved at placement
    time, in-flight count as the tiebreak."""

    name: str = "slack-aware"

    def select(self, replicas: Sequence[Any], request: Request,
               prompt: Sequence[int]) -> int:
        def eta(i: int) -> float:
            r = replicas[i]
            return (r.pending_prefill_tokens + request.input_len) / max(r.mu, 1e-9)

        return min(range(len(replicas)), key=lambda i: (eta(i), replicas[i].in_flight, i))


@register_router("prefix-affinity")
@dataclass
class PrefixAffinityRouter:
    """Route to the replica already holding the longest prefix of this
    prompt (KV reuse beats load when a match exists); prompts with no match
    anywhere fall back to least-queued so cold traffic still balances."""

    name: str = "prefix-affinity"
    min_match_tokens: int = 1  # matches shorter than this don't steer

    def select(self, replicas: Sequence[Any], request: Request,
               prompt: Sequence[int]) -> int:
        matches = [r.prefix_match(prompt) for r in replicas]
        best = max(matches)
        if best >= self.min_match_tokens:
            return min(
                (i for i, m in enumerate(matches) if m == best),
                key=lambda i: (replicas[i].in_flight, i),
            )
        return _least_loaded(replicas)
