"""Prefill-side policies: Kairos urgency (paper Algorithm 1) + baselines.

A prefill policy's job each step: given the queue and a chunk budget ``C``
(chunked prefill, Sarathi-style), pick which requests contribute how many
tokens to this step. Output is a list of (request, n_tokens) with
sum(n_tokens) <= C; a request whose remaining tokens exceed the leftover
budget gets a partial chunk (paper Alg. 1 lines 16-18).

Every class here registers itself in the policy registry; both the
simulator and the engine construct them via ``make_prefill`` — see
``repro.policies.registry``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.predictor import predict_all_finish_times
from repro.core.request import Request
from repro.policies.registry import Selection, register_prefill


def _pack_budget(ordered: Sequence[Request], budget: int) -> Selection:
    """Greedy chunk packing in the given priority order."""
    out: Selection = []
    used = 0
    for r in ordered:
        if used >= budget:
            break
        take = min(r.remaining_prefill_tokens, budget - used)
        if take <= 0:
            continue
        out.append((r, take))
        used += take
    return out


@register_prefill("kairos-urgency")
@dataclass
class UrgencyPrefillScheduler:
    """Paper Algorithm 1: urgency-based priority scheduling.

    score = ((SLO_TTFT - (finish_fcfs - arrive)) / SLO_TTFT) / input_len
    sorted descending; chunk budget filled greedily with partial tail chunk.
    """

    name: str = "kairos-urgency"

    def select(
        self, queue: Sequence[Request], t_now: float, mu: float, budget: int
    ) -> Selection:
        if not queue:
            return []
        finish = predict_all_finish_times(queue, t_now, mu)
        scores = np.empty(len(queue))
        for i, r in enumerate(queue):
            slack = r.slo.ttft - (finish[i] - r.arrival)
            scores[i] = (slack / r.slo.ttft) / max(1, r.input_len)
        # descending by score; rid tiebreak for determinism
        order = np.lexsort((np.array([r.rid for r in queue]), -scores))
        return _pack_budget([queue[i] for i in order], budget)

    def urgency_scores(
        self, queue: Sequence[Request], t_now: float, mu: float
    ) -> np.ndarray:
        finish = predict_all_finish_times(queue, t_now, mu)
        return np.array(
            [
                ((r.slo.ttft - (finish[i] - r.arrival)) / r.slo.ttft) / max(1, r.input_len)
                for i, r in enumerate(queue)
            ]
        )


@register_prefill("kairos-urgency-plus")
@dataclass
class UrgencyPlusPrefillScheduler:
    """Beyond-paper fix of Algorithm 1's negative-slack ordering inversion.

    As printed, u = (slack/SLO)/len sorted descending: once slack < 0 the
    1/len normalization *inverts* — among late requests the LONGEST ranks
    first (its negative score is closest to zero), so a 128K request that
    drove everyone's predicted slack negative monopolizes the budget and
    Kairos degenerates to worse-than-FCFS exactly in the HOL scenario the
    paper targets (observed in sim at util >~0.7).

    Fix: triage into three tiers by *optimistic* slack (if scheduled now:
    finish = t_now + remaining/mu):
      1. rescuable  — FCFS-slack < 0 but optimistic slack >= 0: most urgent;
         ordered by ascending paper-score (shortest/most-behind first).
      2. comfortable — FCFS-slack >= 0: paper's descending order (verbatim).
      3. lost — optimistic slack < 0: cannot meet the SLO even if scheduled
         immediately; ordered by descending score (paper tie-break), they
         only consume leftover budget.
    """

    name: str = "kairos-urgency-plus"

    def select(
        self, queue: Sequence[Request], t_now: float, mu: float, budget: int
    ) -> Selection:
        if not queue:
            return []
        finish = predict_all_finish_times(queue, t_now, mu)
        mu = max(mu, 1e-9)
        tiers: List[Tuple[int, float, int, Request]] = []
        for i, r in enumerate(queue):
            slack_fcfs = r.slo.ttft - (finish[i] - r.arrival)
            slack_opt = r.slo.ttft - (
                (t_now + r.remaining_prefill_tokens / mu) - r.arrival
            )
            u = (slack_fcfs / r.slo.ttft) / max(1, r.input_len)
            if slack_opt < 0:
                tiers.append((2, -u, r.rid, r))  # lost: desc u
            elif slack_fcfs < 0:
                tiers.append((0, u, r.rid, r))  # rescuable: asc u
            else:
                tiers.append((1, -u, r.rid, r))  # comfortable: desc u
        tiers.sort(key=lambda t: (t[0], t[1], t[2]))
        return _pack_budget([t[3] for t in tiers], budget)


@register_prefill("fcfs")
@dataclass
class FCFSPrefillScheduler:
    """DistServe baseline: arrival order + chunked prefill."""

    name: str = "fcfs"

    def select(
        self, queue: Sequence[Request], t_now: float, mu: float, budget: int
    ) -> Selection:
        ordered = sorted(queue, key=lambda r: (r.arrival, r.rid))
        return _pack_budget(ordered, budget)


@register_prefill("sjf")
@dataclass
class SJFPrefillScheduler:
    """Shortest-job-first (paper discusses as impractical: starves long)."""

    name: str = "sjf"

    def select(
        self, queue: Sequence[Request], t_now: float, mu: float, budget: int
    ) -> Selection:
        ordered = sorted(queue, key=lambda r: (r.remaining_prefill_tokens, r.rid))
        return _pack_budget(ordered, budget)


@register_prefill("edf")
@dataclass
class EDFPrefillScheduler:
    """Earliest-deadline-first ablation (deadline = arrival + SLO_TTFT)."""

    name: str = "edf"

    def select(
        self, queue: Sequence[Request], t_now: float, mu: float, budget: int
    ) -> Selection:
        ordered = sorted(queue, key=lambda r: (r.arrival + r.slo.ttft, r.rid))
        return _pack_budget(ordered, budget)


@register_prefill("srpt")
@dataclass
class SRPTPrefillScheduler:
    """Shortest-remaining-processing-time: the theory-grounded reference.

    "Optimal Scheduling Algorithms for LLM Inference" (PAPERS.md) proves
    SRPT-style index rules are optimal (fluid limit) for mean latency in
    single-server LLM serving. Unlike ``sjf`` — which ranks by remaining
    *prefill* only — SRPT's index is the request's whole remaining service:
    prefill tokens still to compute plus decode tokens still to emit, so a
    short prompt with a long generation queues behind a long prompt that is
    nearly done. Reported next to kairos, it turns "beats fcfs" into "how
    far from the clairvoyant-optimal ordering".
    """

    name: str = "srpt"

    def select(
        self, queue: Sequence[Request], t_now: float, mu: float, budget: int
    ) -> Selection:
        ordered = sorted(
            queue,
            key=lambda r: (
                r.remaining_prefill_tokens + max(0, r.output_len - r.n_generated),
                r.rid,
            ),
        )
        return _pack_budget(ordered, budget)


@register_prefill("cache-aware")
@dataclass
class CacheAwarePrefillScheduler:
    """Prefix-reuse-aware urgency: weigh cached pages against TTFT slack.

    On a paged engine (DESIGN.md §kvcache) a request whose prompt head is
    already in the radix cache costs only its *uncached* tail of prefill
    compute, so between two requests with equal slack the one with more
    cached tokens finishes its prefill sooner per budget token. The score is
    kairos-urgency's slack ratio normalized by the request's **remaining**
    (uncached) prefill work rather than its full prompt length:

        score = ((SLO_TTFT - (finish_fcfs - arrive)) / SLO_TTFT)
                / max(1, remaining_prefill_tokens)

    With no prefix cache (``prefix_cached_tokens == 0`` everywhere and no
    chunks run) the ordering matches kairos-urgency exactly; with reuse it
    drains high-hit requests first — which also re-touches their shared
    pages, keeping hot prefixes at the LRU head (sglang's cache-aware
    scheduling argument, SNIPPETS.md §3).
    """

    name: str = "cache-aware"

    def select(
        self, queue: Sequence[Request], t_now: float, mu: float, budget: int
    ) -> Selection:
        if not queue:
            return []
        finish = predict_all_finish_times(queue, t_now, mu)
        scores = np.empty(len(queue))
        for i, r in enumerate(queue):
            slack = r.slo.ttft - (finish[i] - r.arrival)
            scores[i] = (slack / r.slo.ttft) / max(1, r.remaining_prefill_tokens)
        order = np.lexsort((np.array([r.rid for r in queue]), -scores))
        return _pack_budget([queue[i] for i in order], budget)
