"""Policy registry: the one seam between scheduling *policy* and serving
*mechanism*.

The paper's claim is that the urgency-prefill / slack-decode policies are
separable from the serving substrate. This module makes that separation an
API: policies register themselves by name, and both backends — the
discrete-event `DisaggSimulator` and the real-compute `DisaggServer` —
construct them through the same factories, keyed by a shared `PolicySpec`.
Neither backend knows any policy by name anymore.

    spec  = PolicySpec("kairos-slack", {"slo_margin": 0.85})
    psch  = make_prefill("kairos-urgency")
    dsch  = make_decode(spec, lut)
    names = available_policies()   # {"prefill": (...), "decode": (...)}

Registering a new policy is one decorator on the implementing class::

    @register_prefill("my-policy")
    @dataclass
    class MyPrefillScheduler:
        def select(self, queue, t_now, mu, budget): ...

A class may be registered under several names with different construction
defaults (e.g. ``kairos-slack-greedy`` is ``SlackDecodeScheduler`` with
``require_throughput_gain=False``). Explicit `PolicySpec.kwargs` are strict
— an argument the policy's constructor does not accept raises — while
backend-supplied *soft* defaults (e.g. the engine's config-level
``slo_margin``) are silently dropped for policies that do not take them.

See DESIGN.md §registry.
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass, field
from typing import (
    Any,
    Dict,
    List,
    Mapping,
    Protocol,
    Sequence,
    Tuple,
    Union,
    runtime_checkable,
)

from repro.core.lut import StepTimeLUT
from repro.core.request import Request

# A prefill selection: (request, n_tokens) pairs, sum(n_tokens) <= budget.
Selection = List[Tuple[Request, int]]
# A decode partition: (batch to execute now, delayed set idling this step).
Partition = Tuple[List[Request], List[Request]]


@runtime_checkable
class PrefillPolicy(Protocol):
    """Chunked-prefill scheduler: picks who prefills how much this step."""

    name: str

    def select(
        self, queue: Sequence[Request], t_now: float, mu: float, budget: int
    ) -> Selection: ...


@runtime_checkable
class DecodePolicy(Protocol):
    """Decode-batch scheduler: partitions the active set each step."""

    name: str

    def select(self, active: Sequence[Request], t_now: float) -> Partition: ...

    def observe(self, batch: Sequence[Request], actual: float) -> None: ...


@runtime_checkable
class RouterPolicy(Protocol):
    """Multi-server routing: picks which replica a request lands on.

    ``replicas`` is a sequence of replica views (`repro.serving.router.
    ReplicaState`: in-flight count, pending prefill tokens, throughput
    estimate, prefix-match probe); the policy returns an index into it.
    Where a request lands decides whether within-replica urgency scheduling
    can save its TTFT at all, so this is the fleet-level half of the
    scheduling story.
    """

    name: str

    def select(self, replicas: Sequence[Any], request: Request,
               prompt: Sequence[int]) -> int: ...


@runtime_checkable
class DeflectionPolicy(Protocol):
    """Prefill-deflection decision: should this request's *prefill* run on a
    decode-pool server instead of the prefill pool?

    ``fleet`` is a disagg fleet view (`repro.serving.disagg.DisaggSession`):
    per-worker backlogs, throughput estimates, and free decode slots. Return
    True to deflect — the request prefills on an underutilized decode server
    and skips the cross-server KV handoff entirely (Microsoft's load-aware
    prefill deflection, PAPERS.md).
    """

    name: str

    def decide(self, fleet: Any, request: Request,
               prompt: Sequence[int]) -> bool: ...


@runtime_checkable
class AutoscalerPolicy(Protocol):
    """Elastic-scaling decision: how many replicas should the fleet run?

    ``slo`` is `repro.obs.slo.windowed_slo` output (per-window attainment,
    queue-depth gauges, decode-time-vs-TPOT-budget series) — deliberately
    *not* session internals, so the controller reacts to the same telemetry
    an operator would watch. Return the desired live-replica count; the
    fleet controller clamps it to ``[n_min, n_max]`` and performs at most
    one scale step per control interval.
    """

    name: str

    def decide(self, slo: Mapping[str, Any], n_replicas: int,
               n_min: int, n_max: int) -> int: ...


@dataclass(frozen=True)
class PolicySpec:
    """Serializable policy reference: registered name + construction kwargs.

    The same spec drives both backends; a bare string is accepted anywhere a
    spec is (it coerces to ``PolicySpec(name)`` with no kwargs).
    """

    name: str
    kwargs: Mapping[str, Any] = field(default_factory=dict)

    @classmethod
    def coerce(cls, spec: Union[str, "PolicySpec"]) -> "PolicySpec":
        if isinstance(spec, PolicySpec):
            return spec
        if isinstance(spec, str):
            return cls(name=spec)
        raise TypeError(f"policy spec must be str or PolicySpec, got {type(spec)!r}")


@dataclass(frozen=True)
class _Entry:
    cls: type
    defaults: Mapping[str, Any]


_PREFILL: Dict[str, _Entry] = {}
_DECODE: Dict[str, _Entry] = {}
_ROUTER: Dict[str, _Entry] = {}
_DEFLECTION: Dict[str, _Entry] = {}
_AUTOSCALER: Dict[str, _Entry] = {}


def register_prefill(name: str, **defaults):
    """Class decorator: register a prefill policy under ``name``."""

    def deco(cls):
        _PREFILL[name] = _Entry(cls, defaults)
        return cls

    return deco


def register_decode(name: str, **defaults):
    """Class decorator: register a decode policy under ``name``.

    Decode constructors take the shared ``StepTimeLUT`` as their first
    positional argument; ``defaults`` pre-bind keyword arguments (used for
    named variants of one class).
    """

    def deco(cls):
        _DECODE[name] = _Entry(cls, defaults)
        return cls

    return deco


def register_router(name: str, **defaults):
    """Class decorator: register a routing policy under ``name``."""

    def deco(cls):
        _ROUTER[name] = _Entry(cls, defaults)
        return cls

    return deco


def register_deflection(name: str, **defaults):
    """Class decorator: register a prefill-deflection policy under ``name``."""

    def deco(cls):
        _DEFLECTION[name] = _Entry(cls, defaults)
        return cls

    return deco


def register_autoscaler(name: str, **defaults):
    """Class decorator: register an autoscaler policy under ``name``."""

    def deco(cls):
        _AUTOSCALER[name] = _Entry(cls, defaults)
        return cls

    return deco


def available_prefill_policies() -> Tuple[str, ...]:
    return tuple(sorted(_PREFILL))


def available_decode_policies() -> Tuple[str, ...]:
    return tuple(sorted(_DECODE))


def available_router_policies() -> Tuple[str, ...]:
    return tuple(sorted(_ROUTER))


def available_deflection_policies() -> Tuple[str, ...]:
    return tuple(sorted(_DEFLECTION))


def available_autoscaler_policies() -> Tuple[str, ...]:
    return tuple(sorted(_AUTOSCALER))


def available_policies() -> Dict[str, Tuple[str, ...]]:
    """Every registered policy name, per side — the CLI help / parity-test
    enumeration entry point."""
    return {
        "prefill": available_prefill_policies(),
        "decode": available_decode_policies(),
        "router": available_router_policies(),
        "deflection": available_deflection_policies(),
        "autoscaler": available_autoscaler_policies(),
    }


def _lookup(table: Dict[str, _Entry], kind: str, name: str) -> _Entry:
    try:
        return table[name]
    except KeyError:
        known = ", ".join(sorted(table))
        raise ValueError(
            f"unknown {kind} policy {name!r}; registered {kind} policies: {known}"
        ) from None


def _accepted_params(cls: type) -> Dict[str, inspect.Parameter]:
    return dict(inspect.signature(cls).parameters)


def _build(
    table: Dict[str, _Entry],
    kind: str,
    spec: Union[str, PolicySpec],
    positional: Tuple[Any, ...],
    soft_defaults: Mapping[str, Any],
):
    spec = PolicySpec.coerce(spec)
    entry = _lookup(table, kind, spec.name)
    params = _accepted_params(entry.cls)
    bad = [k for k in spec.kwargs if k not in params]
    if bad:
        raise ValueError(
            f"{kind} policy {spec.name!r} ({entry.cls.__name__}) does not accept "
            f"kwargs {bad}; accepted: {sorted(params)}"
        )
    kw: Dict[str, Any] = {k: v for k, v in soft_defaults.items() if k in params}
    kw.update(entry.defaults)
    kw.update(spec.kwargs)
    obj = entry.cls(*positional, **kw)
    # Stamp the registered name so metrics/logs show the variant actually
    # requested (e.g. kairos-slack-greedy, not its implementing class default).
    if "name" not in kw and getattr(obj, "name", spec.name) != spec.name:
        obj.name = spec.name
    return obj


def make_prefill(
    spec: Union[str, PolicySpec], **soft_defaults: Any
) -> PrefillPolicy:
    """Construct a registered prefill policy from a spec (or bare name)."""
    return _build(_PREFILL, "prefill", spec, (), soft_defaults)


def make_decode(
    spec: Union[str, PolicySpec], lut: StepTimeLUT, **soft_defaults: Any
) -> DecodePolicy:
    """Construct a registered decode policy around the shared step-time LUT.

    ``soft_defaults`` lets a backend forward config-level knobs (e.g. the
    engine's ``slo_margin``) without knowing which policies take them.
    """
    return _build(_DECODE, "decode", spec, (lut,), soft_defaults)


def make_router(spec: Union[str, PolicySpec], **soft_defaults: Any) -> RouterPolicy:
    """Construct a registered routing policy from a spec (or bare name)."""
    return _build(_ROUTER, "router", spec, (), soft_defaults)


def make_deflection(
    spec: Union[str, PolicySpec], **soft_defaults: Any
) -> DeflectionPolicy:
    """Construct a registered prefill-deflection policy from a spec/name."""
    return _build(_DEFLECTION, "deflection", spec, (), soft_defaults)


def make_autoscaler(
    spec: Union[str, PolicySpec], **soft_defaults: Any
) -> AutoscalerPolicy:
    """Construct a registered autoscaler policy from a spec (or bare name)."""
    return _build(_AUTOSCALER, "autoscaler", spec, (), soft_defaults)
