"""Serving launcher: disaggregated engine with registry-driven scheduling.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b-smoke \
        --requests 8 [--policy kairos-urgency] [--decode-policy kairos-slack] \
        [--queue-depth 16] [--list-policies]

``--policy`` / ``--decode-policy`` accept any name registered in
``repro.policies`` (the same registry the simulator uses); ``--list-policies``
prints them. ``--queue-depth`` bounds the admission queue: submits beyond it
are shed and reported in the session metrics.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.request import Request, SLOSpec
from repro.models import build_model
from repro.policies import available_policies
from repro.serving.engine import DisaggServer, EngineConfig
from repro.serving.session import ServeSession


def main() -> None:
    pol = available_policies()
    ap = argparse.ArgumentParser(
        description="Disaggregated serving demo (policies from repro.policies)"
    )
    ap.add_argument("--arch", default="llama3-8b-smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-out", type=int, default=12)
    ap.add_argument(
        "--policy",
        default="kairos-urgency",
        choices=pol["prefill"],
        help=f"prefill policy; registered: {', '.join(pol['prefill'])}",
    )
    ap.add_argument(
        "--decode-policy",
        default="kairos-slack",
        choices=pol["decode"],
        help=f"decode policy; registered: {', '.join(pol['decode'])}",
    )
    ap.add_argument(
        "--queue-depth", type=int, default=0,
        help="admission-control queue depth; 0 = unbounded",
    )
    ap.add_argument(
        "--list-policies", action="store_true",
        help="print registered policies and exit",
    )
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--ttft-slo", type=float, default=60.0)
    ap.add_argument("--tpot-slo", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.list_policies:
        for side, names in pol.items():
            print(f"{side}: {', '.join(names)}")
        return

    cfg = get_config(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(args.seed)

    reqs = []
    for i in range(args.requests):
        n = int(rng.choice([6, 10, 16, 40], p=[0.4, 0.3, 0.2, 0.1]))
        prompt = list(map(int, rng.integers(2, cfg.vocab_size, n)))
        reqs.append(
            (
                Request(rid=i, arrival=0.05 * i, input_len=n, output_len=args.max_out,
                        slo=SLOSpec(ttft=args.ttft_slo, tpot=args.tpot_slo)),
                prompt,
            )
        )

    ecfg = EngineConfig(
        max_slots=8, max_len=128, chunk_size=args.chunk_size,
        prefill_policy=args.policy, decode_policy=args.decode_policy,
        admission_queue_depth=args.queue_depth or None,
    )
    server = DisaggServer(model, params, ecfg)

    # drive the streaming session directly (what serve() wraps) so the
    # admission metrics stay in hand
    session = ServeSession(server)
    outs = session.run(reqs)
    n_ok = 0
    for r, _ in reqs:
        ok = r.meets_e2e()
        n_ok += ok
        print(
            f"rid={r.rid} phase={r.phase.value} tokens={len(outs.get(r.rid, []))} "
            f"ttft={(r.ttft() or 0):.2f}s mean_itl={1e3*(r.mean_tpot() or 0):.0f}ms e2e_ok={ok}"
        )
    s = session.summary()
    print(
        f"E2E SLO attainment: {n_ok}/{len(reqs)} "
        f"(submitted={s['submitted']} shed={s['rejected']})"
    )


if __name__ == "__main__":
    main()
