"""Serving launcher: disaggregated engine with Kairos scheduling.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b-smoke \
        --requests 8 [--policy kairos-urgency] [--decode-policy kairos-slack]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.request import Phase, Request, SLOSpec
from repro.models import build_model
from repro.serving.engine import DisaggServer, EngineConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b-smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-out", type=int, default=12)
    ap.add_argument("--policy", default="kairos-urgency")
    ap.add_argument("--decode-policy", default="kairos-slack")
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--ttft-slo", type=float, default=60.0)
    ap.add_argument("--tpot-slo", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(args.seed)

    reqs = []
    for i in range(args.requests):
        n = int(rng.choice([6, 10, 16, 40], p=[0.4, 0.3, 0.2, 0.1]))
        prompt = list(map(int, rng.integers(2, cfg.vocab_size, n)))
        reqs.append(
            (
                Request(rid=i, arrival=0.05 * i, input_len=n, output_len=args.max_out,
                        slo=SLOSpec(ttft=args.ttft_slo, tpot=args.tpot_slo)),
                prompt,
            )
        )

    ecfg = EngineConfig(
        max_slots=8, max_len=128, chunk_size=args.chunk_size,
        prefill_policy=args.policy, decode_policy=args.decode_policy,
    )
    server = DisaggServer(model, params, ecfg)
    outs = server.serve(reqs)
    n_ok = 0
    for r, _ in reqs:
        ok = r.meets_e2e()
        n_ok += ok
        print(
            f"rid={r.rid} phase={r.phase.value} tokens={len(outs.get(r.rid, []))} "
            f"ttft={r.ttft():.2f}s mean_itl={1e3*(r.mean_tpot() or 0):.0f}ms e2e_ok={ok}"
        )
    print(f"E2E SLO attainment: {n_ok}/{len(reqs)}")


if __name__ == "__main__":
    main()
