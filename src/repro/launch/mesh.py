"""Production mesh construction.

Importing this module never touches jax device state; meshes are built by
functions only. Single pod: 16x16 = 256 chips ('data' x 'model'); multi-pod:
2 x 16 x 16 = 512 chips ('pod' x 'data' x 'model') — 'pod' is the DCN axis.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Elastic variant: arbitrary shapes (degraded device counts, smoke)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """Whatever devices exist, one axis each of data/model (CPU tests)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))
