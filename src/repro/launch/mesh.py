"""Production mesh construction.

Importing this module never touches jax device state; meshes are built by
functions only. Single pod: 16x16 = 256 chips ('data' x 'model'); multi-pod:
2 x 16 x 16 = 512 chips ('pod' x 'data' x 'model') — 'pod' is the DCN axis.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax


def ensure_host_platform_devices(n: int = 512) -> None:
    """Expose `n` host platform devices to XLA (production-mesh dry-runs on
    CPU). Must run before jax's backend initializes — i.e. before the first
    device query, NOT before `import jax` (backends are created lazily), so
    CLI mains call this as their first statement and module tops stay
    import-only (ruff E402)."""
    if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + f" --xla_force_host_platform_device_count={n}"
        ).strip()


def _mk(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    # jax.sharding.AxisType landed after 0.4.x; older jax defaults every
    # axis to Auto anyway, so omit the kwarg when it doesn't exist.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Elastic variant: arbitrary shapes (degraded device counts, smoke)."""
    return _mk(shape, axes)


def make_host_mesh():
    """Whatever devices exist, one axis each of data/model (CPU tests)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))
