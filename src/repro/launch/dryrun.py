"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Per cell this driver:
  1. builds the model + step function (train_step / prefill_step / serve_step),
  2. builds ShapeDtypeStruct inputs + divisibility-checked shardings,
  3. jit(...).lower(...).compile(),
  4. records memory_analysis(), cost_analysis(), parsed collective bytes,
     sharding fallbacks and timings to artifacts/dryrun/<cell>.json.

The production meshes need 512 placeholder host devices; main() calls
`ensure_host_platform_devices()` before the first device query (jax locks
the device count on first backend init, not on import).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
  python -m repro.launch.dryrun --calibrate
"""
import argparse
import json
import os
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    ALL_SHAPES,
    ASSIGNED,
    get_config,
    input_specs,
    shape_applicable,
)
from repro.dist.act_sharding import use_activation_sharding
from repro.dist.sharding import (
    ShardingPlan,
    cache_pspecs,
    input_pspecs,
    param_pspecs,
)
from repro.launch.mesh import ensure_host_platform_devices, make_production_mesh
from repro.models import build_model
from repro.roofline import hlo_stats
from repro.roofline.analysis import model_flops_for, parse_collective_bytes
from repro.training.optimizer import OptimizerConfig, OptState, init_opt_state
from repro.training.train_step import make_train_step

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

DEFAULT_MICRO = 8  # train_4k: 256-batch -> 8 microbatches of 32


def _cost_analysis(compiled) -> Dict:
    """jax < 0.5 returns a per-computation list of dicts; newer jax a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _ns(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def build_cell(arch: str, shape_name: str, mesh, sharding_mode: str = "train", n_micro: Optional[int] = None):
    """Returns (fn, args_structs, in_shardings, donate, meta)."""
    cfg = get_config(arch)
    spec = ALL_SHAPES[shape_name]
    model = build_model(cfg)
    plan = ShardingPlan(mesh, mode=sharding_mode)
    specs = input_specs(cfg, spec)

    params_struct = model.param_struct()
    p_pspec = param_pspecs(cfg, params_struct, plan)

    if spec.kind == "train":
        opt_struct = jax.eval_shape(init_opt_state, params_struct)
        opt_pspec = OptState(step=P(), m=p_pspec, v=jax.tree.map(lambda x: x, p_pspec))
        batch_pspec = input_pspecs(cfg, specs, plan)
        if n_micro is None:
            n_micro = DEFAULT_MICRO if spec.global_batch % DEFAULT_MICRO == 0 else 1
        # inside the scan body the microbatch has the scan dim stripped, so
        # its sharding matches the original batch spec
        micro_pspec = batch_pspec
        step = make_train_step(
            model,
            OptimizerConfig(),
            n_micro=n_micro,
            grad_shardings=_ns(mesh, p_pspec),
            micro_shardings=_ns(mesh, micro_pspec),
        )
        args = (params_struct, opt_struct, specs)
        shardings = (_ns(mesh, p_pspec), _ns(mesh, opt_pspec), _ns(mesh, batch_pspec))
        return step, args, shardings, (0, 1), dict(n_micro=n_micro, plan=plan)

    if spec.kind == "prefill":
        batch_pspec = input_pspecs(cfg, specs, plan)

        def prefill_fn(params, batch):
            return model.prefill(params, batch)

        args = (params_struct, specs)
        shardings = (_ns(mesh, p_pspec), _ns(mesh, batch_pspec))
        return prefill_fn, args, shardings, (), dict(plan=plan)

    # decode / serve_step
    cache_struct_ = specs["cache"]
    c_pspec = cache_pspecs(cfg, cache_struct_, plan)
    tok_pspec = input_pspecs(cfg, dict(t=specs["tokens"]), plan)["t"]
    pos_pspec = input_pspecs(cfg, dict(t=specs["positions"]), plan)["t"]

    def serve_step(params, tokens, positions, cache):
        return model.decode(params, tokens, positions, cache)

    args = (params_struct, specs["tokens"], specs["positions"], cache_struct_)
    shardings = (
        _ns(mesh, p_pspec),
        NamedSharding(mesh, tok_pspec),
        NamedSharding(mesh, pos_pspec),
        _ns(mesh, c_pspec),
    )
    return serve_step, args, shardings, (3,), dict(plan=plan)


def run_cell(
    arch: str, shape_name: str, mesh_kind: str, skip_existing: bool = False,
    sharding_mode: str = "train", tag: str = "", n_micro: Optional[int] = None,
) -> Dict:
    os.makedirs(ARTIFACTS, exist_ok=True)
    cell = f"{arch}__{shape_name}__{mesh_kind}" + (f"__{tag}" if tag else "")
    path = os.path.join(ARTIFACTS, cell + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    spec = ALL_SHAPES[shape_name]
    out: Dict = dict(arch=arch, shape=shape_name, mesh=mesh_kind, sharding_mode=sharding_mode, tag=tag)

    reason = shape_applicable(cfg, spec)
    if reason is not None:
        out.update(status="skipped", reason=reason)
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        return out

    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        chips = mesh.size
        t0 = time.time()
        fn, args, shardings, donate, meta = build_cell(arch, shape_name, mesh, sharding_mode, n_micro)
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        with use_activation_sharding(mesh, meta["plan"].batch_axes):
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
        t_compile = time.time() - t0

        ca = _cost_analysis(compiled)
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        colls = parse_collective_bytes(hlo)
        loop_aware = hlo_stats.analyze(hlo)

        out.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            # raw XLA cost_analysis (loop bodies counted ONCE — see
            # roofline/hlo_stats.py; kept for reference)
            flops=float(ca.get("flops", 0.0)),
            bytes_accessed=float(ca.get("bytes accessed", 0.0)),
            transcendentals=float(ca.get("transcendentals", 0.0)),
            # loop-aware per-device stats (used by the roofline)
            hlo_stats=loop_aware.as_dict(),
            memory=dict(
                argument_bytes=int(ma.argument_size_in_bytes),
                output_bytes=int(ma.output_size_in_bytes),
                temp_bytes=int(ma.temp_size_in_bytes),
                alias_bytes=int(ma.alias_size_in_bytes),
                generated_code_bytes=int(ma.generated_code_size_in_bytes),
            ),
            collectives=colls,
            model_flops=model_flops_for(cfg, spec),
            params=cfg.count_params(),
            active_params=cfg.count_active_params(),
            sharding_fallbacks=meta["plan"].fallbacks,
            hlo_len=len(hlo),
        )
    except Exception as e:  # a failure here is a bug in the system: record it
        out.update(status="error", error=f"{type(e).__name__}: {e}", tb=traceback.format_exc()[-4000:])
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return out


def calibrate() -> Dict:
    """Determine cost_analysis semantics (global vs per-partition flops)."""
    mesh = make_production_mesh(multi_pod=False)
    n = 4096
    a = jax.ShapeDtypeStruct((n, n), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((n, n), jnp.bfloat16)
    sh_a = NamedSharding(mesh, P("data", None))
    sh_b = NamedSharding(mesh, P(None, "model"))
    fn = jax.jit(lambda x, y: x @ y, in_shardings=(sh_a, sh_b))
    compiled = fn.lower(a, b).compile()
    flops = float(_cost_analysis(compiled).get("flops", 0.0))
    true_global = 2.0 * n * n * n
    ratio = flops / true_global
    sem = "global" if ratio > 0.5 else "per_partition"
    result = dict(reported=flops, true_global=true_global, ratio=ratio, semantics=sem, chips=mesh.size)
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, "_calibration.json"), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ensure_host_platform_devices()  # before any jax device query initializes the backend
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--calibrate", action="store_true")
    ap.add_argument("--sharding-mode", default="train", choices=["train", "serve", "dp", "zero"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--n-micro", type=int, default=None)
    args = ap.parse_args()

    if args.calibrate:
        print(json.dumps(calibrate(), indent=1))
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ASSIGNED if args.all or args.arch is None else [args.arch]
    shapes = list(ALL_SHAPES) if args.all or args.shape is None else [args.shape]

    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                t0 = time.time()
                res = run_cell(arch, shape, mk, skip_existing=args.skip_existing,
                               sharding_mode=args.sharding_mode, tag=args.tag,
                               n_micro=args.n_micro)
                status = res.get("status")
                extra = ""
                if status == "ok":
                    extra = (
                        f"compile={res['compile_s']}s flops={res['flops']:.3g} "
                        f"coll={res['collectives'].get('total', 0):.3g}B "
                        f"temp/dev={res['memory']['temp_bytes']/1e9:.2f}GB"
                    )
                elif status == "error":
                    extra = res["error"][:160]
                elif status == "skipped":
                    extra = "skipped"
                print(f"[{time.time()-t0:7.1f}s] {arch:26s} {shape:12s} {mk:6s} {status} {extra}", flush=True)


if __name__ == "__main__":
    main()
