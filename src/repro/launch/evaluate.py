"""SLO-attainment evaluation CLI: scenario × policy × backend grids.

    PYTHONPATH=src python -m repro.launch.evaluate \
        --scenario multi-tenant --backend engine \
        --prefill kairos-urgency --decode kairos-slack-greedy

Every flag that names a scenario/policy/backend accepts several values and
the harness sweeps the cartesian grid, emitting one JSON report (per-cell
total and per-tenant/per-class attainment, goodput, shed/cancelled counts)
to stdout or ``--out``. All six backends — ``sim``, ``engine``,
``async-engine`` (the `AsyncServeSession` frontend with concurrent stream
consumers; see `repro.launch.loadgen` for the dedicated open-loop driver),
``router`` (``--replicas`` frontends behind a `RouterSession`, placement by
``--router``, per-replica breakdown in the cell's ``router`` block), and
``disagg`` (a ``--pools P:D`` prefill/decode split with KV handoff and
``--deflect`` prefill deflection; handoff/deflection/per-pool-attainment in
the cell's ``disagg`` block), and ``churn`` (the router fleet under a
`FleetSession` control plane: ``--kill T:IDX`` replica-failure injection
with in-flight restore, ``--autoscaler`` elastic scaling on windowed-SLO
telemetry within ``--min-replicas``..``--max-replicas``; control-plane
record in the cell's ``churn`` block) — share the report schema;
``--list-scenarios`` / ``--list-policies`` print the registries.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.policies import (
    available_autoscaler_policies,
    available_deflection_policies,
    available_policies,
    available_router_policies,
)
from repro.workloads.harness import (
    BACKENDS,
    HarnessConfig,
    parse_kills,
    parse_pools,
    run_grid,
)
from repro.workloads.scenarios import available_scenarios


def build_parser() -> argparse.ArgumentParser:
    pol = available_policies()
    ap = argparse.ArgumentParser(
        description="Evaluate registered scheduling policies across workload "
        "scenarios on the simulator and/or the live engine."
    )
    ap.add_argument(
        "--scenario", nargs="+", default=["paper-longtail"], choices=available_scenarios(),
        help="workload scenario(s) from the repro.workloads registry",
    )
    ap.add_argument(
        "--prefill", nargs="+", default=["kairos-urgency"], choices=pol["prefill"],
        help="prefill policy name(s) from the repro.policies registry",
    )
    ap.add_argument(
        "--decode", nargs="+", default=["kairos-slack"], choices=pol["decode"],
        help="decode policy name(s) from the repro.policies registry",
    )
    ap.add_argument(
        "--backend", nargs="+", default=["sim"], choices=BACKENDS,
        help="serving substrate(s): discrete-event sim and/or live JAX engine",
    )
    ap.add_argument("--n", type=int, default=64, help="requests per scenario")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--queue-depth", type=int, default=0,
        help="engine global admission queue depth; 0 = unbounded",
    )
    ap.add_argument(
        "--tenant-quota", type=int, default=0,
        help="engine per-tenant queued-request quota; 0 = no quota",
    )
    ap.add_argument(
        "--replay-trace", default=None,
        help='JSONL request-trace file for the "replay" scenario (input)',
    )
    ap.add_argument(
        "--trace", default=None, metavar="PATH",
        help="write a per-cell event trace (repro.obs): .jsonl = raw event "
        "log, anything else = Chrome trace-event / Perfetto JSON; the cell "
        "coordinates are spliced into the filename and each cell carries a "
        '"trace" summary block in the report',
    )
    ap.add_argument(
        "--slo-window", type=float, default=None, metavar="SECONDS",
        help="with --trace: windowed SLO telemetry bucket width in backend "
        "virtual seconds (adds a windows series to each trace block)",
    )
    ap.add_argument(
        "--clients", type=int, default=4,
        help="async-engine backend: concurrent stream-consumer tasks",
    )
    ap.add_argument(
        "--stream-buffer", type=int, default=16,
        help="async-engine backend: per-request token buffer size",
    )
    ap.add_argument(
        "--backpressure", default="block", choices=("block", "shed"),
        help="async-engine backend: slow-consumer policy (block the engine "
        "or shed the laggard's request)",
    )
    ap.add_argument(
        "--arrival-scale", type=float, default=0.01,
        help="engine backend: arrivals are multiplied by this (engine virtual "
        "seconds per trace second; 0.01 compresses the trace 100x)",
    )
    ap.add_argument(
        "--replicas", type=int, default=2,
        help="router backend: AsyncServeSession replica count",
    )
    ap.add_argument(
        "--router", default="least-queued", choices=available_router_policies(),
        help="router backend: routing policy from the repro.policies registry",
    )
    ap.add_argument(
        "--pools", default="2:2", type=parse_pools, metavar="P:D",
        help="disagg backend: prefill:decode pool sizes (e.g. 2:2)",
    )
    ap.add_argument(
        "--deflect", default="never", choices=available_deflection_policies(),
        help="disagg backend: prefill-deflection policy from the registry",
    )
    ap.add_argument(
        "--kill", action="append", default=None, metavar="T:IDX",
        help="churn backend: kill replica IDX at fleet virtual time T "
        "(repeatable; in-flight requests restore onto survivors)",
    )
    ap.add_argument(
        "--autoscaler", default="static", choices=available_autoscaler_policies(),
        help="churn backend: autoscaler policy from the repro.policies registry",
    )
    ap.add_argument(
        "--autoscale-interval", type=float, default=0.05,
        help="churn backend: autoscaler evaluation period in fleet virtual "
        "seconds (also the windowed-SLO bucket width when --slo-window is "
        "not given)",
    )
    ap.add_argument(
        "--min-replicas", type=int, default=1,
        help="churn backend: autoscaler floor on live replicas",
    )
    ap.add_argument(
        "--max-replicas", type=int, default=6,
        help="churn backend: autoscaler ceiling on live replicas",
    )
    ap.add_argument(
        "--page-size", type=int, default=0,
        help="engine-family backends: tokens per KV page; >0 switches the "
        "decode engines from contiguous slot KV to refcounted pages with "
        "radix prefix reuse (DESIGN.md §kvcache); 0 keeps the slot substrate",
    )
    ap.add_argument(
        "--cache-pages", type=int, default=0,
        help="with --page-size: total pages in the KV pool (0 = the "
        "slot-equivalent max_slots * max_len / page_size)",
    )
    ap.add_argument(
        "--transfer-bw", type=float, default=900e9,
        help="KV handoff bandwidth in bytes/sec (engine admission + disagg "
        "cross-server transfers, priced via CostModel.transfer_time)",
    )
    ap.add_argument(
        "--transfer-lat", type=float, default=0.002,
        help="KV handoff fixed latency in virtual seconds",
    )
    ap.add_argument("--out", default=None, help="write the JSON report here (default stdout)")
    ap.add_argument("--list-scenarios", action="store_true")
    ap.add_argument("--list-policies", action="store_true")
    return ap


def main(argv: Optional[List[str]] = None) -> dict:
    ap = build_parser()
    args = ap.parse_args(argv)

    if args.list_scenarios:
        print("scenarios:", ", ".join(available_scenarios()))
        return {}
    if args.list_policies:
        for side, names in available_policies().items():
            print(f"{side}: {', '.join(names)}")
        return {}

    scenario_kwargs = {}
    if "replay" in args.scenario:
        if args.replay_trace is None:
            ap.error('the "replay" scenario requires --replay-trace <file.jsonl>')
        scenario_kwargs["replay"] = {"path": args.replay_trace}

    hcfg = HarnessConfig(
        n_requests=args.n,
        seed=args.seed,
        queue_depth=args.queue_depth or None,
        tenant_quota=args.tenant_quota or None,
        engine_arrival_scale=args.arrival_scale,
        async_clients=args.clients,
        stream_buffer=args.stream_buffer,
        backpressure=args.backpressure,
        router_replicas=args.replicas,
        router_policy=args.router,
        disagg_prefill=args.pools[0],
        disagg_decode=args.pools[1],
        deflect_policy=args.deflect,
        churn_kills=parse_kills(args.kill or ()),
        autoscaler_policy=args.autoscaler,
        autoscale_interval=args.autoscale_interval,
        fleet_min_replicas=args.min_replicas,
        fleet_max_replicas=args.max_replicas,
        transfer_bw=args.transfer_bw,
        transfer_lat=args.transfer_lat,
        page_size=args.page_size or None,
        cache_pages=args.cache_pages or None,
        trace=args.trace,
        slo_window=args.slo_window,
    )
    report = run_grid(
        scenarios=args.scenario,
        prefills=args.prefill,
        decodes=args.decode,
        backends=args.backend,
        hcfg=hcfg,
        scenario_kwargs=scenario_kwargs,
    )
    text = json.dumps(report, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        ncells = len(report["cells"])
        print(f"wrote {ncells} cells to {args.out}", file=sys.stderr)
    else:
        print(text)
    return report


if __name__ == "__main__":
    main()
