"""Open-loop async load generator: live clients against the serving engine.

    PYTHONPATH=src python -m repro.launch.loadgen \
        --scenario bursty --clients 8 \
        --prefill kairos-urgency --decode kairos-slack

Replays any registered `repro.workloads` scenario against a live
`DisaggServer` through the `AsyncServeSession` frontend: every request is
submitted at its arrival time regardless of how the previous ones are doing
(open loop — the load does not back off when the server struggles), and the
resulting token streams are drained by ``--clients`` concurrent consumer
tasks. This is the online counterpart of ``launch/evaluate.py``'s replayed
backends, and it emits the *same* JSON report schema (one ``async-engine``
cell inside the usual grid envelope), so the PR 3 analysis/plotting
tooling consumes loadgen output unchanged. The cell carries one extra
``loadgen`` block: per-client token counts, the backpressure policy, and
whether the run used the wall clock.

By default the run is driven on a deterministic `ManualClock` (virtual
time, reproducible, fast); ``--realtime`` switches to the wall clock for a
true online measurement where consumer latency and engine step time
genuinely overlap.

``--servers N --router <policy>`` serves through a `RouterSession` fleet
instead of a single frontend: N replica engines, placement by a registered
routing policy (round-robin / least-queued / slack-aware / prefix-affinity),
and a ``router`` block in the cell with per-replica request counts and
prefix-cache hit rates.

``--pools P:D`` serves through a disaggregated `DisaggFleetSession` instead:
P prefill + D decode servers on one shared clock, cross-pool KV handoff
priced by the calibrated cost model, prefill deflection by ``--deflect``,
and the same ``disagg`` cell block ``launch/evaluate.py`` emits (handoff and
deflection records, per-pool attainment).
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.policies import (
    available_deflection_policies,
    available_policies,
    available_router_policies,
)
from repro.obs import TraceRecorder, trace_cell_block, write_trace
from repro.workloads.harness import (
    HarnessConfig,
    _cell_report,
    _EngineBundle,
    _engine_setup,
    _trace_path,
    disagg_cell_block,
    kv_cell_block,
    parse_pools,
    router_cell_block,
)
from repro.workloads.scenarios import available_scenarios, make_scenario


def run_loadgen(
    scenario: str,
    prefill: str,
    decode: str,
    hcfg: HarnessConfig,
    realtime: bool = False,
    scenario_kwargs: Optional[Dict] = None,
    servers: int = 1,
    router: Optional[str] = None,
    pools: Optional[Tuple[int, int]] = None,
) -> Dict:
    """One open-loop cell wrapped in the evaluate.py schema: a single
    ``async-engine`` frontend by default, a routed fleet (`RouterSession`,
    per-replica ``router`` block) with ``servers > 1`` or an explicit
    ``router`` policy, or a disaggregated P:D fleet (`DisaggFleetSession`,
    ``disagg`` block) with ``pools``."""
    from repro.serving.clock import MonotonicClock
    from repro.serving.disagg import DisaggFleetSession
    from repro.serving.frontend import AsyncServeSession
    from repro.serving.router import RouterSession

    routed = servers > 1 or router is not None
    disagg = pools is not None
    if routed and disagg:
        raise ValueError("--pools (disagg) and --servers/--router are exclusive")
    if routed:
        hcfg = dataclasses.replace(
            hcfg,
            router_replicas=max(1, servers),
            router_policy=router or hcfg.router_policy,
        )
    if disagg:
        hcfg = dataclasses.replace(
            hcfg, disagg_prefill=pools[0], disagg_decode=pools[1]
        )
    kwargs = dict(scenario_kwargs or {})
    if hcfg.n_requests is not None:
        kwargs.setdefault("n_requests", hcfg.n_requests)
    reqs = make_scenario(scenario, **kwargs).generate(hcfg.seed)
    n_servers = 1
    if routed:
        n_servers = hcfg.router_replicas
    elif disagg:
        n_servers = hcfg.disagg_prefill + hcfg.disagg_decode
    fleet, pairs = _engine_setup(
        reqs, prefill, decode, hcfg, _EngineBundle(hcfg.engine_arch),
        n_servers=n_servers, shared_clock=disagg,
    )
    if realtime:
        # the disagg fleet must keep sharing ONE clock instance even on the
        # wall clock — per-server clocks fail _FleetClock's validation
        wall_clock = MonotonicClock()
        for srv in fleet:
            srv.clock = wall_clock if disagg else MonotonicClock()
    clients = max(1, hcfg.async_clients)
    # same contract as the harness: None keeps every emission site on its
    # fast path; "" records in memory without writing a file
    recorder = TraceRecorder() if hcfg.trace is not None else None

    async def _serve():
        # the open-loop drive is (Async|Router|DisaggFleet)Session.replay —
        # the same code paths as the harness's engine backends — with a
        # hook for the per-client accounting this report adds
        counts = [0] * clients
        on_tok = lambda c, _tok: counts.__setitem__(c, counts[c] + 1)
        if routed:
            session = RouterSession(
                fleet,
                policy=hcfg.router_policy,
                stream_buffer=hcfg.stream_buffer,
                backpressure=hcfg.backpressure,
                prefix_block=hcfg.prefix_block,
                prefix_cache_blocks=hcfg.prefix_cache_blocks,
                trace=recorder,
            )
        elif disagg:
            session = DisaggFleetSession(
                fleet[: hcfg.disagg_prefill],
                fleet[hcfg.disagg_prefill :],
                deflection=hcfg.deflect_policy,
                stream_buffer=hcfg.stream_buffer,
                backpressure=hcfg.backpressure,
                max_inflight_transfers=hcfg.max_inflight_transfers,
                trace=recorder,
            )
        else:
            session = AsyncServeSession(
                fleet[0],
                stream_buffer=hcfg.stream_buffer,
                backpressure=hcfg.backpressure,
                trace=recorder,
            )
        async with session:
            await session.replay(pairs, clients=clients, on_client_token=on_tok)
        return counts, session

    t0 = time.perf_counter()
    tokens_by_client, session = asyncio.run(_serve())
    wall = time.perf_counter() - t0

    backend = "router" if routed else ("disagg" if disagg else "async-engine")
    cell = dict(
        scenario=scenario,
        prefill=prefill,
        decode=decode,
        backend=backend,
        wall_time_s=wall,
    )
    cell.update(_cell_report([r for r, _ in pairs]))
    cell["loadgen"] = dict(
        clients=clients,
        realtime=realtime,
        tokens_by_client=tokens_by_client,
        backpressure=hcfg.backpressure,
        stream_buffer=hcfg.stream_buffer,
    )
    if hcfg.page_size is not None:
        cell["variant"] = "paged"
    kv_block = kv_cell_block(session.summary())
    if kv_block is not None:
        cell["kv"] = kv_block
    if routed:
        cell["router"] = router_cell_block(session.summary())
    if disagg:
        cell["disagg"] = disagg_cell_block(session.core, [r for r, _ in pairs])
    if recorder is not None:
        trace_block = trace_cell_block(recorder.events, slo_window=hcfg.slo_window)
        if hcfg.trace:
            path = _trace_path(hcfg.trace, scenario, prefill, decode, backend)
            trace_block["path"] = path
            trace_block["format"] = write_trace(recorder.events, path)
        cell["trace"] = trace_block
    return dict(
        grid=dict(
            scenarios=[scenario],
            prefills=[prefill],
            decodes=[decode],
            backends=[backend],
        ),
        config=hcfg.as_dict(),
        cells=[cell],
    )


def build_parser() -> argparse.ArgumentParser:
    pol = available_policies()
    ap = argparse.ArgumentParser(
        description="Open-loop async load generator over the live engine "
        "(AsyncServeSession frontend)."
    )
    ap.add_argument(
        "--scenario", default="paper-longtail", choices=available_scenarios(),
        help="workload scenario from the repro.workloads registry",
    )
    ap.add_argument("--prefill", default="kairos-urgency", choices=pol["prefill"])
    ap.add_argument("--decode", default="kairos-slack", choices=pol["decode"])
    ap.add_argument(
        "--servers", type=int, default=1,
        help="replica count: >1 serves through a RouterSession fleet",
    )
    ap.add_argument(
        "--router", default=None, choices=available_router_policies(),
        help="routing policy (implies the routed path even with --servers 1)",
    )
    ap.add_argument(
        "--pools", default=None, type=parse_pools, metavar="P:D",
        help="serve through a disaggregated prefill:decode fleet "
        "(DisaggFleetSession) instead of a single frontend",
    )
    ap.add_argument(
        "--deflect", default="never", choices=available_deflection_policies(),
        help="disagg fleet: prefill-deflection policy from the registry",
    )
    ap.add_argument(
        "--page-size", type=int, default=0,
        help="tokens per KV page; >0 switches the decode engines to paged "
        "KV with radix prefix reuse (DESIGN.md §kvcache); 0 = slot KV",
    )
    ap.add_argument(
        "--cache-pages", type=int, default=0,
        help="with --page-size: total pages in the KV pool (0 = the "
        "slot-equivalent max_slots * max_len / page_size)",
    )
    ap.add_argument(
        "--transfer-bw", type=float, default=900e9,
        help="KV handoff bandwidth in bytes/sec (priced via CostModel.transfer_time)",
    )
    ap.add_argument(
        "--transfer-lat", type=float, default=0.002,
        help="KV handoff fixed latency in virtual seconds",
    )
    ap.add_argument("--clients", type=int, default=4, help="concurrent consumer tasks")
    ap.add_argument("--n", type=int, default=64, help="requests in the scenario")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--queue-depth", type=int, default=0,
        help="global admission queue depth; 0 = unbounded",
    )
    ap.add_argument(
        "--tenant-quota", type=int, default=0,
        help="per-tenant queued-request quota; 0 = no quota",
    )
    ap.add_argument(
        "--arrival-scale", type=float, default=0.01,
        help="arrivals are multiplied by this (virtual seconds per trace second)",
    )
    ap.add_argument(
        "--stream-buffer", type=int, default=16,
        help="per-request token buffer before backpressure applies",
    )
    ap.add_argument(
        "--backpressure", default="block", choices=("block", "shed"),
        help="slow-consumer policy: stall the engine, or cancel the laggard",
    )
    ap.add_argument(
        "--realtime", action="store_true",
        help="drive the engine on the wall clock instead of virtual time",
    )
    ap.add_argument(
        "--replay-trace", default=None,
        help='JSONL request-trace file for the "replay" scenario (input)',
    )
    ap.add_argument(
        "--trace", default=None, metavar="PATH",
        help="write an event trace of the run (repro.obs): .jsonl = raw "
        "event log, anything else = Chrome trace-event / Perfetto JSON; "
        'the cell gains a "trace" summary block',
    )
    ap.add_argument(
        "--slo-window", type=float, default=None, metavar="SECONDS",
        help="with --trace: windowed SLO telemetry bucket width in virtual "
        "(or, with --realtime, wall) seconds",
    )
    ap.add_argument("--out", default=None, help="write the JSON report here (default stdout)")
    return ap


def main(argv: Optional[List[str]] = None) -> dict:
    ap = build_parser()
    args = ap.parse_args(argv)
    scenario_kwargs = None
    if args.scenario == "replay":
        if args.replay_trace is None:
            ap.error('the "replay" scenario requires --replay-trace <file.jsonl>')
        scenario_kwargs = {"path": args.replay_trace}

    if args.pools is not None and (args.servers > 1 or args.router is not None):
        ap.error("--pools (disagg) and --servers/--router are mutually exclusive")

    hcfg = HarnessConfig(
        n_requests=args.n,
        seed=args.seed,
        queue_depth=args.queue_depth or None,
        tenant_quota=args.tenant_quota or None,
        engine_arrival_scale=args.arrival_scale,
        async_clients=args.clients,
        stream_buffer=args.stream_buffer,
        backpressure=args.backpressure,
        deflect_policy=args.deflect,
        transfer_bw=args.transfer_bw,
        transfer_lat=args.transfer_lat,
        page_size=args.page_size or None,
        cache_pages=args.cache_pages or None,
        trace=args.trace,
        slo_window=args.slo_window,
    )
    report = run_loadgen(
        args.scenario, args.prefill, args.decode, hcfg,
        realtime=args.realtime, scenario_kwargs=scenario_kwargs,
        servers=args.servers, router=args.router, pools=args.pools,
    )
    text = json.dumps(report, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        cell = report["cells"][0]
        print(
            f"loadgen: {cell['n_completed']}/{cell['n_requests']} completed, "
            f"{sum(cell['loadgen']['tokens_by_client'])} tokens streamed by "
            f"{cell['loadgen']['clients']} clients -> {args.out}",
            file=sys.stderr,
        )
    else:
        print(text)
    return report


if __name__ == "__main__":
    main()
