"""Open-loop async load generator: live clients against the serving engine.

    PYTHONPATH=src python -m repro.launch.loadgen \
        --scenario bursty --clients 8 \
        --prefill kairos-urgency --decode kairos-slack

Replays any registered `repro.workloads` scenario against a live
`DisaggServer` through the `AsyncServeSession` frontend: every request is
submitted at its arrival time regardless of how the previous ones are doing
(open loop — the load does not back off when the server struggles), and the
resulting token streams are drained by ``--clients`` concurrent consumer
tasks. This is the online counterpart of ``launch/evaluate.py``'s replayed
backends, and it emits the *same* JSON report schema (one ``async-engine``
cell inside the usual grid envelope), so the PR 3 analysis/plotting
tooling consumes loadgen output unchanged. The cell carries one extra
``loadgen`` block: per-client token counts, the backpressure policy, and
whether the run used the wall clock.

By default the run is driven on a deterministic `ManualClock` (virtual
time, reproducible, fast); ``--realtime`` switches to the wall clock for a
true online measurement where consumer latency and engine step time
genuinely overlap.

``--servers N --router <policy>`` serves through a `RouterSession` fleet
instead of a single frontend: N replica engines, placement by a registered
routing policy (round-robin / least-queued / slack-aware / prefix-affinity),
and a ``router`` block in the cell with per-replica request counts and
prefix-cache hit rates.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import sys
import time
from typing import Dict, List, Optional

from repro.policies import available_policies, available_router_policies
from repro.workloads.harness import (
    HarnessConfig,
    _cell_report,
    _EngineBundle,
    _engine_setup,
    router_cell_block,
)
from repro.workloads.scenarios import available_scenarios, make_scenario


def run_loadgen(
    scenario: str,
    prefill: str,
    decode: str,
    hcfg: HarnessConfig,
    realtime: bool = False,
    scenario_kwargs: Optional[Dict] = None,
    servers: int = 1,
    router: Optional[str] = None,
) -> Dict:
    """One open-loop cell wrapped in the evaluate.py schema: a single
    ``async-engine`` frontend by default, or — with ``servers > 1`` or an
    explicit ``router`` policy — a routed fleet (`RouterSession`) whose
    cell adds the per-replica ``router`` block."""
    from repro.serving.clock import MonotonicClock
    from repro.serving.frontend import AsyncServeSession
    from repro.serving.router import RouterSession

    routed = servers > 1 or router is not None
    if routed:
        hcfg = dataclasses.replace(
            hcfg,
            router_replicas=max(1, servers),
            router_policy=router or hcfg.router_policy,
        )
    kwargs = dict(scenario_kwargs or {})
    if hcfg.n_requests is not None:
        kwargs.setdefault("n_requests", hcfg.n_requests)
    reqs = make_scenario(scenario, **kwargs).generate(hcfg.seed)
    fleet, pairs = _engine_setup(
        reqs, prefill, decode, hcfg, _EngineBundle(hcfg.engine_arch),
        n_servers=hcfg.router_replicas if routed else 1,
    )
    if realtime:
        for srv in fleet:
            srv.clock = MonotonicClock()
    clients = max(1, hcfg.async_clients)

    async def _serve():
        # the open-loop drive is (Async|Router)Session.replay — the same
        # code paths as the harness's async-engine/router backends — with a
        # hook for the per-client accounting this report adds
        counts = [0] * clients
        on_tok = lambda c, _tok: counts.__setitem__(c, counts[c] + 1)
        if routed:
            session = RouterSession(
                fleet,
                policy=hcfg.router_policy,
                stream_buffer=hcfg.stream_buffer,
                backpressure=hcfg.backpressure,
                prefix_block=hcfg.prefix_block,
                prefix_cache_blocks=hcfg.prefix_cache_blocks,
            )
        else:
            session = AsyncServeSession(
                fleet[0],
                stream_buffer=hcfg.stream_buffer,
                backpressure=hcfg.backpressure,
            )
        async with session:
            await session.replay(pairs, clients=clients, on_client_token=on_tok)
        return counts, session

    t0 = time.perf_counter()
    tokens_by_client, session = asyncio.run(_serve())
    wall = time.perf_counter() - t0

    backend = "router" if routed else "async-engine"
    cell = dict(
        scenario=scenario,
        prefill=prefill,
        decode=decode,
        backend=backend,
        wall_time_s=wall,
    )
    cell.update(_cell_report([r for r, _ in pairs]))
    cell["loadgen"] = dict(
        clients=clients,
        realtime=realtime,
        tokens_by_client=tokens_by_client,
        backpressure=hcfg.backpressure,
        stream_buffer=hcfg.stream_buffer,
    )
    if routed:
        cell["router"] = router_cell_block(session.summary())
    return dict(
        grid=dict(
            scenarios=[scenario],
            prefills=[prefill],
            decodes=[decode],
            backends=[backend],
        ),
        config=hcfg.as_dict(),
        cells=[cell],
    )


def build_parser() -> argparse.ArgumentParser:
    pol = available_policies()
    ap = argparse.ArgumentParser(
        description="Open-loop async load generator over the live engine "
        "(AsyncServeSession frontend)."
    )
    ap.add_argument(
        "--scenario", default="paper-longtail", choices=available_scenarios(),
        help="workload scenario from the repro.workloads registry",
    )
    ap.add_argument("--prefill", default="kairos-urgency", choices=pol["prefill"])
    ap.add_argument("--decode", default="kairos-slack", choices=pol["decode"])
    ap.add_argument(
        "--servers", type=int, default=1,
        help="replica count: >1 serves through a RouterSession fleet",
    )
    ap.add_argument(
        "--router", default=None, choices=available_router_policies(),
        help="routing policy (implies the routed path even with --servers 1)",
    )
    ap.add_argument("--clients", type=int, default=4, help="concurrent consumer tasks")
    ap.add_argument("--n", type=int, default=64, help="requests in the scenario")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--queue-depth", type=int, default=0,
        help="global admission queue depth; 0 = unbounded",
    )
    ap.add_argument(
        "--tenant-quota", type=int, default=0,
        help="per-tenant queued-request quota; 0 = no quota",
    )
    ap.add_argument(
        "--arrival-scale", type=float, default=0.01,
        help="arrivals are multiplied by this (virtual seconds per trace second)",
    )
    ap.add_argument(
        "--stream-buffer", type=int, default=16,
        help="per-request token buffer before backpressure applies",
    )
    ap.add_argument(
        "--backpressure", default="block", choices=("block", "shed"),
        help="slow-consumer policy: stall the engine, or cancel the laggard",
    )
    ap.add_argument(
        "--realtime", action="store_true",
        help="drive the engine on the wall clock instead of virtual time",
    )
    ap.add_argument(
        "--trace", default=None, help='JSONL trace file for the "replay" scenario'
    )
    ap.add_argument("--out", default=None, help="write the JSON report here (default stdout)")
    return ap


def main(argv: Optional[List[str]] = None) -> dict:
    ap = build_parser()
    args = ap.parse_args(argv)
    scenario_kwargs = None
    if args.scenario == "replay":
        if args.trace is None:
            ap.error('the "replay" scenario requires --trace <file.jsonl>')
        scenario_kwargs = {"path": args.trace}

    hcfg = HarnessConfig(
        n_requests=args.n,
        seed=args.seed,
        queue_depth=args.queue_depth or None,
        tenant_quota=args.tenant_quota or None,
        engine_arrival_scale=args.arrival_scale,
        async_clients=args.clients,
        stream_buffer=args.stream_buffer,
        backpressure=args.backpressure,
    )
    report = run_loadgen(
        args.scenario, args.prefill, args.decode, hcfg,
        realtime=args.realtime, scenario_kwargs=scenario_kwargs,
        servers=args.servers, router=args.router,
    )
    text = json.dumps(report, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        cell = report["cells"][0]
        print(
            f"loadgen: {cell['n_completed']}/{cell['n_requests']} completed, "
            f"{sum(cell['loadgen']['tokens_by_client'])} tokens streamed by "
            f"{cell['loadgen']['clients']} clients -> {args.out}",
            file=sys.stderr,
        )
    else:
        print(text)
    return report


if __name__ == "__main__":
    main()
