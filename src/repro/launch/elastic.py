"""Elastic re-mesh dry-run: prove the framework recompiles onto a degraded
device count (node failures at scale) without code changes.

Simulates losing one 'data' row of the single-pod mesh (16x16 -> 15x16 is
not expressible for every dim, so production policy shrinks to the largest
divisible rectangle: 8x16) and re-lowers the serve step with the same
sharding rules — the divisibility fallback machinery re-resolves every dim.

    PYTHONPATH=src python -m repro.launch.elastic --arch llama3-8b
"""
import argparse
import json
import time

import jax

from repro.dist.act_sharding import use_activation_sharding
from repro.dist.fault import FleetState, plan_recovery
from repro.launch import dryrun
from repro.launch.mesh import ensure_host_platform_devices, make_mesh


def check(arch: str, shape: str, mesh_shape, axes) -> dict:
    mesh = make_mesh(mesh_shape, axes)
    t0 = time.time()
    fn, args, shardings, donate, meta = dryrun.build_cell(arch, shape, mesh, "serve" if "decode" in shape else "train")
    with use_activation_sharding(mesh, meta["plan"].batch_axes):
        compiled = (
            jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
            .lower(*args)
            .compile()
        )
    return dict(
        mesh=str(mesh_shape),
        chips=mesh.size,
        compile_s=round(time.time() - t0, 2),
        temp_gb=round(compiled.memory_analysis().temp_size_in_bytes / 1e9, 2),
        fallbacks=meta["plan"].fallbacks,
    )


def main() -> None:
    ensure_host_platform_devices()  # before any jax device query initializes the backend
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument(
        "--plan-fleet",
        default=None,
        help="comma-separated healthy chips per pod (e.g. '256,200'): print "
        "the dist.fault recovery narrative, then re-lower onto the planned "
        "per-pod data x model rectangle",
    )
    args = ap.parse_args()

    if args.plan_fleet:
        fleet = FleetState(pods=tuple(int(x) for x in args.plan_fleet.split(",")))
        rec = plan_recovery(fleet)
        for line in rec.describe():
            print(line)
        shape = rec.mesh.shape[-2:]  # per-pod data x model rectangle
        res = check(args.arch, args.shape, shape, ("data", "model"))
        print("planned", json.dumps(res))
        print("elastic re-mesh from fault plan: OK")
        return

    results = {}
    for name, mesh_shape in [
        ("healthy_256", (16, 16)),
        ("degraded_128", (8, 16)),  # lost half the data rows
        ("degraded_64", (4, 16)),
    ]:
        results[name] = check(args.arch, args.shape, mesh_shape, ("data", "model"))
        print(name, json.dumps(results[name]))
    print("elastic re-mesh: OK — same code, three device counts")


if __name__ == "__main__":
    main()
