"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b-smoke \
        --steps 100 --batch 8 --seq 256 [--ckpt-dir DIR] [--resume]

On the CPU container this trains reduced configs; the same code path drives
full configs on TPU (shardings from dist/, mesh from launch/mesh.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, SyntheticDataset
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b-smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={cfg.count_params():,}")

    opt_cfg = OptimizerConfig(
        lr=args.lr,
        warmup_steps=max(2, args.steps // 10),
        stable_steps=args.steps,
        decay_steps=max(1, args.steps // 10),
    )
    ds = SyntheticDataset(cfg, DataConfig(seq_len=args.seq, global_batch=args.batch))
    step_fn = jax.jit(make_train_step(model, opt_cfg, n_micro=args.n_micro))

    ck = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    params = model.init(jax.random.key(0))
    opt = init_opt_state(params)
    start = 0
    if ck is not None and ck.latest_step() is not None:
        restored, start = ck.restore({"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"resumed at step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, ds.batch_at(step))
        params, opt, m = step_fn(params, opt, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(
                f"step {step:5d} loss={float(m['loss']):.4f} lr={float(m['lr']):.2e} "
                f"gnorm={float(m['grad_norm']):.2f} {(time.time()-t0):.0f}s",
                flush=True,
            )
        if ck is not None and step and step % args.ckpt_every == 0:
            ck.save(step, {"params": params, "opt": opt}, async_=True)
    if ck is not None:
        ck.save(args.steps, {"params": params, "opt": opt})


if __name__ == "__main__":
    main()
