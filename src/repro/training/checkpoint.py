"""Checkpointing: sharded pytree snapshots with atomic manifests.

Fault-tolerance contract:
  * save() writes leaves to <dir>/step_N.tmp/ then atomically renames to
    <dir>/step_N/ and updates LATEST only after a complete write — a killed
    writer can never produce a half-checkpoint that restore() would load.
  * async mode runs the serialization on a background thread (training
    continues); join() blocks until durable.
  * restore() returns (pytree, step) from the newest complete checkpoint.

Leaves are stored as .npy files keyed by their pytree path, dtype-preserved
(bf16 round-trips via a uint16 view).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BF16_TAG = "bfloat16"


def _path_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


def _save_leaf(d: str, key: str, arr) -> Dict[str, Any]:
    a = np.asarray(arr)
    meta = {"dtype": str(arr.dtype), "shape": list(a.shape)}
    if str(arr.dtype) == _BF16_TAG:
        a = np.asarray(jax.device_get(arr)).view(np.uint16)
        meta["stored"] = "uint16"
    np.save(os.path.join(d, key + ".npy"), a, allow_pickle=False)
    return meta


def _load_leaf(d: str, key: str, meta: Dict[str, Any]):
    a = np.load(os.path.join(d, key + ".npy"), allow_pickle=False)
    if meta.get("stored") == "uint16":
        a = a.view(jnp.bfloat16)
    return jnp.asarray(a)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, async_: bool = False) -> None:
        # materialize on host first (cheap for CPU; device_get for TPU)
        flat = jax.tree_util.tree_flatten_with_path(tree)
        host = [(p, jax.device_get(v)) for p, v in flat[0]]
        treedef = flat[1]

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": {}}
            for path, val in host:
                key = _path_key(path)
                manifest["leaves"][key] = _save_leaf(tmp, key, val)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
                f.write(str(step))
            os.replace(os.path.join(self.dir, "LATEST.tmp"), os.path.join(self.dir, "LATEST"))
            self._gc()

        if async_:
            self.join()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def join(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        self.join()
        latest = os.path.join(self.dir, "LATEST")
        if os.path.exists(latest):
            with open(latest) as f:
                s = int(f.read().strip())
            if os.path.exists(os.path.join(self.dir, f"step_{s}", "manifest.json")):
                return s
        # fall back to scanning complete checkpoints
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None) -> Tuple[Any, int]:
        """Restore into the structure of `like` (a pytree of arrays/structs)."""
        self.join()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, _ in flat:
            key = _path_key(path)
            leaves.append(_load_leaf(d, key, manifest["leaves"][key]))
        return jax.tree_util.tree_unflatten(treedef, leaves), step

    # -------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                steps.append(int(m.group(1)))
        for s in sorted(steps)[: -self.keep] if len(steps) > self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)
