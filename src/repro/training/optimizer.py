"""Optimizer: AdamW with WSD (warmup-stable-decay) schedule (MiniCPM-style).

No optax dependency: states are explicit pytrees so the sharding rules can
annotate them (fp32 m/v sharded like their parameters).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # WSD schedule
    warmup_steps: int = 100
    stable_steps: int = 1000
    decay_steps: int = 100
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array  # i32 scalar
    m: Any  # pytree like params (f32)
    v: Any  # pytree like params (f32)


def wsd_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Warmup -> stable -> (cosine-free) linear decay to min_lr_frac."""
    s = step.astype(jnp.float32)
    warm = cfg.lr * (s + 1.0) / max(1, cfg.warmup_steps)
    stable = jnp.float32(cfg.lr)
    t = (s - cfg.warmup_steps - cfg.stable_steps) / max(1, cfg.decay_steps)
    t = jnp.clip(t, 0.0, 1.0)
    decay = cfg.lr * (1.0 - (1.0 - cfg.min_lr_frac) * t)
    lr = jnp.where(s < cfg.warmup_steps, warm, jnp.where(t > 0, decay, stable))
    return lr


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    cfg: OptimizerConfig, params, grads, state: OptState
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step with grad clipping + WSD LR. Returns (params', state', metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = wsd_schedule(cfg, state.step)
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v, strict=True)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = dict(grad_norm=gnorm, lr=lr)
    return new_p, OptState(step=step, m=new_m, v=new_v), metrics
