"""Training step with gradient accumulation (microbatching).

The assigned train_4k shape is (global_batch=256, seq=4096); materializing
logits over a 128K-entry vocab for the full batch is infeasible, so the
step scans over microbatches accumulating grads — exactly how production
frameworks run this shape. Microbatch count is static per compile.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.training.optimizer import OptimizerConfig, OptState, adamw_update


def _split_batch(batch: Dict, n_micro: int) -> Dict:
    """(B, ...) -> (n_micro, B/n_micro, ...) for every leaf."""

    def rs(x):
        b = x.shape[0]
        assert b % n_micro == 0, f"batch {b} % microbatches {n_micro} != 0"
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    return jax.tree.map(rs, batch)


def _constrain(tree, shardings):
    if shardings is None:
        return tree
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s) if s is not None else x,
        tree,
        shardings,
    )


def loss_and_grad_accum(
    model: Model,
    params: Dict,
    batch: Dict,
    n_micro: int,
    grad_shardings=None,
    micro_shardings=None,
) -> Tuple[jax.Array, Dict]:
    """Mean loss + grads accumulated over microbatches via lax.scan.

    Two sharding constraints matter at scale (both measured at the 256-chip
    production mesh on an 8B model):
      * `grad_shardings` pins the scan-carry grad accumulator — otherwise
        GSPMD replicates the full f32 grad tree (~4 B/param/device).
      * `micro_shardings` pins each scanned microbatch — the (B,) ->
        (n_micro, B/n_micro) reshape silently drops the batch sharding, and
        every activation downstream (attention scores included) replicates.
    """
    if n_micro <= 1:
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        return loss, _constrain(grads, grad_shardings)

    micro = _split_batch(batch, n_micro)

    def body(carry, mb):
        loss_acc, grad_acc = carry
        mb = _constrain(mb, micro_shardings)
        loss, grads = jax.value_and_grad(model.loss)(params, mb)
        grad_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / n_micro, grad_acc, grads
        )
        grad_acc = _constrain(grad_acc, grad_shardings)
        return (loss_acc + loss / n_micro, grad_acc), None

    zero_grads = _constrain(
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        grad_shardings,
    )
    (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero_grads), micro)
    return loss, grads


def make_train_step(
    model: Model,
    opt_cfg: OptimizerConfig,
    n_micro: int = 1,
    grad_shardings=None,
    micro_shardings=None,
):
    """Returns train_step(params, opt_state, batch) -> (params', opt_state', metrics)."""

    def train_step(params: Dict, opt_state: OptState, batch: Dict):
        loss, grads = loss_and_grad_accum(
            model, params, batch, n_micro, grad_shardings, micro_shardings
        )
        params2, opt2, metrics = adamw_update(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params2, opt2, metrics

    return train_step


def default_microbatches(cfg: ModelConfig, global_batch: int) -> int:
    """Pick a microbatch count so per-microbatch logits stay ~<=64 MB/device
    at the production mesh (heuristic; overridable via TrainConfig)."""
    if global_batch >= 256:
        return 8
    if global_batch >= 64:
        return 4
    return 1
