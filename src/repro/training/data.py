"""Synthetic LM data pipeline: deterministic, shardable, restart-safe.

Batches are a pure function of (seed, step), so a restarted trainer resumes
from the checkpointed step with bit-identical data — the property the
checkpoint tests assert.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    seed: int = 1234
    # synthetic structure: token n+1 depends on token n (learnable signal)
    vocab_cap: int = 0  # 0 => cfg.vocab_size


class SyntheticDataset:
    """Markov-ish synthetic tokens: learnable but trivial to generate."""

    def __init__(self, cfg: ModelConfig, data_cfg: DataConfig):
        self.cfg = cfg
        self.dcfg = data_cfg
        self.vocab = data_cfg.vocab_cap or cfg.vocab_size

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        d = self.dcfg
        rng = np.random.default_rng((d.seed, step))
        b, s = d.global_batch, d.seq_len
        starts = rng.integers(0, self.vocab, size=(b, 1))
        deltas = rng.integers(1, 7, size=(b, s))
        toks = (starts + np.cumsum(deltas, axis=1)) % self.vocab
        toks = toks.astype(np.int32)
        inputs = toks[:, :-1] if s > 1 else toks
        labels = toks[:, 1:] if s > 1 else toks
        # keep shapes (b, s): pad one position with ignore-label -100
        inputs = np.concatenate([inputs, inputs[:, -1:]], axis=1)
        labels = np.concatenate([labels, np.full((b, 1), -100, np.int32)], axis=1)
        if self.cfg.is_encdec:
            half = s // 2
            return dict(
                src=rng.standard_normal((b, half, self.cfg.d_model)).astype(np.float32),
                tgt=inputs[:, :half],
                labels=labels[:, :half],
            )
        if self.cfg.input_mode == "embeddings":
            return dict(
                inputs=rng.standard_normal((b, s, self.cfg.d_model)).astype(np.float32),
                labels=labels,
            )
        return dict(inputs=inputs, labels=labels)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
