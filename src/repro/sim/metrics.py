"""SLO-attainment and throughput metrics (paper §4.1 Metrics).

Attainment semantics: a request shed by admission control (``Phase.FAILED``)
is an SLO *miss*, not a non-event — by default it counts in the denominator
of every attainment fraction (and contributes nothing to the numerator).
``attainment(done_only=True)`` restores the historical completed-only view
for callers that explicitly want conditional attainment.

Client-cancelled requests (``Phase.CANCELLED``) are a third, distinct kind
of terminal request: the *client* withdrew (disconnect, backpressure shed of
a slow consumer), so the server neither met nor missed an SLO for them.
They are excluded from every attainment fraction's numerator AND
denominator, and surfaced separately as ``n_cancelled`` — conflating them
with ``FAILED`` (as a pre-cancellation-aware caller might) would punish a
policy for clients that walked away.

Multi-tenant additions: ``attainment_by`` groups the same metrics per tenant
or per SLO class, and ``goodput`` reports SLO-met generated tokens per
second — the paper-style "useful throughput" a sweep should maximize.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core.request import Phase, Request
from repro.sim.simulator import SimResult


@dataclass(frozen=True)
class Attainment:
    ttft: float  # fraction of requests meeting the TTFT SLO
    tpot: float  # fraction meeting the TPOT SLO (mean inter-token latency)
    e2e: float  # both
    decode_tput_p50: float  # median per-request decode tokens/sec
    decode_tput_mean: float
    n: int  # requests in the denominator (completed + shed unless done_only)
    n_shed: int = 0  # Phase.FAILED requests counted as misses
    n_cancelled: int = 0  # Phase.CANCELLED: client withdrew; not in n

    def as_dict(self) -> Dict[str, float]:
        return dict(
            ttft=self.ttft,
            tpot=self.tpot,
            e2e=self.e2e,
            decode_tput_p50=self.decode_tput_p50,
            decode_tput_mean=self.decode_tput_mean,
            n=self.n,
            n_shed=self.n_shed,
            n_cancelled=self.n_cancelled,
        )


def attainment(requests: Sequence[Request], done_only: bool = False) -> Attainment:
    """SLO attainment over the terminal requests (DONE, plus FAILED unless
    ``done_only``). Shed requests met no SLO: they dilute every fraction.
    Cancelled requests are the client's doing — reported via ``n_cancelled``
    but never in the fractions (see module docstring)."""
    done = [r for r in requests if r.phase == Phase.DONE]
    shed = [] if done_only else [r for r in requests if r.phase == Phase.FAILED]
    n_cancelled = sum(r.phase == Phase.CANCELLED for r in requests)
    n = len(done) + len(shed)
    if n == 0:
        return Attainment(0.0, 0.0, 0.0, 0.0, 0.0, 0, n_cancelled=n_cancelled)
    ttft = sum(r.meets_ttft() for r in done) / n
    tpot = sum(r.meets_tpot() for r in done) / n
    e2e = sum(r.meets_e2e() for r in done) / n
    tputs = [t for t in (r.decode_tput() for r in done) if t is not None]
    p50 = float(np.percentile(tputs, 50)) if tputs else 0.0
    mean = float(np.mean(tputs)) if tputs else 0.0
    return Attainment(ttft, tpot, e2e, p50, mean, n, n_shed=len(shed), n_cancelled=n_cancelled)


def attainment_by(
    requests: Sequence[Request],
    key: Union[str, Callable[[Request], str]] = "tenant",
    done_only: bool = False,
) -> Dict[str, Attainment]:
    """Attainment broken down by a request attribute (``"tenant"``,
    ``"slo_class"``) or an arbitrary key function."""
    keyfn = (lambda r: getattr(r, key)) if isinstance(key, str) else key
    groups: Dict[str, List[Request]] = {}
    for r in requests:
        groups.setdefault(keyfn(r), []).append(r)
    return {k: attainment(groups[k], done_only=done_only) for k in sorted(groups)}


def attainment_by_pool(
    requests: Sequence[Request],
    pools: Mapping[int, str],
    done_only: bool = False,
) -> Dict[str, Attainment]:
    """Attainment broken down by fleet pool label: ``pools`` maps rid ->
    worker label (`repro.serving.disagg.DisaggSession.pool_labels`), so a
    disagg cell can report prefill-pool TTFT vs decode-pool TPOT attainment
    separately. Requests never placed on a worker (shed before placement,
    cancelled pre-prefill for the decode leg) group under ``"unassigned"``."""
    return attainment_by(
        requests, lambda r: pools.get(r.rid, "unassigned"), done_only=done_only
    )


def goodput(requests: Sequence[Request], span: Optional[float] = None) -> float:
    """SLO-met tokens/sec: generated tokens of completed requests that met
    their e2e SLO, over the trace span (first arrival -> last completion
    unless ``span`` is given). Shed and SLO-missing requests contribute 0."""
    good = [r for r in requests if r.phase == Phase.DONE and r.meets_e2e()]
    if not good:
        return 0.0
    if span is None:
        # completions only: a cancelled request's done_time records when the
        # client bailed, which must not stretch the serving span
        ends = [r.done_time for r in requests if r.phase == Phase.DONE]
        span = max(ends) - min(r.arrival for r in requests)
    if span <= 0:
        return 0.0
    return sum(r.n_generated for r in good) / span


def summarize(result: SimResult) -> Dict[str, float]:
    att = attainment(result.requests)
    out = att.as_dict()
    out.update(
        makespan=result.makespan,
        decode_steps=result.decode_steps,
        decode_tokens=result.decode_tokens,
        agg_decode_tput=(
            result.decode_tokens / result.decode_busy if result.decode_busy else 0.0
        ),
        prefill_busy=result.prefill_busy,
        decode_busy=result.decode_busy,
        goodput=goodput(result.requests, span=result.makespan or None),
    )
    done = [r for r in result.requests if r.phase == Phase.DONE]
    if done:
        out["ttft_p50"] = float(np.percentile([r.ttft() for r in done], 50))
        out["ttft_p99"] = float(np.percentile([r.ttft() for r in done], 99))
        tpots = [r.mean_tpot() for r in done if r.mean_tpot() is not None]
        out["tpot_p50"] = float(np.percentile(tpots, 50)) if tpots else 0.0
        out["tpot_p99"] = float(np.percentile(tpots, 99)) if tpots else 0.0
    return out


def compare(kairos: SimResult, baseline: SimResult) -> Dict[str, float]:
    """Headline deltas, paper-style (percentage points / relative %)."""
    ka, ba = attainment(kairos.requests), attainment(baseline.requests)
    return dict(
        ttft_gain_pp=100 * (ka.ttft - ba.ttft),
        tpot_gain_pp=100 * (ka.tpot - ba.tpot),
        e2e_gain_pp=100 * (ka.e2e - ba.e2e),
        decode_tput_gain_rel=(
            100 * (ka.decode_tput_p50 / ba.decode_tput_p50 - 1.0)
            if ba.decode_tput_p50
            else 0.0
        ),
    )
