"""SLO-attainment and throughput metrics (paper §4.1 Metrics)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np

from repro.core.request import Phase, Request
from repro.sim.simulator import SimResult


@dataclass(frozen=True)
class Attainment:
    ttft: float  # fraction of requests meeting the TTFT SLO
    tpot: float  # fraction meeting the TPOT SLO (mean inter-token latency)
    e2e: float  # both
    decode_tput_p50: float  # median per-request decode tokens/sec
    decode_tput_mean: float
    n: int

    def as_dict(self) -> Dict[str, float]:
        return dict(
            ttft=self.ttft,
            tpot=self.tpot,
            e2e=self.e2e,
            decode_tput_p50=self.decode_tput_p50,
            decode_tput_mean=self.decode_tput_mean,
            n=self.n,
        )


def attainment(requests: Sequence[Request]) -> Attainment:
    done = [r for r in requests if r.phase == Phase.DONE]
    n = len(done)
    if n == 0:
        return Attainment(0.0, 0.0, 0.0, 0.0, 0.0, 0)
    ttft = sum(r.meets_ttft() for r in done) / n
    tpot = sum(r.meets_tpot() for r in done) / n
    e2e = sum(r.meets_e2e() for r in done) / n
    tputs = [t for t in (r.decode_tput() for r in done) if t is not None]
    p50 = float(np.percentile(tputs, 50)) if tputs else 0.0
    mean = float(np.mean(tputs)) if tputs else 0.0
    return Attainment(ttft, tpot, e2e, p50, mean, n)


def summarize(result: SimResult) -> Dict[str, float]:
    att = attainment(result.requests)
    out = att.as_dict()
    out.update(
        makespan=result.makespan,
        decode_steps=result.decode_steps,
        decode_tokens=result.decode_tokens,
        agg_decode_tput=(
            result.decode_tokens / result.decode_busy if result.decode_busy else 0.0
        ),
        prefill_busy=result.prefill_busy,
        decode_busy=result.decode_busy,
    )
    done = [r for r in result.requests if r.phase == Phase.DONE]
    if done:
        out["ttft_p50"] = float(np.percentile([r.ttft() for r in done], 50))
        out["ttft_p99"] = float(np.percentile([r.ttft() for r in done], 99))
        tpots = [r.mean_tpot() for r in done if r.mean_tpot() is not None]
        out["tpot_p50"] = float(np.percentile(tpots, 50)) if tpots else 0.0
        out["tpot_p99"] = float(np.percentile(tpots, 99)) if tpots else 0.0
    return out


def compare(kairos: SimResult, baseline: SimResult) -> Dict[str, float]:
    """Headline deltas, paper-style (percentage points / relative %)."""
    ka, ba = attainment(kairos.requests), attainment(baseline.requests)
    return dict(
        ttft_gain_pp=100 * (ka.ttft - ba.ttft),
        tpot_gain_pp=100 * (ka.tpot - ba.tpot),
        e2e_gain_pp=100 * (ka.e2e - ba.e2e),
        decode_tput_gain_rel=(
            100 * (ka.decode_tput_p50 / ba.decode_tput_p50 - 1.0)
            if ba.decode_tput_p50
            else 0.0
        ),
    )
