"""Discrete-event simulator of a PD-disaggregated serving deployment.

Two engine clocks (prefill instance, decode instance) advance through a
shared timeline; arrivals are injected as the clocks pass them. The
simulator constructs its schedulers through the *same* policy registry
(`repro.policies`) as the real JAX engine — the paper's algorithms are
exercised verbatim, and any `PolicySpec` accepted here is accepted there.

Fault injection: `FaultPlan` kills the decode instance at given times; all
in-flight decode requests lose their KV and re-enter the prefill queue
(Request.reset_for_restart), modeling the framework's recovery path.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.lut import StepTimeLUT
from repro.core.pacer import DeliveryPacer
from repro.core.predictor import PrefillThroughputEstimator
from repro.core.request import Phase, Request
from repro.obs.events import EventType, TraceRecorder
from repro.policies import PolicySpec, make_decode, make_prefill
from repro.sim.costmodel import CalibratedCostModel, PAPER_COST_MODEL


@dataclass(frozen=True)
class SimConfig:
    chunk_size: int = 8192  # chunked-prefill token budget per step
    # decode-node KV memory in tokens: the paper reports a memory-bound
    # decode regime ("KV cache memory is exhausted and new requests cannot
    # be admitted", §4.5) — ~600K tokens at ~0.5 MB/token on 4xH200 after
    # weights.
    kv_cap_tokens: int = 500_000
    max_decode_batch: int = 512
    step_noise_sigma: float = 0.0  # lognormal jitter on true step times
    prefix_cache_hit_frac: float = 0.0  # fraction of prompt served from cache
    pacer_mode: str = "immediate"
    seed: int = 0


@dataclass(frozen=True)
class FaultPlan:
    decode_failures: Tuple[float, ...] = ()  # times at which decode node dies
    recovery_time: float = 5.0  # seconds to bring up the replacement


@dataclass
class SimResult:
    requests: List[Request]
    prefill_busy: float = 0.0
    decode_busy: float = 0.0
    decode_steps: int = 0
    decode_tokens: int = 0
    packed_steps: int = 0  # kairos: steps where stragglers were delayed
    full_steps: int = 0  # steps decoding the whole active set
    max_active: int = 0
    makespan: float = 0.0
    config: Optional[SimConfig] = None

    def completed(self) -> List[Request]:
        return [r for r in self.requests if r.phase == Phase.DONE]


class DisaggSimulator:
    def __init__(
        self,
        cost: CalibratedCostModel = PAPER_COST_MODEL,
        prefill_policy: Union[str, PolicySpec] = "kairos-urgency",
        decode_policy: Union[str, PolicySpec] = "kairos-slack",
        sim_cfg: Optional[SimConfig] = None,
        fault_plan: Optional[FaultPlan] = None,
        lut: Optional[StepTimeLUT] = None,
        trace: Optional[TraceRecorder] = None,
        trace_label: str = "sim",
    ):
        if sim_cfg is None:
            sim_cfg = SimConfig()
        if fault_plan is None:
            fault_plan = FaultPlan()
        self.cost = cost
        self.cfg = sim_cfg
        self.faults = sorted(fault_plan.decode_failures)
        self.recovery = fault_plan.recovery_time
        self.rng = np.random.default_rng(sim_cfg.seed)

        # policies come from the shared registry — the same specs (and the
        # same classes) the live engine constructs from
        self.prefill_sched = make_prefill(prefill_policy)
        self.lut = lut or StepTimeLUT(analytic=cost.decode_lut_seed)
        self.decode_sched = make_decode(decode_policy, self.lut)
        self.mu = PrefillThroughputEstimator(mu=cost.prefill_throughput_seed())
        self.pacer = DeliveryPacer(mode=sim_cfg.pacer_mode)
        # observability (repro.obs): None = tracing off. The simulator emits
        # the SAME event schema as the live backends at its cost-model
        # timestamps, so event-level parity with the engine can be asserted
        # (tests/test_obs_parity.py). Emissions never touch the timeline.
        self.trace = trace
        self.trace_label = trace_label

    # ------------------------------------------------------------------ run
    def run(self, requests: Sequence[Request]) -> SimResult:
        cfg, cost = self.cfg, self.cost
        reqs = sorted(requests, key=lambda r: r.arrival)
        for r in reqs:
            if cfg.prefix_cache_hit_frac > 0:
                r.prefix_cached_tokens = int(r.input_len * cfg.prefix_cache_hit_frac)
        n = len(reqs)
        arr_i = 0  # next arrival to inject

        prefill_q: List[Request] = []
        transfer: List[Tuple[float, Request]] = []  # (ready_time, request)
        wait_adm: List[Request] = []  # transferred, waiting for KV admission
        active: List[Request] = []
        kv_used = 0

        tp = 0.0  # prefill clock
        td = 0.0  # decode clock
        res = SimResult(requests=list(reqs), config=cfg)
        faults = list(self.faults)
        decode_down_until = -1.0

        tr = self.trace
        lbl = self.trace_label

        def inject(up_to: float):
            nonlocal arr_i
            while arr_i < n and reqs[arr_i].arrival <= up_to:
                r = reqs[arr_i]
                prefill_q.append(r)
                arr_i += 1
                if tr is not None:
                    # the sim has no admission control: every arrival is
                    # SUBMIT + ADMIT at its declared arrival time
                    tr.emit(
                        EventType.SUBMIT, r.arrival, rid=r.rid, tenant=r.tenant,
                        pool=lbl, arrival=r.arrival, input_len=r.input_len,
                        output_len=r.output_len, slo_ttft=r.slo.ttft,
                        slo_tpot=r.slo.tpot, slo_class=r.slo_class,
                    )
                    tr.emit(
                        EventType.ADMIT, r.arrival, rid=r.rid, tenant=r.tenant,
                        pool=lbl, queue_depth=len(prefill_q),
                    )

        def noisy(t: float) -> float:
            if cfg.step_noise_sigma > 0:
                return t * float(self.rng.lognormal(0.0, cfg.step_noise_sigma))
            return t

        def admit(now: float):
            nonlocal kv_used
            ready = [x for x in transfer if x[0] <= now]
            for x in ready:
                transfer.remove(x)
                wait_adm.append(x[1])
            wait_adm.sort(key=lambda r: (r.prefill_finish or 0.0, r.rid))
            still = []
            for r in wait_adm:
                need = r.input_len + r.output_len
                if (
                    kv_used + need <= cfg.kv_cap_tokens
                    and len(active) < cfg.max_decode_batch
                ):
                    kv_used += need
                    r.phase = Phase.DECODE
                    r.decode_start = now
                    active.append(r)
                    if tr is not None:
                        tr.emit(
                            EventType.HANDOFF_ATTACH, now, rid=r.rid,
                            tenant=r.tenant, pool=lbl,
                        )
                else:
                    still.append(r)
            wait_adm[:] = still

        def handle_fault(now: float):
            """Decode node dies: KV lost, in-flight requests restart."""
            nonlocal kv_used, decode_down_until
            for r in list(active):
                active.remove(r)
                r.reset_for_restart()
                prefill_q.append(r)
            for _, r in list(transfer):
                r.reset_for_restart()
                prefill_q.append(r)
            transfer.clear()
            for r in list(wait_adm):
                r.reset_for_restart()
                prefill_q.append(r)
            wait_adm.clear()
            kv_used = 0
            decode_down_until = now + self.recovery

        done = 0
        while done < n:
            # --- next time each engine has work -----------------------------
            t_prefill_work = None
            if any(not r.prefill_done for r in prefill_q):
                t_prefill_work = tp
            elif arr_i < n:
                t_prefill_work = max(tp, reqs[arr_i].arrival)

            t_decode_work = None
            if active:
                t_decode_work = td
            elif transfer:
                t_decode_work = max(td, min(t for t, _ in transfer))
            elif wait_adm:
                t_decode_work = td  # admission retried each visit

            if t_prefill_work is None and t_decode_work is None:
                break  # nothing left anywhere (all done or unreachable)

            # step the engine whose work time is earlier
            if t_decode_work is None or (
                t_prefill_work is not None and t_prefill_work <= t_decode_work
            ):
                tp = t_prefill_work
                while faults and faults[0] <= tp:
                    handle_fault(faults.pop(0))
                inject(tp)
                tp, td = self._prefill_step(tp, td, prefill_q, transfer, res)
            else:
                td = t_decode_work
                if td < decode_down_until:
                    td = decode_down_until
                while faults and faults[0] <= td:
                    handle_fault(faults.pop(0))
                inject(td)
                td, kv_used, done = self._decode_step(
                    td, active, transfer, wait_adm, kv_used, done, res, admit, noisy
                )

        res.makespan = max(tp, td)
        # pacing (delivery timestamps)
        for r in reqs:
            if r.token_times and r.first_token_time is not None:
                r.delivery_times = self.pacer.delivery_times(
                    r.token_times, r.first_token_time, r.slo.tpot
                )
        return res

    # --------------------------------------------------------------- prefill
    def _emit_prefill_finish(self, r: Request, t_end: float, ready: float, depth: int) -> None:
        """PREFILL_END -> HANDOFF_QUEUED -> HANDOFF_START -> TOKEN at t_end —
        the exact order `ServeSession.step` emits on prefill completion, so
        the sequences compare equal modulo the pool tag."""
        tr = self.trace
        lbl = self.trace_label
        tr.emit(
            EventType.PREFILL_END, t_end, rid=r.rid, tenant=r.tenant,
            pool=lbl, queue_depth=depth,
        )
        tr.emit(EventType.HANDOFF_QUEUED, t_end, rid=r.rid, tenant=r.tenant, pool=lbl)
        tr.emit(
            EventType.HANDOFF_START, t_end, rid=r.rid, tenant=r.tenant,
            pool=lbl, ready_at=ready,
        )
        tr.emit(EventType.TOKEN, t_end, rid=r.rid, tenant=r.tenant, pool=lbl)

    def _prefill_step(self, tp, td, prefill_q, transfer, res):
        cfg, cost = self.cfg, self.cost
        tr = self.trace
        queue = [r for r in prefill_q if r.arrival <= tp and not r.prefill_done]
        if not queue:
            future = [r.arrival for r in prefill_q if not r.prefill_done]
            tp = max(tp, min(future)) if future else max(tp, td)
            return tp, td
        # degenerate: fully prefix-cached requests complete instantly
        for r in list(queue):
            if r.remaining_prefill_tokens == 0:
                r.prefill_finish = tp
                r.first_token_time = tp
                r.token_times.append(tp)
                r.n_generated = 1
                r.phase = Phase.TRANSFER
                prefill_q.remove(r)
                queue.remove(r)
                ready = tp + cost.transfer_time(r.input_len)
                transfer.append((ready, r))
                if tr is not None:
                    tr.emit(
                        EventType.PREFILL_START, tp, rid=r.rid,
                        tenant=r.tenant, pool=self.trace_label, take=0,
                    )
                    self._emit_prefill_finish(r, tp, ready, len(prefill_q))
        if not queue:
            return tp, td
        sel = self.prefill_sched.select(queue, tp, self.mu.mu, cfg.chunk_size)
        if not sel:
            tp += 0.001
            return tp, td
        chunks = []
        for r, take in sel:
            if tr is not None and r.prefilled_tokens == 0:
                tr.emit(
                    EventType.PREFILL_START, tp, rid=r.rid, tenant=r.tenant,
                    pool=self.trace_label, take=take,
                )
            r.phase = Phase.PREFILL
            offset = r.prefix_cached_tokens + r.prefilled_tokens
            chunks.append((take, offset))
        step_t = cost.prefill_chunk_time(chunks)
        t_end = tp + step_t
        total = 0
        for r, take in sel:
            r.prefilled_tokens += take
            total += take
            if r.prefill_done:
                r.prefill_finish = t_end
                r.first_token_time = t_end  # first token emitted by prefill
                r.token_times.append(t_end)
                r.n_generated = 1
                r.phase = Phase.TRANSFER
                prefill_q.remove(r)
                ready = t_end + cost.transfer_time(r.input_len)
                transfer.append((ready, r))
                if tr is not None:
                    self._emit_prefill_finish(r, t_end, ready, len(prefill_q))
        self.mu.update(total, step_t)
        res.prefill_busy += step_t
        return t_end, td

    # ---------------------------------------------------------------- decode
    def _decode_step(self, td, active, transfer, wait_adm, kv_used, done, res, admit, noisy):
        cfg, cost = self.cfg, self.cost
        admit(td)
        if not active:
            pending = [t for t, _ in transfer]
            if pending:
                td = max(td, min(pending))
            else:
                td += 0.001
            return td, kv_used, done

        batch, _delayed = self.decode_sched.select(active, td)
        step_t = noisy(cost.decode_step_time([r.seq_len for r in batch]))
        t_end = td + step_t
        if _delayed:
            res.packed_steps += 1
        else:
            res.full_steps += 1
        res.max_active = max(res.max_active, len(active))
        tr = self.trace
        lbl = self.trace_label
        if tr is not None and batch:
            tr.emit(
                EventType.DECODE_STEP, t_end, pool=lbl,
                batch=len(batch), step_time=step_t, active=len(active),
                tpot_budget=min(r.slo.tpot for r in batch),
            )
        for r in batch:
            r.n_generated += 1
            r.n_decoded += 1
            r.token_times.append(t_end)
            if tr is not None:
                tr.emit(EventType.TOKEN, t_end, rid=r.rid, tenant=r.tenant, pool=lbl)
            if r.decode_done:
                r.phase = Phase.DONE
                r.done_time = t_end
                active.remove(r)
                kv_used -= r.input_len + r.output_len
                done += 1
                if tr is not None:
                    tr.emit(
                        EventType.DONE, t_end, rid=r.rid, tenant=r.tenant,
                        pool=lbl, n_generated=r.n_generated,
                    )
        self.decode_sched.observe(batch, step_t)
        res.decode_busy += step_t
        res.decode_steps += 1
        res.decode_tokens += len(batch)
        return t_end, kv_used, done


def run_policy(
    requests: Sequence[Request],
    prefill_policy: Union[str, PolicySpec],
    decode_policy: Union[str, PolicySpec],
    cost: CalibratedCostModel = PAPER_COST_MODEL,
    sim_cfg: Optional[SimConfig] = None,
    fault_plan: Optional[FaultPlan] = None,
    trace: Optional[TraceRecorder] = None,
) -> SimResult:
    import copy

    reqs = copy.deepcopy(list(requests))
    sim = DisaggSimulator(
        cost, prefill_policy, decode_policy, sim_cfg, fault_plan, trace=trace
    )
    return sim.run(reqs)


def run_kairos(requests, **kw) -> SimResult:
    return run_policy(requests, "kairos-urgency", "kairos-slack", **kw)


def run_distserve(requests, **kw) -> SimResult:
    """Baseline: FCFS prefill + continuous batching (DistServe)."""
    return run_policy(requests, "fcfs", "continuous", **kw)


def run_kairos_plus(requests, **kw) -> SimResult:
    """Beyond-paper variant: urgency-plus prefill + greedy-fill decode."""
    return run_policy(requests, "kairos-urgency-plus", "kairos-slack-greedy", **kw)
