"""Step-time cost models for the discrete-event simulator.

Calibrated to the paper's measured numbers (MiniMax-M2.5, 4xH200 TP4):
  prefill:  8K -> 400.4 ms, 128K -> 8.8 s            (paper §2.2)
  decode @bsz=1:  8K -> 11.0 ms, 128K -> 40.3 ms     (paper Fig. 1b)

Decode batching follows the paper's explicit premise: "the batch step time
is determined by the slowest request" (§2.2) — i.e. a *max*-based straggler
model plus a small per-request term, matching the paper's LUT[bsz, max_seq]
parameterization. An optional sum-term models memory-bandwidth contention
for ablations.

A TPU-roofline variant derives the same coefficients from first principles
for a given ModelConfig + chip constants; it seeds the LUT on TPU
deployments where no GPU profile exists.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class CalibratedCostModel:
    """Fit to the paper's published measurements."""

    # prefill t(n) = p0 + p1*n + p2*n^2   (compute-linear + attention-quadratic)
    p0: float = 0.020
    p1: float = 4.5063e-5
    p2: float = 1.6724e-10
    # per-chunk fixed overhead for chunked prefill steps
    p_chunk: float = 0.004

    # decode t(bsz, seqs) = d0 + d1*bsz + d2*max(seqs) + d3*sum(seqs)
    # d0+d1: fixed weight-read + per-request dispatch/sampling overhead.
    # d2: straggler latency (slowest request's attention, paper §2.2);
    # d3: aggregate KV bandwidth across the batch. d2+d3 calibrated so
    # bsz=1 matches the paper (11.0 ms @8K, 40.3 ms @128K).
    d0: float = 0.00874
    d1: float = 0.00031
    d2: float = 2.2000e-7
    d3: float = 0.1845e-7

    # KV transfer prefill -> decode instance
    transfer_lat: float = 0.002  # fixed latency
    kv_bytes_per_token: float = 500e3  # KV footprint per token
    transfer_bw: float = 900e9  # NVLink (paper testbed); ICI on TPU

    # ------------------------------------------------------------- prefill
    def prefill_time(self, n_tokens: int) -> float:
        """Whole-prompt prefill from scratch."""
        return self.p0 + self.p1 * n_tokens + self.p2 * n_tokens * n_tokens

    def prefill_chunk_time(self, chunks: Sequence) -> float:
        """One chunked-prefill step processing [(chunk_len, ctx_offset), ...].

        Attention cost of a chunk at context offset o is quadratic-difference:
        p2 * ((o+c)^2 - o^2); linear (MLP) cost is p1 * c.
        """
        t = self.p_chunk
        for c, o in chunks:
            t += self.p1 * c + self.p2 * (float(o + c) ** 2 - float(o) ** 2)
        return t

    def prefill_throughput_seed(self) -> float:
        """Initial mu_prefill (tokens/sec) before any observations."""
        return 1.0 / self.p1

    # -------------------------------------------------------------- decode
    def decode_step_time(self, seqs: Sequence[int]) -> float:
        """True per-step time for a batch with the given sequence lengths."""
        if not seqs:
            return 0.0
        return (
            self.d0
            + self.d1 * len(seqs)
            + self.d2 * max(seqs)
            + self.d3 * sum(seqs)
        )

    def decode_lut_seed(self, bsz: int, seq: int) -> float:
        """Analytic LUT entry: homogeneous batch at (bsz, seq) — the paper's
        LUT[bsz, seq] parameterization."""
        return self.d0 + self.d1 * bsz + (self.d2 + self.d3 * bsz) * seq

    # ------------------------------------------------------------ transfer
    def transfer_time(self, n_tokens: int) -> float:
        return self.transfer_lat + n_tokens * self.kv_bytes_per_token / self.transfer_bw


@dataclass(frozen=True)
class TPUCostModel:
    """Roofline-derived coefficients for a ModelConfig on TPU v5e chips.

    decode:  d0 = active weight bytes / (chips * HBM_bw)   (weight read)
             d2 = per-token KV bytes / (chips * HBM_bw)    (KV read, straggler)
             d1 = small dispatch/sampling overhead
    prefill: p1 = 2 * N_active / (chips * peak_flops)      (GEMM-bound)
             p2 = attention flops coefficient
    """

    cfg: ModelConfig
    chips: int = 4
    hbm_bw: float = 819e9  # v5e per chip
    peak_flops: float = 197e12  # bf16 per chip
    ici_bw: float = 50e9  # per link
    mfu: float = 0.5  # achievable fraction for prefill GEMMs
    membw_frac: float = 0.7  # achievable HBM fraction for decode

    def _active_bytes(self) -> float:
        return self.cfg.count_active_params() * 2.0  # bf16

    def kv_bytes_per_token(self) -> float:
        c = self.cfg
        if c.family == "ssm":
            return 0.0  # constant state, no per-token KV
        per_layer = 2 * c.num_kv_heads * c.resolved_head_dim * 2.0
        n_attn = c.num_layers if c.family != "hybrid" else c.num_layers // max(1, c.hybrid_period)
        return per_layer * n_attn

    def to_calibrated(self) -> CalibratedCostModel:
        c = self.cfg
        bw = self.chips * self.hbm_bw * self.membw_frac
        flops = self.chips * self.peak_flops * self.mfu
        n_act = c.count_active_params()
        d0 = self._active_bytes() / bw
        d2 = self.kv_bytes_per_token() / bw
        p1 = 2.0 * n_act / flops
        # attention quadratic term: 2 heads_flops per (q, kv) pair
        if c.num_heads:
            attn_per_pair = 4.0 * c.num_heads * c.resolved_head_dim * (
                c.num_layers if c.family != "hybrid" else c.num_layers // max(1, c.hybrid_period)
            )
        else:
            attn_per_pair = 0.0
        p2 = attn_per_pair / flops
        return CalibratedCostModel(
            p0=0.005,
            p1=p1,
            p2=p2,
            p_chunk=0.002,
            d0=d0,
            d1=2e-5,
            d2=d2,
            d3=0.0,
            kv_bytes_per_token=self.kv_bytes_per_token(),
            transfer_bw=self.ici_bw * 4,  # 4 ICI links per chip
            transfer_lat=0.001,
        )


PAPER_COST_MODEL = CalibratedCostModel()


def check_calibration(cm: CalibratedCostModel = PAPER_COST_MODEL) -> dict:
    """Returns the paper's calibration points vs the model's predictions."""
    return {
        "prefill_8k": (cm.prefill_time(8192), 0.4004),
        "prefill_128k": (cm.prefill_time(131072), 8.8),
        "decode_8k_b1": (cm.decode_step_time([8192]), 0.0110),
        "decode_128k_b1": (cm.decode_step_time([131072]), 0.0403),
    }
