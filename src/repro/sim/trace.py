"""Long-tail request trace generation + loading.

The paper evaluates on a production trace of 1000 requests with a pronounced
long-tail length distribution (Fig. 1a). We generate a statistically similar
trace: a lognormal body of short requests plus a lognormal long tail, Poisson
arrivals at a target QPS. `load_trace` accepts external JSONL traces
({"arrival":…,"input_len":…,"output_len":…} per line) for replaying real
production data.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.request import Request, SLOSpec


@dataclass(frozen=True)
class TraceConfig:
    n_requests: int = 1000
    qps: float = 3.0
    seed: int = 0

    # input lengths: mixture of lognormal body + lognormal long tail
    long_frac: float = 0.08
    short_median: float = 1800.0
    short_sigma: float = 0.75
    long_median: float = 24000.0
    long_sigma: float = 0.95
    min_input: int = 64
    max_input: int = 131_072  # paper's examples top out at 128K

    # output lengths
    out_median_short: float = 220.0
    out_median_long: float = 300.0
    out_sigma: float = 0.9
    min_output: int = 8
    max_output: int = 4000

    # SLOs (paper §4.1)
    slo_ttft: float = 8.0
    slo_tpot: float = 0.050


def generate_trace(cfg: TraceConfig) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n_requests
    gaps = rng.exponential(1.0 / cfg.qps, size=n)
    arrivals = np.cumsum(gaps)

    is_long = rng.random(n) < cfg.long_frac
    ln_short = rng.lognormal(np.log(cfg.short_median), cfg.short_sigma, size=n)
    ln_long = rng.lognormal(np.log(cfg.long_median), cfg.long_sigma, size=n)
    input_lens = np.where(is_long, ln_long, ln_short)
    input_lens = np.clip(input_lens, cfg.min_input, cfg.max_input).astype(int)

    out_med = np.where(is_long, cfg.out_median_long, cfg.out_median_short)
    output_lens = rng.lognormal(np.log(out_med), cfg.out_sigma)
    output_lens = np.clip(output_lens, cfg.min_output, cfg.max_output).astype(int)

    slo = SLOSpec(ttft=cfg.slo_ttft, tpot=cfg.slo_tpot)
    return [
        Request(
            rid=i,
            arrival=float(arrivals[i]),
            input_len=int(input_lens[i]),
            output_len=int(output_lens[i]),
            slo=slo,
        )
        for i in range(n)
    ]


def load_trace(path: str, qps: Optional[float] = None, slo: SLOSpec = SLOSpec()) -> List[Request]:
    """Load a JSONL trace; optionally rescale arrivals to a target QPS."""
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    reqs = [
        Request(
            rid=i,
            arrival=float(r.get("arrival", i)),
            input_len=int(r["input_len"]),
            output_len=int(r["output_len"]),
            slo=slo,
        )
        for i, r in enumerate(rows)
    ]
    if qps is not None and reqs:
        span = max(r.arrival for r in reqs) - min(r.arrival for r in reqs)
        target_span = len(reqs) / qps
        scale = target_span / max(span, 1e-9)
        t0 = min(r.arrival for r in reqs)
        for r in reqs:
            r.arrival = (r.arrival - t0) * scale
    return reqs


def trace_stats(reqs: List[Request]) -> dict:
    ins = np.array([r.input_len for r in reqs])
    outs = np.array([r.output_len for r in reqs])
    return dict(
        n=len(reqs),
        input_p50=float(np.percentile(ins, 50)),
        input_p90=float(np.percentile(ins, 90)),
        input_p99=float(np.percentile(ins, 99)),
        input_max=int(ins.max()),
        input_mean=float(ins.mean()),
        output_p50=float(np.percentile(outs, 50)),
        output_p99=float(np.percentile(outs, 99)),
        output_mean=float(outs.mean()),
    )
