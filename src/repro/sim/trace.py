"""Long-tail request trace generation + loading.

The paper evaluates on a production trace of 1000 requests with a pronounced
long-tail length distribution (Fig. 1a). We generate a statistically similar
trace: a lognormal body of short requests plus a lognormal long tail, Poisson
arrivals at a target QPS. `load_trace` accepts external JSONL traces
({"arrival":…,"input_len":…,"output_len":…} per line) for replaying real
production data.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, fields
from typing import List, Optional, Tuple

import numpy as np

from repro.core.request import Request, SLOSpec


@dataclass(frozen=True)
class LengthDist:
    """Lognormal body + lognormal long-tail length mixture (paper Fig. 1a).

    The one source of truth for the paper's length distribution: both
    `generate_trace` (via `TraceConfig.lengths()`) and the per-tenant
    `repro.workloads` scenarios sample through this class, so the defaults
    here ARE the `TraceConfig` defaults.
    """

    # input lengths: mixture of lognormal body + lognormal long tail
    long_frac: float = 0.08
    short_median: float = 1800.0
    short_sigma: float = 0.75
    long_median: float = 24000.0
    long_sigma: float = 0.95
    min_input: int = 64
    max_input: int = 131_072  # paper's examples top out at 128K

    # output lengths
    out_median_short: float = 220.0
    out_median_long: float = 300.0
    out_sigma: float = 0.9
    min_output: int = 8
    max_output: int = 4000

    def sample(self, n: int, rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray]:
        """Draw (input_lens, output_lens) for n requests."""
        is_long = rng.random(n) < self.long_frac
        ln_short = rng.lognormal(np.log(self.short_median), self.short_sigma, size=n)
        ln_long = rng.lognormal(np.log(self.long_median), self.long_sigma, size=n)
        input_lens = np.where(is_long, ln_long, ln_short)
        input_lens = np.clip(input_lens, self.min_input, self.max_input).astype(int)
        out_med = np.where(is_long, self.out_median_long, self.out_median_short)
        output_lens = rng.lognormal(np.log(out_med), self.out_sigma)
        output_lens = np.clip(output_lens, self.min_output, self.max_output).astype(int)
        return input_lens, output_lens


@dataclass(frozen=True)
class TraceConfig(LengthDist):
    n_requests: int = 1000
    qps: float = 3.0
    seed: int = 0

    # SLOs (paper §4.1)
    slo_ttft: float = 8.0
    slo_tpot: float = 0.050

    def lengths(self) -> LengthDist:
        return LengthDist(**{f.name: getattr(self, f.name) for f in fields(LengthDist)})


def generate_trace(cfg: TraceConfig) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n_requests
    gaps = rng.exponential(1.0 / cfg.qps, size=n)
    arrivals = np.cumsum(gaps)
    input_lens, output_lens = cfg.lengths().sample(n, rng)
    slo = SLOSpec(ttft=cfg.slo_ttft, tpot=cfg.slo_tpot)
    return [
        Request(
            rid=i,
            arrival=float(arrivals[i]),
            input_len=int(input_lens[i]),
            output_len=int(output_lens[i]),
            slo=slo,
        )
        for i in range(n)
    ]


def _parse_trace_line(path: str, lineno: int, line: str) -> dict:
    try:
        row = json.loads(line)
    except json.JSONDecodeError as e:
        raise ValueError(f"{path}:{lineno}: malformed trace line (not valid JSON): {e}") from None
    if not isinstance(row, dict):
        raise ValueError(
            f"{path}:{lineno}: trace line must be a JSON object, got {type(row).__name__}"
        )
    missing = [k for k in ("input_len", "output_len") if k not in row]
    if missing:
        raise ValueError(
            f"{path}:{lineno}: trace line missing required field(s) {missing}; "
            f'expected {{"arrival":…,"input_len":…,"output_len":…}} per line'
        )
    for k in ("input_len", "output_len"):
        v = row[k]
        # accept JSON integers only (12.0 is fine, 12.9 would silently
        # truncate, "12" hints at a mis-serialized trace)
        if (
            isinstance(v, bool)
            or not isinstance(v, (int, float))
            or (isinstance(v, float) and not v.is_integer())
        ):
            raise ValueError(
                f"{path}:{lineno}: field {k!r} must be an integer, got {v!r}"
            )
        row[k] = int(v)
        if row[k] <= 0:
            raise ValueError(f"{path}:{lineno}: field {k!r} must be positive, got {row[k]}")
    for k in ("arrival", "slo_ttft", "slo_tpot"):
        if k in row:
            v = row[k]
            # JSON numbers only; reject NaN/Infinity (json.loads accepts the
            # literals, and NaN poisons arrival sorting + qps rescaling)
            if isinstance(v, bool) or not isinstance(v, (int, float)) or not math.isfinite(v):
                raise ValueError(
                    f"{path}:{lineno}: field {k!r} must be a finite number, got {v!r}"
                )
            row[k] = float(v)
            if row[k] < 0:
                raise ValueError(
                    f"{path}:{lineno}: field {k!r} must be >= 0, got {row[k]}"
                )
    return row


def load_trace(path: str, qps: Optional[float] = None, slo: Optional[SLOSpec] = None) -> List[Request]:
    """Load a JSONL trace; optionally rescale arrivals to a target QPS.

    Per-line fields: required ``input_len``/``output_len``; optional
    ``arrival``, ``tenant``, ``slo_class``, ``slo_ttft``/``slo_tpot`` (which
    override the ``slo`` default). Malformed lines raise ``ValueError``
    naming the file and line number.
    """
    if slo is None:
        slo = SLOSpec()
    rows = []
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            line = line.strip()
            if line:
                rows.append(_parse_trace_line(path, lineno, line))
    reqs = [
        Request(
            rid=i,
            arrival=float(r.get("arrival", i)),
            input_len=r["input_len"],
            output_len=r["output_len"],
            slo=SLOSpec(
                ttft=float(r.get("slo_ttft", slo.ttft)),
                tpot=float(r.get("slo_tpot", slo.tpot)),
            ),
            tenant=str(r.get("tenant", "default")),
            slo_class=str(r.get("slo_class", "standard")),
        )
        for i, r in enumerate(rows)
    ]
    if qps is not None:
        rescale_qps(reqs, qps)
    return reqs


def rescale_qps(reqs: List[Request], qps: float) -> List[Request]:
    """Rescale arrivals in place so the trace averages ``qps``; arrivals are
    re-zeroed to the first one. Returns the same list for chaining."""
    if reqs:
        span = max(r.arrival for r in reqs) - min(r.arrival for r in reqs)
        target_span = len(reqs) / qps
        scale = target_span / max(span, 1e-9)
        t0 = min(r.arrival for r in reqs)
        for r in reqs:
            r.arrival = (r.arrival - t0) * scale
    return reqs


def save_trace(path: str, requests: List[Request]) -> None:
    """Write requests as a JSONL trace (inverse of `load_trace`).

    Round-trip preserving: arrival, lengths, tenant, slo_class, and the
    numeric SLO targets. `load_trace(save_trace(path, reqs))` rebuilds an
    equivalent trace (rids are reassigned by position).
    """
    with open(path, "w") as f:
        for r in requests:
            f.write(
                json.dumps(
                    dict(
                        arrival=float(r.arrival),
                        input_len=int(r.input_len),
                        output_len=int(r.output_len),
                        tenant=r.tenant,
                        slo_class=r.slo_class,
                        slo_ttft=float(r.slo.ttft),
                        slo_tpot=float(r.slo.tpot),
                    )
                )
                + "\n"
            )


def trace_stats(reqs: List[Request]) -> dict:
    ins = np.array([r.input_len for r in reqs])
    outs = np.array([r.output_len for r in reqs])
    return dict(
        n=len(reqs),
        input_p50=float(np.percentile(ins, 50)),
        input_p90=float(np.percentile(ins, 90)),
        input_p99=float(np.percentile(ins, 99)),
        input_max=int(ins.max()),
        input_mean=float(ins.mean()),
        output_p50=float(np.percentile(outs, 50)),
        output_p99=float(np.percentile(outs, 99)),
        output_mean=float(outs.mean()),
    )
