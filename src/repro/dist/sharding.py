"""Sharding rules: mesh-axis assignment with divisibility fallback.

MaxText-style logical rules, but resolved by *dimension size* rather than by
a per-module annotation table: every parameter / cache / input leaf asks the
``ShardingPlan`` which mesh axes may shard each of its dims, and ``pick()``
only grants an axis whose size divides the dim. Anything indivisible falls
back to replication and is recorded in ``plan.fallbacks`` — the dry-run
writes that list into its artifacts so a silent re-mesh (elastic degradation
to a non-power-of-two rectangle) shows up as data, not as a crash.

Entry points (pspec trees mirror the input tree structure exactly):

  plan  = ShardingPlan(mesh, mode="train")
  specs = param_pspecs(cfg, model.param_struct(), plan)
  specs = cache_pspecs(cfg, cache_struct, plan)
  specs = input_pspecs(cfg, input_specs(cfg, shape), plan)
"""
from __future__ import annotations

import logging
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

log = logging.getLogger(__name__)

# A candidate is one mesh axis name, or a tuple of names sharded jointly.
Candidate = Union[str, Tuple[str, ...]]

_MODEL_AXIS = "model"


class ShardingPlan:
    """Per-(mesh, mode) axis assignment state.

    mode:
      train / serve  tensor-parallel params on the model axis (default)
      dp             pure data parallel: params fully replicated
      zero           tensor parallel + ZeRO-style sharding of one leftover
                     param dim across the batch axes
    """

    def __init__(self, mesh, mode: str = "train"):
        self.mesh = mesh
        self.mode = mode
        self.sizes: Dict[str, int] = dict(mesh.shape)
        self.fallbacks: List[str] = []  # human/JSON-readable fallback records

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        """Mesh axes the batch dim spans (everything but the model axis)."""
        return tuple(a for a in self.mesh.axis_names if a != _MODEL_AXIS)

    def _axes_of(self, cand: Candidate) -> Tuple[str, ...]:
        return (cand,) if isinstance(cand, str) else tuple(cand)

    def pick(
        self,
        dim_size: int,
        candidate_axes: Sequence[Candidate],
        used_axes: Set[str],
        label: str,
    ) -> Optional[Candidate]:
        """Assign the first candidate whose mesh axes all exist, are unused
        in this leaf, and whose combined size divides ``dim_size``. Returns
        the candidate (str or tuple) and marks its axes used; returns None
        (replicated) and records a fallback when no candidate fits."""
        tried = []
        for cand in candidate_axes:
            axes = self._axes_of(cand)
            if not axes:
                continue
            if any(a not in self.sizes for a in axes):
                continue
            if any(a in used_axes for a in axes):
                continue
            n = math.prod(self.sizes[a] for a in axes)
            if dim_size % n == 0:
                used_axes.update(axes)
                return cand
            tried.append(f"{cand}={n}")
        if tried:
            rec = (
                f"{label}: dim {dim_size} not divisible by "
                f"{', '.join(tried)} -> replicated"
            )
            self.fallbacks.append(rec)
            log.info("sharding fallback: %s", rec)
        return None


# ---------------------------------------------------------------------------
# path / label helpers
# ---------------------------------------------------------------------------

def _path_parts(path) -> List[str]:
    parts = []
    for k in path:
        for attr in ("key", "name", "idx"):
            if hasattr(k, attr):
                parts.append(str(getattr(k, attr)))
                break
        else:
            parts.append(str(k))
    return parts


def _last_dict_key(path) -> str:
    parts = _path_parts(path)
    return parts[-1] if parts else ""


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _model_shardable_sizes(cfg: ModelConfig) -> Set[int]:
    """Dim sizes eligible for the tensor-parallel (model) axis: vocab, ffn,
    projected head dims, and the fused SSM channel dims."""
    hd = cfg.resolved_head_dim
    sizes = {
        cfg.vocab_size,
        cfg.d_ff,
        cfg.num_heads * hd,
        cfg.num_kv_heads * hd,
    }
    if cfg.ssm_state_dim:
        di = cfg.ssm_d_inner
        gn = cfg.ssm_ngroups * cfg.ssm_state_dim
        sizes |= {
            di,
            di + 2 * gn,  # conv channels
            2 * di + 2 * gn + cfg.ssm_num_heads,  # fused in_proj
            cfg.ssm_num_heads,
        }
    sizes.discard(0)
    return sizes


_STACKED_CONTAINERS = {"layers", "enc_layers", "dec_layers"}


def _n_stacked_dims(cfg: ModelConfig, parts: List[str]) -> int:
    """Leading scan-stacked dims (replicated): 1 for layer stacks, 2 for the
    hybrid family's (super_block, period) double stack."""
    if not parts or parts[0] not in _STACKED_CONTAINERS:
        return 0
    if cfg.family == "hybrid" and parts[0] == "layers":
        return 2
    return 1


def _param_spec(cfg: ModelConfig, plan: ShardingPlan, parts: List[str], shape) -> P:
    label = ".".join(parts) or "param"
    nd = len(shape)
    lead = min(_n_stacked_dims(cfg, parts), nd)
    entries: List[Optional[Candidate]] = [None] * nd
    used: Set[str] = set()
    model_sizes = _model_shardable_sizes(cfg)

    if plan.mode != "dp":
        # tensor parallel: shard the rightmost eligible dim on the model axis
        for i in range(nd - 1, lead - 1, -1):
            if shape[i] in model_sizes:
                entries[i] = plan.pick(
                    shape[i], [_MODEL_AXIS], used, f"{label}[{i}]"
                )
                if entries[i] is not None:
                    break

    if plan.mode == "zero" and plan.batch_axes:
        # ZeRO-style: spread one leftover dim across the batch axes
        for i in range(lead, nd):
            if entries[i] is None and shape[i] > 1:
                got = plan.pick(
                    shape[i], [plan.batch_axes], used, f"{label}[{i}].zero"
                )
                if got is not None:
                    entries[i] = got
                    break

    return P(*entries)


def param_pspecs(cfg: ModelConfig, param_struct, plan: ShardingPlan):
    """PartitionSpec tree matching ``param_struct``'s tree structure."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(cfg, plan, _path_parts(path), leaf.shape),
        param_struct,
    )


# ---------------------------------------------------------------------------
# cache rules
# ---------------------------------------------------------------------------

# KV-style leaves: (...layer stack..., B, M, H, D)
_KV_KEYS = {"k", "v", "k_local", "v_local", "self_k", "self_v", "cross_k", "cross_v"}


def _cache_spec(plan: ShardingPlan, key: str, shape, label: str) -> P:
    nd = len(shape)
    entries: List[Optional[Candidate]] = [None] * nd
    used: Set[str] = set()
    batch = plan.batch_axes

    def assign(idx: int, cands: Sequence[Candidate], what: str) -> None:
        if 0 <= idx < nd and cands:
            entries[idx] = plan.pick(shape[idx], cands, used, f"{label}.{what}")

    if key in _KV_KEYS:
        assign(nd - 4, [batch], "batch")
        assign(nd - 2, [_MODEL_AXIS], "heads")
    elif key == "conv":  # (..., B, W-1, C)
        assign(nd - 3, [batch], "batch")
        assign(nd - 1, [_MODEL_AXIS], "channels")
    elif key == "state":  # (..., B, H, P, N)
        assign(nd - 4, [batch], "batch")
        assign(nd - 3, [_MODEL_AXIS], "heads")
    else:  # unknown leaf: batch-shard dim 0 if it fits, replicate the rest
        assign(0, [batch], "batch")
    return P(*entries)


def cache_pspecs(cfg: ModelConfig, cache_struct, plan: ShardingPlan):
    """PartitionSpec tree for a decode cache (all four model families)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_spec(
            plan, _last_dict_key(path), leaf.shape, ".".join(_path_parts(path))
        ),
        cache_struct,
    )


# ---------------------------------------------------------------------------
# input rules
# ---------------------------------------------------------------------------

def _input_spec(plan: ShardingPlan, parts: List[str], shape) -> P:
    nd = len(shape)
    if nd == 0:
        return P()
    entries: List[Optional[Candidate]] = [None] * nd
    if plan.batch_axes:
        entries[0] = plan.pick(
            shape[0], [plan.batch_axes], set(), ".".join(parts) + ".batch"
        )
    return P(*entries)


def input_pspecs(cfg: ModelConfig, input_specs, plan: ShardingPlan):
    """Batch rule for step-function inputs: dim 0 spans the batch axes (with
    divisibility fallback, e.g. the global_batch=1 long-context cell stays
    replicated). A nested ``cache`` subtree uses the cache rules instead."""

    def rule(path, leaf):
        parts = _path_parts(path)
        if "cache" in parts:
            return _cache_spec(plan, _last_dict_key(path), leaf.shape, ".".join(parts))
        return _input_spec(plan, parts, leaf.shape)

    return jax.tree_util.tree_map_with_path(rule, input_specs)
