"""Distribution layer: sharding plans, activation sharding, fault planning.

Three concerns, three modules (see DESIGN.md §dist):

  sharding.py      ShardingPlan + param/cache/input PartitionSpec rules with
                   divisibility-checked fallback to replication.
  act_sharding.py  Activation batch-axis constraints that are exact no-ops
                   outside an explicit mesh context (single-host tests and
                   the serving engine never pay for them).
  fault.py         Policy layer for degraded fleets: which pods to shed,
                   what mesh to rebuild, and the recovery step narrative.
"""
from repro.dist.act_sharding import constrain_batch, use_activation_sharding
from repro.dist.fault import FleetState, plan_mesh, plan_recovery
from repro.dist.sharding import (
    ShardingPlan,
    cache_pspecs,
    input_pspecs,
    param_pspecs,
)

__all__ = [
    "ShardingPlan",
    "param_pspecs",
    "cache_pspecs",
    "input_pspecs",
    "constrain_batch",
    "use_activation_sharding",
    "FleetState",
    "plan_mesh",
    "plan_recovery",
]
