"""Activation sharding constraints, scoped by an explicit context.

The model trunks call ``constrain_batch`` on every embedded activation so
that, under a production mesh, XLA keeps the batch dim distributed instead
of re-gathering between layers. Outside a ``use_activation_sharding``
context (single-host tests, the serving engine) the call is an *exact*
no-op — it returns its argument unchanged, so CPU numerics, dtypes, and
tracing are untouched.

Usage (launch/dryrun.py, launch/elastic.py):

    plan = ShardingPlan(mesh)
    with use_activation_sharding(mesh, plan.batch_axes):
        jax.jit(step, ...).lower(*args).compile()
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import List, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Stack of (mesh, batch_axes): supports nested/elastic contexts and restores
# the prior state on exit (including on exceptions).
_ACTIVE: List[Tuple[object, Tuple[str, ...]]] = []


def active_context():
    """The innermost (mesh, batch_axes) context, or None. Test/debug hook."""
    return _ACTIVE[-1] if _ACTIVE else None


def constrain_batch(x: jax.Array) -> jax.Array:
    """Constrain dim 0 of ``x`` to the active context's batch axes.

    Exact no-op (returns ``x`` itself) when no context is active, when the
    context has no batch axes, or when the batch dim is not divisible by the
    batch-axes extent (the divisibility-fallback rule: replicate rather
    than force an uneven shard)."""
    if not _ACTIVE:
        return x
    mesh, batch_axes = _ACTIVE[-1]
    if not batch_axes:
        return x
    n = math.prod(mesh.shape[a] for a in batch_axes)
    if n <= 0 or x.ndim == 0 or x.shape[0] % n != 0:
        return x
    spec = P(batch_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


@contextmanager
def use_activation_sharding(mesh, batch_axes):
    """Activate batch-axis activation sharding for traces under this scope."""
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    _ACTIVE.append((mesh, tuple(batch_axes)))
    try:
        yield
    finally:
        _ACTIVE.pop()
