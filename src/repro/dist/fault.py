"""Fault-tolerance policy layer: re-mesh planning under pod degradation.

Pure policy, no jax device state: given per-pod healthy-chip counts, decide
which pods to shed, what mesh rectangle the survivors can all support, and
the ordered recovery steps. The mechanism layer (``launch/mesh.make_mesh``,
``launch/elastic.py``) builds whatever this module plans — the same
divisibility-fallback sharding rules then re-resolve every dim on the
smaller mesh (DESIGN.md §dist).

Production fleet: pods of 16x16 = 256 chips, meshed as
('pod', 'data', 'model'); the model axis is kept at 16 (intra-pod ICI) and
degradation shrinks the data axis to the largest rectangle every surviving
pod can host. Pods below 50% health cost more in collective stragglers than
they contribute and are shed outright.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Tuple

POD_CHIPS = 256  # healthy pod: 16 x 16
MODEL_AXIS_SIZE = 16  # fixed: tensor parallelism stays intra-pod
HEALTH_FLOOR = 0.5  # pods below this health fraction are shed


@dataclass(frozen=True)
class FleetState:
    """Healthy-chip count per pod (index = pod id)."""

    pods: Tuple[int, ...]
    pod_chips: int = POD_CHIPS


@dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_pods: Tuple[int, ...] = ()

    @property
    def chips(self) -> int:
        return math.prod(self.shape)


def plan_mesh(fleet: FleetState) -> MeshPlan:
    """Largest-common-rectangle mesh over the surviving pods.

    Healthy 2-pod fleet -> (2, 16, 16) over ('pod', 'data', 'model');
    a partially degraded pod clamps the data axis for everyone (SPMD needs a
    uniform per-pod rectangle); sub-50% pods are shed; an all-dead fleet
    raises RuntimeError."""
    floor = fleet.pod_chips * HEALTH_FLOOR
    kept = [i for i, c in enumerate(fleet.pods) if c >= floor]
    dropped = tuple(i for i in range(len(fleet.pods)) if i not in kept)
    if not kept:
        raise RuntimeError(
            f"no pod is >= {HEALTH_FLOOR:.0%} healthy (pods={fleet.pods}); "
            "cannot plan a mesh"
        )
    rows = min(
        fleet.pod_chips // MODEL_AXIS_SIZE,
        min(fleet.pods[i] for i in kept) // MODEL_AXIS_SIZE,
    )
    if rows < 1:
        raise RuntimeError(
            f"surviving pods cannot host a single {MODEL_AXIS_SIZE}-chip "
            f"model row (pods={fleet.pods}); cannot plan a mesh"
        )
    if len(kept) == 1:
        return MeshPlan(
            shape=(rows, MODEL_AXIS_SIZE),
            axes=("data", "model"),
            dropped_pods=dropped,
        )
    return MeshPlan(
        shape=(len(kept), rows, MODEL_AXIS_SIZE),
        axes=("pod", "data", "model"),
        dropped_pods=dropped,
    )


@dataclass(frozen=True)
class RecoveryPlan:
    """Ordered (action, detail) steps to move the fleet onto ``mesh``."""

    fleet: FleetState
    mesh: MeshPlan
    steps: Tuple[Tuple[str, str], ...]

    def describe(self) -> List[str]:
        return [f"{i}. {a}: {d}" for i, (a, d) in enumerate(self.steps, 1)]


def plan_recovery(fleet: FleetState) -> RecoveryPlan:
    """Recovery narrative for a degraded fleet, consumable by
    ``launch/elastic.py``: checkpoint first (the old mesh can still serve a
    save), shed unhealthy pods, then restart onto the planned mesh."""
    mesh = plan_mesh(fleet)
    steps: List[Tuple[str, str]] = [
        ("drain", "stop admitting new requests; finish in-flight decode steps"),
        ("checkpoint", "save the latest complete step from the surviving hosts"),
    ]
    if mesh.dropped_pods:
        health = ", ".join(
            f"pod {i}: {fleet.pods[i]}/{fleet.pod_chips}" for i in mesh.dropped_pods
        )
        steps.append(
            ("shed pods", f"{mesh.dropped_pods} below {HEALTH_FLOOR:.0%} health ({health})")
        )
    steps.append(
        (
            "reset_for_restart",
            f"rebuild mesh {mesh.shape} over {mesh.axes} "
            f"({mesh.chips} chips) and restore the checkpoint",
        )
    )
    return RecoveryPlan(fleet=fleet, mesh=mesh, steps=tuple(steps))
