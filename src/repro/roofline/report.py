"""Roofline report generation from dry-run artifacts.

Reads artifacts/dryrun/*.json (loop-aware per-device stats), builds
RooflineTerms per cell, and emits the §Roofline markdown table + per-cell
bottleneck narratives. Single-pod cells only (per assignment); multi-pod
cells prove the 'pod' axis shards.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import ALL_SHAPES, get_config
from repro.roofline.analysis import RooflineTerms, analytic_memory_bytes, model_flops_for
from repro.configs.shapes import ALL_SHAPES as _SHAPES
from repro.roofline.hw import V5E

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")


def load_cells(mesh: str = "single") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, f"*__{mesh}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def terms_for(cell: Dict) -> Optional[RooflineTerms]:
    if cell.get("status") != "ok":
        return None
    hs = cell["hlo_stats"]
    chips = cell["chips"]
    cfg = get_config(cell["arch"])
    spec = ALL_SHAPES[cell["shape"]]
    return RooflineTerms(
        arch=cell["arch"],
        shape=cell["shape"],
        mesh=cell["mesh"],
        chips=chips,
        flops_global=hs["flops"] * chips,
        bytes_global=hs["bytes_accessed"] * chips,
        # the windowed ring cache (D6) only exists in cells compiled after it
        # landed (tagged runs); baselines predate it
        bytes_analytic_global=analytic_memory_bytes(
            cfg, spec, windowed=bool(cell.get("tag"))
        ),
        collective_bytes_per_chip=hs["collective_bytes"],
        model_flops=cell["model_flops"],
    )


def _advice(t: RooflineTerms, cell: Dict) -> str:
    if t.dominant == "compute":
        if t.useful_flops_frac < 0.5:
            return "compute-bound with low useful-flops fraction: cut remat/recompute or pad waste"
        return "compute-bound near useful flops: raise MXU utilization (larger tiles, fused attention)"
    if t.dominant == "memory":
        return "HBM-bound: shrink bytes (fuse elementwise chains, narrower dtypes, windowed KV)"
    return "collective-bound: reshard to cut all-gathers (2D weight sharding trades memory for comm)"


def markdown_table(mesh: str = "single") -> str:
    rows = []
    hdr = (
        "| arch | shape | chips | compute_s | memory_s | mem_s(hlo-ub) | collective_s | "
        "dominant | MODEL_FLOPS | useful/HLO | roofline_frac | HBM/chip | note |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|"
    )
    rows.append(hdr)
    for cell in load_cells(mesh):
        arch, shape = cell["arch"], cell["shape"]
        if cell.get("status") == "skipped":
            rows.append(
                f"| {arch} | {shape} | — | — | — | — | skipped | — | — | — | — | "
                f"{cell['reason'][:70]}… |"
            )
            continue
        if cell.get("status") != "ok":
            rows.append(f"| {arch} | {shape} | — | ERROR | | | | | | | | {cell.get('error','')[:60]} |")
            continue
        t = terms_for(cell)
        hbm = (
            cell["memory"]["argument_bytes"]
            + cell["memory"]["temp_bytes"]
            + cell["memory"]["output_bytes"]
        ) / 1e9
        rows.append(
            f"| {arch} | {shape} | {t.chips} | {t.compute_s:.4g} | {t.memory_s:.4g} | "
            f"{t.memory_s_hlo:.4g} | {t.collective_s:.4g} | **{t.dominant}** | {t.model_flops:.3g} | "
            f"{t.useful_flops_frac:.2f} | {t.roofline_frac:.3f} | {hbm:.1f} GB | "
            f"{_advice(t, cell)} |"
        )
    return "\n".join(rows)


def pick_hillclimb_cells() -> Dict[str, Dict]:
    """The three hillclimb targets: worst roofline fraction, most
    collective-bound, most representative of the paper (decode serve_step)."""
    cells = [c for c in load_cells("single") if c.get("status") == "ok"]
    terms = [(terms_for(c), c) for c in cells]
    worst = min(terms, key=lambda tc: tc[0].roofline_frac if tc[0].roofline_frac > 0 else 1e9)
    coll = max(terms, key=lambda tc: tc[0].collective_s / max(tc[0].step_time_bound_s, 1e-12))
    decode_cells = [tc for tc in terms if tc[1]["shape"] == "decode_32k"]
    rep = max(decode_cells, key=lambda tc: tc[1]["model_flops"])
    return {
        "worst_roofline": dict(cell=f"{worst[1]['arch']}/{worst[1]['shape']}", **worst[0].as_dict()),
        "most_collective_bound": dict(cell=f"{coll[1]['arch']}/{coll[1]['shape']}", **coll[0].as_dict()),
        "paper_representative": dict(cell=f"{rep[1]['arch']}/{rep[1]['shape']}", **rep[0].as_dict()),
    }


def main() -> None:
    print("## Roofline (single-pod, 256 x v5e)\n")
    print(markdown_table("single"))
    print("\n### Hillclimb targets\n")
    for k, v in pick_hillclimb_cells().items():
        print(f"- **{k}**: {v['cell']} — dominant={v['dominant']}, roofline_frac={v['roofline_frac']:.3f}")


if __name__ == "__main__":
    main()
