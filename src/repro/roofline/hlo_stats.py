"""Loop-aware statistics from optimized (SPMD-partitioned) HLO text.

XLA's compiled.cost_analysis() counts while-loop bodies ONCE regardless of
trip count (verified: scan of N matmuls reports the flops of one), which
undercounts every scan-over-layers / microbatch-accumulation model by 1-2
orders of magnitude. This module re-derives per-device totals by:

  1. splitting the HLO module into computations,
  2. building a per-computation symbol table (instr name -> shape bytes),
  3. extracting while-loop trip counts from their condition computations
     (largest integer constant compared against the induction variable),
  4. propagating multipliers entry -> while body/cond -> nested loops,
  5. summing, with multipliers:
       * dot flops (2 * prod(result_dims) * contracted_size)
       * bytes accessed (operands + result of top-level instructions;
         fusion bodies excluded — a fusion touches HBM only at its edges)
       * collective wire bytes (ring model per kind + replica group size)

All numbers are per-device (the SPMD module is the per-partition program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"n"\s*:\s*"(\d+)"')
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\]\{\},\/]+)\s+([\w\-]+)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALL_ATTR_RE = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def _dims(dims_str: str) -> Tuple[int, ...]:
    if not dims_str:
        return ()
    return tuple(int(x) for x in dims_str.split(","))


def _shape_list(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    return [(d, _dims(s)) for d, s in _SHAPE_RE.findall(text)]


def _nbytes(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for d, dims in shapes:
        n = 1
        for x in dims:
            n *= x
        total += n * _DTYPE_BYTES[d]
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    line: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)
    root: Optional[Instr] = None


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line.strip()) if line.strip().endswith("{") else None
        if hdr and ("->" in line):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.strip().startswith("ENTRY"):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_txt, opcode, rest = m.groups()
        # operands: up to the matching close paren of the call
        depth = 1
        op_txt = []
        for ch in rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            op_txt.append(ch)
        operands = _OPERAND_RE.findall("".join(op_txt))
        is_root = line.lstrip().startswith("ROOT")
        instr = Instr(name, opcode, _shape_list(shape_txt), operands, line, is_root)
        cur.instrs.append(instr)
        cur.by_name[name] = instr
        if is_root:
            cur.root = instr
    return comps, entry


def _trip_count(cond: Computation) -> int:
    best = 1
    for ins in cond.instrs:
        for m in _CONST_RE.finditer(ins.line):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(instr: Instr, comp: Computation) -> float:
    """2 * prod(result) * contracted_size, from lhs shape + contracting dims."""
    res = 1
    for _, dims in instr.result_shapes:
        for x in dims:
            res *= x
        break  # single result
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    k = 1
    if m and instr.operands:
        lhs = comp.by_name.get(instr.operands[0])
        lhs_dims: Tuple[int, ...] = ()
        if lhs is not None and lhs.result_shapes:
            lhs_dims = lhs.result_shapes[0][1]
        for di in _dims(m.group(1)):
            if di < len(lhs_dims):
                k *= lhs_dims[di]
    return 2.0 * res * k


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 2


def _wire_bytes(kind: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    f = (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * result_bytes * f
    if kind == "all-gather":
        return result_bytes * f
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-to-all":
        return result_bytes * f
    return float(result_bytes)


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy-start", "copy-done", "after-all", "partition-id", "replica-id",
}

# ops whose big aliased/randomly-indexed operand must NOT count as streamed
# HBM traffic: in-place DUS touches only the written slice; gather reads only
# result-sized data. Without this, every per-token KV-cache update "reads"
# the whole cache and every embedding lookup "reads" the whole table.
_INPLACE_ROOTS = {"dynamic-update-slice", "scatter"}
_GATHER_ROOTS = {"gather", "dynamic-slice"}


def _effective_bytes(op_root: str, ins: Instr, comp: "Computation") -> int:
    rb = _nbytes(ins.result_shapes)
    opb = []
    for o in ins.operands:
        src = comp.by_name.get(o)
        opb.append(_nbytes(src.result_shapes) if src is not None else 0)
    if op_root in _INPLACE_ROOTS:
        # exclude the result-shaped aliased buffer; count the small pieces
        # twice (read update + write slice)
        small = [b for b in opb if b != rb]
        return 2 * sum(small)
    if op_root in _GATHER_ROOTS:
        small = [b for b in opb if b < rb]
        return 2 * rb + sum(small)
    return rb + sum(opb)


@dataclass
class HloStats:
    flops: float = 0.0  # per-device, loop-aware
    bytes_accessed: float = 0.0  # per-device, loop-aware (fusion-edge model)
    collective_bytes: float = 0.0  # per-device wire bytes
    collectives: Dict[str, float] = field(default_factory=dict)
    collective_count: int = 0
    while_trips: List[int] = field(default_factory=list)

    def as_dict(self) -> Dict:
        return dict(
            flops=self.flops,
            bytes_accessed=self.bytes_accessed,
            collective_bytes=self.collective_bytes,
            collectives=dict(self.collectives),
            collective_count=self.collective_count,
            while_trips=list(self.while_trips),
        )


def analyze(hlo: str) -> HloStats:
    comps, entry = parse_module(hlo)
    stats = HloStats(collectives=defaultdict(float))
    if entry is None:
        return stats

    def visit(comp_name: str, mult: float, count_bytes: bool, depth: int = 0):
        if depth > 64 or comp_name not in comps:
            return
        comp = comps[comp_name]
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                body = cond = None
                m = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if m:
                    body = m.group(1)
                m = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                if m:
                    cond = m.group(1)
                mt = _TRIP_RE.search(ins.line)
                if mt:
                    trips = int(mt.group(1))
                else:
                    trips = _trip_count(comps[cond]) if cond and cond in comps else 1
                stats.while_trips.append(trips)
                if body:
                    visit(body, mult * trips, count_bytes, depth + 1)
                continue
            if op in ("fusion",):
                # fused body touches HBM only at the fusion edges; still
                # recurse for dot flops inside output fusions
                called = _CALL_ATTR_RE.findall(ins.line)
                for c in called:
                    visit(c, mult, False, depth + 1)
            elif op in ("call", "conditional", "async-start"):
                for c in _CALL_ATTR_RE.findall(ins.line):
                    visit(c, mult, count_bytes, depth + 1)

            if op == "dot":
                stats.flops += mult * _dot_flops(ins, comp)
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLL_KINDS and not op.endswith("-done"):
                rb = _nbytes(ins.result_shapes)
                wb = _wire_bytes(base, rb, _group_size(ins.line))
                stats.collectives[base] += mult * wb
                stats.collective_bytes += mult * wb
                stats.collective_count += 1
            if count_bytes and op not in _SKIP_BYTES_OPS and op != "while":
                # fusion traffic is governed by its root's semantics
                op_root = op
                if op == "fusion":
                    for c in _CALL_ATTR_RE.findall(ins.line):
                        called = comps.get(c)
                        if called is not None and called.root is not None:
                            op_root = called.root.opcode
                            break
                stats.bytes_accessed += mult * _effective_bytes(op_root, ins, comp)

    visit(entry, 1.0, True)
    stats.collectives = dict(stats.collectives)
    return stats
