"""Target hardware constants (TPU v5e) for the roofline analysis."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12  # per chip
    hbm_bw: float = 819e9  # bytes/sec per chip
    hbm_bytes: float = 16e9  # capacity per chip
    ici_link_bw: float = 50e9  # bytes/sec per link
    ici_links: int = 4  # links per chip (2D torus)
    dcn_bw: float = 25e9  # per host, cross-pod


V5E = ChipSpec()
