"""Roofline terms from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(); collective bytes
are parsed out of the optimized HLO (sum of operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, async starts
counted once). Whether cost_analysis reports per-partition or global values
is runtime-dependent; calibrate_cost_semantics() measures it with a known
matmul and the caller normalizes.
"""
from __future__ import annotations

import re
from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.roofline.hw import V5E, ChipSpec

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g. "bf16[64,2048,128]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

# "%name = <result shape or tuple> <kind>[-start](..." — SPMD HLO prints
# operands as bare %refs, so we read the *result* shape (per-device shard)
# and convert to bytes-on-the-wire per device using the collective's
# semantics + replica group size.
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+("
    + "|".join(_COLL_KINDS)
    + r")(-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 2  # unknown; conservative


def _wire_bytes(kind: str, result_bytes: int, g: int) -> float:
    """Per-device bytes moved over ICI for one collective (ring algorithms).

    all-reduce: result is the reduced shard-size tensor -> 2*S*(g-1)/g
    all-gather: result is the gathered tensor              -> S*(g-1)/g
    reduce-scatter: result is the scattered piece S/g      -> S*(g-1) (= full*(g-1)/g)
    all-to-all / collective-permute: result-sized exchange -> S*(g-1)/g / S
    """
    if g <= 1:
        return 0.0
    f = (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * result_bytes * f
    if kind == "all-gather":
        return result_bytes * f
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-to-all":
        return result_bytes * f
    return float(result_bytes)  # collective-permute


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic by kind, from optimized (SPMD) HLO.

    Async pairs (-start/-done) are counted once via the -start op. Returns
    {kind: bytes, ..., "total": bytes, "count": n_ops}.
    """
    out: Counter = Counter()
    count = 0
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        result, kind = m.group(1), m.group(2)
        nbytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(result))
        if nbytes:
            out[kind] += _wire_bytes(kind, nbytes, _group_size(line))
            count += 1
    total = float(sum(out.values()))
    res = {k: float(v) for k, v in out.items()}
    res["total"] = total
    res["count"] = count
    return res


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_global: float
    bytes_global: float  # loop-aware HLO bytes (CPU-fusion upper bound)
    collective_bytes_per_chip: float
    model_flops: float  # 6*N*D (train) / 2*N_active*tokens (inference)
    bytes_analytic_global: float = 0.0  # TPU-fusion lower bound
    hw: ChipSpec = field(default_factory=lambda: V5E)

    @property
    def compute_s(self) -> float:
        return self.flops_global / (self.chips * self.hw.peak_flops_bf16)

    @property
    def memory_s(self) -> float:
        """Memory term from the analytic (TPU-fusion) model when available;
        the HLO-derived number is a CPU-backend upper bound (memory_s_hlo)."""
        b = self.bytes_analytic_global or self.bytes_global
        return b / (self.chips * self.hw.hbm_bw)

    @property
    def memory_s_hlo(self) -> float:
        return self.bytes_global / (self.chips * self.hw.hbm_bw)

    @property
    def collective_s(self) -> float:
        # per-chip collective bytes over per-chip aggregate ICI bandwidth
        return self.collective_bytes_per_chip / (self.hw.ici_link_bw * self.hw.ici_links)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        if self.flops_global <= 0:
            return 0.0
        return self.model_flops / self.flops_global

    @property
    def roofline_frac(self) -> float:
        """Fraction of the compute roofline the dominant-bound step achieves
        on useful model flops."""
        t = self.step_time_bound_s
        if t <= 0:
            return 0.0
        return (self.model_flops / t) / (self.chips * self.hw.peak_flops_bf16)

    def as_dict(self) -> Dict:
        return dict(
            arch=self.arch,
            shape=self.shape,
            mesh=self.mesh,
            chips=self.chips,
            flops_global=self.flops_global,
            bytes_global=self.bytes_global,
            bytes_analytic_global=self.bytes_analytic_global,
            collective_bytes_per_chip=self.collective_bytes_per_chip,
            model_flops=self.model_flops,
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            memory_s_hlo=self.memory_s_hlo,
            collective_s=self.collective_s,
            dominant=self.dominant,
            useful_flops_frac=self.useful_flops_frac,
            roofline_frac=self.roofline_frac,
        )


def analytic_memory_bytes(cfg, spec, n_micro: int = 8, windowed: bool = False) -> float:
    """TPU-fusion lower-bound HBM traffic per step (global bytes).

    The HLO-derived byte count is compiled for the CPU backend, whose far
    weaker op fusion materializes every elementwise intermediate — measured
    10-200x above what a TPU executes. This analytic model counts what a
    well-fused TPU program must stream:

      train:   weight reads (fwd+bwd per microbatch) + optimizer traffic +
               C materialized activations per layer + attention scores
               (naive path: quadratic; flash kernels remove this term)
      prefill: weight read + activations + scores + KV output
      decode:  weight read + full KV-cache read + GQA expansion
    """
    n_total = cfg.count_params()
    n_active = cfg.count_active_params()
    tokens = spec.global_batch * spec.seq_len
    d = max(cfg.d_model, 1)
    L = max(cfg.num_layers, 1)
    hq = max(cfg.num_heads, 1)
    hkv = max(cfg.num_kv_heads, 1)
    dh = cfg.resolved_head_dim

    def attn_layers() -> int:
        if cfg.family == "hybrid":
            return cfg.num_layers // max(1, cfg.hybrid_period)
        if cfg.family == "ssm":
            return 0
        return L

    if spec.kind == "train":
        micro = n_micro if spec.global_batch % n_micro == 0 else 1
        w = 2.0 * n_total * (2 * micro)  # bf16 read fwd+bwd per microbatch
        opt = 20.0 * n_total  # f32 m/v read+write + param read/write
        acts = 12.0 * L * tokens * d * 2.0  # ~12 materialized tensors/layer (remat incl.)
        # naive attention scores fwd + bwd recompute (f32), per attn layer
        scores = 3.0 * attn_layers() * spec.global_batch * hq * (spec.seq_len ** 2) * 4.0
        if cfg.is_encdec:
            scores *= 0.75  # half-length enc/dec sequences
        return w + opt + acts + scores

    if spec.kind == "prefill":
        w = 2.0 * n_active
        acts = 8.0 * L * tokens * d * 2.0
        # blockwise (flash-style) attention path at 32K: no quadratic term
        kv_out = 2.0 * attn_layers() * tokens * 2 * hkv * dh * 2.0
        return w + acts + kv_out

    # decode: one token per sequence
    w = 2.0 * n_active
    if windowed and cfg.alternate_local_global and cfg.sliding_window and spec.seq_len > cfg.sliding_window:
        # windowed ring cache (§Perf D6): half the layers read only the window
        per_layer_tokens = (spec.seq_len + cfg.sliding_window) / 2.0
    else:
        per_layer_tokens = float(spec.seq_len)
    kv_read = 2.0 * attn_layers() * spec.global_batch * per_layer_tokens * hkv * dh * 2.0
    if cfg.family in ("ssm", "hybrid"):
        ssm_state = (
            2.0 * L * spec.global_batch * cfg.ssm_num_heads * cfg.ssm_head_dim
            * cfg.ssm_state_dim * 4.0
        )
        kv_read += 2.0 * ssm_state
    acts = 6.0 * L * spec.global_batch * d * 2.0
    return w + kv_read + acts


def model_flops_for(cfg, spec) -> float:
    """MODEL_FLOPS: 6*N*D for training; forward-only for inference shapes."""
    n_active = cfg.count_active_params()
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * n_active * tokens
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * spec.global_batch
