"""Injectable clocks for the serving engine.

`DisaggServer` historically called ``time.monotonic()`` directly, which made
every engine test wall-clock-dependent (and flaky under CI load). The engine
now reads time through a ``Clock`` object:

    MonotonicClock  production default — thin wrapper over time.monotonic
    ManualClock     tests — time advances only when the test (or the
                    engine's own idle-sleep) says so, making TTFT/TPOT
                    arithmetic exactly reproducible run-to-run

``ManualClock.auto_step`` optionally advances time by a fixed amount per
``monotonic()`` read, modeling "each observation costs dt" so elapsed-time
deltas (LUT observations, prefill-throughput updates) are non-zero yet
deterministic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    def monotonic(self) -> float: ...

    def sleep(self, dt: float) -> None: ...

    def peek(self) -> float: ...


@dataclass
class MonotonicClock:
    """Wall clock (production default)."""

    def monotonic(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)

    def peek(self) -> float:
        """Same reading as ``monotonic`` — the wall clock has no observer
        cost, so observing and peeking are one operation."""
        return time.monotonic()


@dataclass
class ManualClock:
    """Deterministic virtual clock for tests.

    ``sleep`` advances virtual time instead of blocking, so engine idle
    waits (e.g. for a future arrival) complete instantly and identically
    on every run.
    """

    t: float = 0.0
    auto_step: float = 0.0  # seconds added per monotonic() read

    def __post_init__(self) -> None:
        # the construction origin — what reset() must restore, NOT a
        # literal 0.0: a clock built at t=5 that "re-zeroes" to 0 would
        # break construction parity for restarted replicas
        self._t_init = self.t

    def monotonic(self) -> float:
        self.t += self.auto_step
        return self.t

    def sleep(self, dt: float) -> None:
        if dt > 0:
            self.t += dt

    def advance(self, dt: float) -> None:
        self.t += dt

    def peek(self) -> float:
        """Current virtual time WITHOUT charging ``auto_step`` — the
        control plane's read. A fleet controller polling via ``monotonic``
        would advance every replica's time by how often it looked,
        destroying replay determinism; ``peek`` is observation-free."""
        return self.t

    def reset(self) -> float:
        """Restore virtual time to its construction value and return it.
        Sessions call this (via `DisaggServer.reset_clock`) so runs
        accumulate ``auto_step`` from exactly the origin — float
        accumulation depends on the starting value, so without the reset
        two runs whose *construction* paths read the clock a different
        number of times would disagree in the last ulp even with identical
        serving-time read sequences. Restarted replicas
        (`DisaggServer.reset_for_restart`) rely on the construction-value
        contract for post-failover timing parity."""
        self.t = self._t_init
        return self.t
