"""Multi-server routing: one submit/stream/cancel surface over N replicas.

`RouterSession` fronts several `AsyncServeSession` replicas (each wrapping
its own `DisaggServer`) behind the exact client surface a single frontend
exposes — ``await submit(...) -> RequestHandle``, ``async for tok in
handle.stream()``, ``cancel(rid)``, ``replay``, ``drain``/``aclose`` — so a
client written against one engine scales to a fleet by swapping the
constructor. Placement is a registered policy (`repro.policies.router`:
``round-robin``, ``least-queued``, ``slack-aware``, ``prefix-affinity``),
chosen at submit time from the router's own view of each replica.

Two prefix tries per replica (DESIGN.md §router):

  * the **routing index** (`ReplicaState.route_index`) is the router's
    record of which prefixes it sent where — updated at *routing* time,
    probed by ``prefix-affinity``. A real router can't read replica
    internals, so it routes on what it routed.
  * the **session cache** (the replica `ServeSession`'s `PrefixCache`) does
    admission-time hit accounting and grants the `SlotAllocator` KV budget
    credit. It is deliberately separate: inserting at routing time would
    make every request hit its own just-routed prefix.

Determinism: the router adds no clock reads of its own. ``submit`` picks a
replica synchronously (policies are pure functions of the router's view)
and delegates to that replica's frontend with the same ``at``; with one
replica the awaited call sequence is identical to a bare
`AsyncServeSession`, so a 1-replica routed run reproduces the async-engine
backend bit-for-bit on a `ManualClock` (pinned in tests and CI). One
scoping note: replica sessions carry a `PrefixCache`, whose only timing
effect is the `SlotAllocator` KV-budget credit — the parity is exact while
``kv_cap_tokens`` stays slack (true of every shipped engine config; a
config whose cap binds admits more under the credit, by design). With N
replicas each stepper owns its own clock and session, so per-replica
timelines depend only on what was routed there — deterministic given
deterministic routing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.request import TERMINAL_PHASES, Request
from repro.obs.events import EventType, TraceRecorder
from repro.policies import PolicySpec, make_router
from repro.serving.engine import DisaggServer
from repro.serving.frontend import AsyncServeSession, RequestHandle, drive_replay
from repro.serving.prefixcache import DEFAULT_PREFIX_BLOCK, PrefixCache
from repro.serving.session import FROM_CONFIG


@dataclass
class ReplicaState:
    """The router's view of one replica — everything a `RouterPolicy` may
    consult, derived from requests *this router* routed there (no reach
    into stepper internals, so the view is valid mid-flight).

    The view is live: phases and prefill progress are read at decision
    time. Under live interleaved submission that means real load; under
    upfront open-loop ``replay`` (every submission scheduled before the
    first engine step) routed work hasn't started yet, so `least-queued` /
    `slack-aware` reduce to greedy predicted-load balancing over assigned
    counts / token mass — still the right greedy decision with the
    information a router has at that instant.
    """

    index: int
    frontend: AsyncServeSession
    route_index: PrefixCache
    assigned: int = 0  # total ever routed here (terminal ones included)
    routed: List[Request] = field(default_factory=list)  # non-terminal view
    # fleet liveness (repro.serving.fleetctl): a dead replica stays in
    # `replicas` so indices/owner records remain stable, but is never
    # routed to again; a draining one finishes its work before scale-down
    alive: bool = True
    draining: bool = False

    def _live(self) -> List[Request]:
        # prune terminal requests as they are observed, so per-submit scans
        # stay O(in-flight) instead of O(everything ever routed) and the
        # list doesn't pin every Request for the session's lifetime
        self.routed = [r for r in self.routed if r.phase not in TERMINAL_PHASES]
        return self.routed

    @property
    def in_flight(self) -> int:
        """Routed requests that have not reached a terminal phase."""
        return len(self._live())

    @property
    def pending_prefill_tokens(self) -> int:
        """Prompt tokens routed here whose prefill hasn't finished — the
        backlog a new arrival queues behind."""
        return sum(r.remaining_prefill_tokens for r in self._live())

    @property
    def mu(self) -> float:
        """The replica's online prefill-throughput estimate (tokens/s)."""
        return self.frontend.session.server.mu.mu

    def prefix_match(self, prompt: Sequence[int]) -> int:
        """Longest prefix (tokens) the router already sent this replica."""
        return self.route_index.match(prompt)


class RouterSession:
    """N `AsyncServeSession` replicas behind one submit/stream/cancel surface."""

    def __init__(
        self,
        servers: Sequence[DisaggServer],
        policy: Union[str, PolicySpec] = "round-robin",
        max_queue_depth: Any = FROM_CONFIG,
        tenant_queue_depth: Any = FROM_CONFIG,
        stream_buffer: int = 16,
        backpressure: str = "block",
        prefix_block: int = DEFAULT_PREFIX_BLOCK,
        prefix_cache_blocks: Optional[int] = None,
        trace: Optional[TraceRecorder] = None,
    ):
        if not servers:
            raise ValueError("RouterSession needs at least one server")
        self.policy = make_router(policy)
        self.prefix_block = prefix_block
        # one shared recorder across all replicas: each replica stamps its
        # own pool label ("replica:i"), so the fleet shares one timeline
        self.trace = trace
        self.replicas: List[ReplicaState] = [
            ReplicaState(
                index=i,
                frontend=AsyncServeSession(
                    srv,
                    max_queue_depth=max_queue_depth,
                    tenant_queue_depth=tenant_queue_depth,
                    stream_buffer=stream_buffer,
                    backpressure=backpressure,
                    prefix_cache=PrefixCache(
                        block=prefix_block, max_blocks=prefix_cache_blocks
                    ),
                    trace=trace,
                    trace_label=f"replica:{i}",
                ),
                route_index=PrefixCache(
                    block=prefix_block, max_blocks=prefix_cache_blocks
                ),
            )
            for i, srv in enumerate(servers)
        ]
        self._owner: Dict[int, int] = {}  # rid -> replica index
        self._handles: Dict[int, RequestHandle] = {}

    # ------------------------------------------------------------ lifecycle
    async def __aenter__(self) -> "RouterSession":
        self.start()
        return self

    async def __aexit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            await self.drain()
        else:
            await self.aclose()

    def start(self) -> None:
        for rep in self.replicas:
            rep.frontend.start()

    @staticmethod
    async def _settle_all(coros) -> None:
        """Await every replica before re-raising the first failure: a bare
        gather would propagate one replica's crash immediately, orphaning
        the other replicas' drains/steppers mid-teardown."""
        import asyncio

        results = await asyncio.gather(*coros, return_exceptions=True)
        for res in results:
            if isinstance(res, BaseException):
                raise res

    async def drain(self) -> None:
        """Wait for every replica's admitted work to finish, then stop all
        steppers. A replica crash re-raises only after the others drained."""
        await self._settle_all(rep.frontend.drain() for rep in self.replicas)

    async def aclose(self) -> None:
        await self._settle_all(rep.frontend.aclose() for rep in self.replicas)

    # -------------------------------------------------------------- submit
    async def submit(
        self, request: Request, prompt: Sequence[int], at: Optional[float] = None
    ) -> RequestHandle:
        """Route then delegate: the policy picks a replica from the current
        router view, the routing index records the prompt's prefix there,
        and the replica frontend takes over (admission control included —
        a routed request can still be shed by its replica's quotas)."""
        cands = self._routable()
        if not cands:
            raise RuntimeError("no live replica to route to (all dead/draining)")
        k = self.policy.select(cands, request, prompt)
        if not 0 <= k < len(cands):
            raise ValueError(
                f"router policy {self.policy.name!r} chose replica {k} "
                f"of {len(cands)}"
            )
        rep = cands[k]
        idx = rep.index
        # delegate BEFORE recording the route: if the frontend rejects the
        # call outright (length mismatch, not started), no phantom load or
        # phantom prefix affinity may survive on the replica's books
        handle = await rep.frontend.submit(request, prompt, at=at)
        rep.route_index.admit(prompt)
        rep.assigned += 1
        rep.routed.append(request)
        self._owner[request.rid] = idx
        self._handles[request.rid] = handle
        if self.trace is not None:
            # routing happens before the replica stepper runs admission, so
            # ROUTE precedes the rid's SUBMIT in the shared timeline. No
            # clock read: stamped with the scheduled submission time.
            self.trace.emit(
                EventType.ROUTE,
                request.arrival if at is None else at,
                rid=request.rid, tenant=request.tenant,
                pool=f"replica:{idx}", policy=self.policy.name,
            )
        return handle

    def _routable(self) -> List[ReplicaState]:
        """Replicas new work may land on. With every replica alive this is
        `self.replicas` itself, so policies see the identical view (and the
        identical indices) they always did — the bit-parity contracts hold.
        Policies receive the candidate list and return an index *into it*;
        `ReplicaState.index` maps back to the stable fleet index."""
        if all(rep.alive and not rep.draining for rep in self.replicas):
            return self.replicas
        return [rep for rep in self.replicas if rep.alive and not rep.draining]

    def cancel(self, rid: int) -> bool:
        """Withdraw a routed request on whichever replica owns it (client
        disconnect); False for unknown/never-routed rids."""
        handle = self._handles.get(rid)
        if handle is None:
            return False
        handle.cancel()
        return True

    def owner_of(self, rid: int) -> Optional[int]:
        """Replica index a rid was routed to (None if never routed)."""
        return self._owner.get(rid)

    # -------------------------------------------------------------- replay
    async def replay(
        self,
        pairs: Sequence[Tuple[Request, Sequence[int]]],
        clients: int = 4,
        on_client_token: Optional[Any] = None,
    ) -> Dict[int, List[int]]:
        """Open-loop replay across the fleet: the same `drive_replay` body
        `AsyncServeSession.replay` runs (identical submit order and consumer
        structure), which is what makes the 1-replica routed run
        bit-identical to it."""
        await drive_replay(self.submit, pairs, clients, on_client_token)
        return self.outputs

    # ------------------------------------------------------------- metrics
    @property
    def outputs(self) -> Dict[int, List[int]]:
        """rid -> output tokens, merged across replicas (rids are global;
        lists are copies, so callers can't corrupt session state)."""
        merged: Dict[int, List[int]] = {}
        for rep in self.replicas:
            for rid, toks in rep.frontend.session.outputs.items():
                merged[rid] = list(toks)
        return merged

    def prefix_summary(self) -> Dict[str, Any]:
        """Session-level (admission) prefix-hit accounting, per replica and
        aggregated — the hit rate routing policies compete on."""
        per = []
        hit_tokens = lookup_tokens = lookups = hits = 0
        for rep in self.replicas:
            m = rep.frontend.session.metrics
            per.append(
                dict(
                    replica=rep.index,
                    lookups=m.prefix_lookups,
                    hits=m.prefix_hits,
                    hit_tokens=m.prefix_hit_tokens,
                    lookup_tokens=m.prefix_lookup_tokens,
                    hit_rate=(
                        m.prefix_hit_tokens / m.prefix_lookup_tokens
                        if m.prefix_lookup_tokens
                        else 0.0
                    ),
                )
            )
            lookups += m.prefix_lookups
            hits += m.prefix_hits
            hit_tokens += m.prefix_hit_tokens
            lookup_tokens += m.prefix_lookup_tokens
        return dict(
            block=self.prefix_block,  # hit rates are only comparable per block size
            per_replica=per,
            lookups=lookups,
            hits=hits,
            hit_tokens=hit_tokens,
            lookup_tokens=lookup_tokens,
            hit_rate=hit_tokens / lookup_tokens if lookup_tokens else 0.0,
        )

    def summary(self) -> Dict[str, Any]:
        """One fleet-level report: aggregated session counters, the routing
        decision record, prefix-hit accounting, and each replica's full
        `ServeSession.summary()` under ``per_replica``."""
        per_replica = []
        agg = dict(
            submitted=0, accepted=0, rejected=0, rejected_global=0,
            rejected_tenant=0, completed=0, cancelled=0, backpressure_shed=0,
        )
        requests: List[Dict[str, Any]] = []
        for rep in self.replicas:
            s = rep.frontend.summary()
            for k in agg:
                agg[k] += s[k]
            requests.extend(
                dict(row, replica=rep.index) for row in s["requests"]
            )
            per_replica.append(dict(replica=rep.index, assigned=rep.assigned, **s))
        requests.sort(key=lambda row: row["rid"])
        return dict(
            routing=dict(
                policy=self.policy.name,
                replicas=len(self.replicas),
                assigned=[rep.assigned for rep in self.replicas],
            ),
            prefix=self.prefix_summary(),
            per_replica=per_replica,
            requests=requests,
            **agg,
        )
