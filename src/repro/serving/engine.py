"""Disaggregated serving engine: real JAX compute driven by core/ schedulers.

PrefillEngine owns a prefill cache per in-flight request and executes
chunked prefill steps chosen by the prefill scheduler (urgency/FCFS/...).
DecodeEngine owns the slot cache; each step the decode scheduler
(slack-guided / continuous) picks the sub-batch, which is gathered into a
power-of-two bucket, decoded, and scattered back. Observed wall-clock step
times feed the LUT and the prefill-throughput estimator online — the same
adaptation loop the paper runs on GPUs.

Engine model families: decoder-only attention archs (dense / moe / vlm).
SSM/hybrid/enc-dec serving is exercised via smoke tests + the dry-run; see
DESIGN.md §engine-scope.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lut import StepTimeLUT
from repro.core.predictor import PrefillThroughputEstimator
from repro.core.request import Request

if TYPE_CHECKING:  # import for annotation only: engine stays obs-free
    from repro.obs.events import TraceRecorder
from repro.models.model import Model, cache_struct
from repro.models.transformer import chunk_prefill_step, decode_step
from repro.policies import PolicySpec, make_decode, make_prefill
from repro.serving.clock import Clock, MonotonicClock
from repro.serving.kvcache import (
    PageAllocator,
    SlotAllocator,
    gather_pages,
    gather_slots,
    scatter_pages,
    scatter_slots,
)
from repro.serving.prefixcache import PrefixCache
from repro.serving.sampler import sample


def _bucket(n: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclass
class EngineConfig:
    max_slots: int = 8
    max_len: int = 256
    chunk_size: int = 64
    kv_cap_tokens: int = 1 << 16
    decode_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    eos_token: int = 1
    temperature: float = 0.0
    # policy specs resolved through the repro.policies registry: a registered
    # name, or a PolicySpec carrying construction kwargs
    prefill_policy: Union[str, PolicySpec] = "kairos-urgency"
    decode_policy: Union[str, PolicySpec] = "kairos-slack"
    slo_margin: float = 0.9
    # virtual time: 1.0 => wall clock; larger stretches SLOs for slow CPUs
    time_scale: float = 1.0
    # ServeSession admission control: max requests waiting in the prefill
    # queue before submits are shed; None = unbounded (offline serve default)
    admission_queue_depth: Optional[int] = None
    # per-tenant bound on queued requests, applied on top of the global
    # bound (one tenant's burst can't monopolize admission); None = no quota
    tenant_queue_depth: Optional[int] = None
    # KV-handoff pricing, shared with the simulator through
    # `CalibratedCostModel.transfer_time` (lat + tokens * bytes / bw): the
    # session's prefill->decode admission and the disagg fleet's
    # cross-server handoff both wait this long per transfer. Units are
    # engine *virtual* seconds; defaults match the sim's cost model.
    transfer_lat: float = 0.002
    transfer_bw: float = 900e9
    kv_bytes_per_token: float = 500e3
    # paged KV (DESIGN.md §kvcache): a page size switches the decode cache
    # from one contiguous max_len slot per request to a pool of fixed-size
    # pages with per-request page tables and an engine-owned page-mapped
    # prefix cache (real reuse: matched prefix pages are linked, not
    # recomputed). None keeps the legacy slot layout. max_len must divide
    # evenly into pages; requires a plain k/v attention cache.
    page_size: Optional[int] = None
    # pool capacity in pages; None sizes it to max_slots full-length
    # requests (capacity-neutral vs slot mode)
    cache_pages: Optional[int] = None


@dataclass
class LiveRequest:
    req: Request
    tokens: List[int]  # prompt + generated
    slot: Optional[int] = None
    prefill_cache: Optional[Dict] = None
    next_logits: Optional[np.ndarray] = None
    # earliest virtual time the prefill->decode KV handoff may complete
    # (prefill_finish + CostModel.transfer_time); None until prefill is done
    transfer_ready_at: Optional[float] = None
    # paged KV: prefix pages shared from the radix cache (set at submit),
    # the engine whose pool holds them (prefill seeds its cache from it),
    # and the page table built at reserve time
    shared_pages: Optional[Tuple[int, ...]] = None
    kv_src: Optional["DecodeEngine"] = None
    page_table: Optional[List[int]] = None


class PrefillEngine:
    def __init__(self, model: Model, params: Dict, ecfg: EngineConfig):
        self.model, self.params, self.ecfg = model, params, ecfg
        cfg = model.cfg
        self._chunk = jax.jit(
            lambda p, t, s, v, c: chunk_prefill_step(p, t, s, v, cfg, c)
        )

    def new_cache(self) -> Dict:
        return self.model.init_cache(1, self.ecfg.max_len)

    def _seed_cache(self, lr: LiveRequest) -> Dict:
        """Build lr's prefill cache pre-loaded with its shared prefix pages.

        This is where prefix reuse becomes real compute savings: the chunk
        loop starts at ``prefix_cached_tokens``, so the attention over the
        skipped head reads KV that was never recomputed — it is copied out
        of the source engine's page pool (positions ``[0, hit)``), exactly
        the bytes an earlier request already produced.
        """
        cache = self.new_cache()
        src = lr.kv_src
        pages = lr.shared_pages
        if src is None or not pages:
            return cache
        ps = src.page_size
        idx = jnp.asarray(pages, jnp.int32)
        for name, leaf in cache.items():
            pool = src.pool[name]  # (L, n_pages, ps, ...)
            head = jnp.take(pool, idx, axis=1)  # (L, n_shared, ps, ...)
            head = head.reshape(pool.shape[0], 1, len(pages) * ps, *pool.shape[3:])
            cache[name] = leaf.at[:, :, : len(pages) * ps].set(head)
        return cache

    def run_chunk(self, lr: LiveRequest, take: int) -> Optional[np.ndarray]:
        """Prefill `take` tokens of lr; returns last logits if prompt done."""
        r = lr.req
        ecfg = self.ecfg
        if lr.prefill_cache is None:
            lr.prefill_cache = self._seed_cache(lr)
        start = r.prefix_cached_tokens + r.prefilled_tokens
        chunk = lr.tokens[start : start + take]
        pad = ecfg.chunk_size - len(chunk)
        toks = jnp.asarray([chunk + [0] * pad], jnp.int32)
        logits, lr.prefill_cache = self._chunk(
            self.params,
            toks,
            jnp.asarray([start], jnp.int32),
            jnp.asarray([len(chunk)], jnp.int32),
            lr.prefill_cache,
        )
        r.prefilled_tokens += take
        if r.prefill_done:
            return np.asarray(logits[0])
        return None


class DecodeEngine:
    def __init__(self, model: Model, params: Dict, ecfg: EngineConfig):
        self.model, self.params, self.ecfg = model, params, ecfg
        cfg = model.cfg
        # slot ids stay the batch-lane identity in both layouts; in paged
        # mode they charge 0 tokens (the page pool is the capacity) so
        # fleet probes of alloc.free keep meaning "free decode lanes"
        self.alloc = SlotAllocator(ecfg.max_slots, ecfg.kv_cap_tokens)
        self.page_size = ecfg.page_size
        if self.page_size is not None:
            self._init_paged(cfg)
        else:
            self.pages = None
            self.prefix = None
            self.pool = None
            # +1: lane max_slots is non-allocatable scratch for pad lanes —
            # padding into a LIVE slot would overwrite its position-0 KV
            # (the paged scratch page is the same idea at page granularity)
            self.cache = model.init_cache(ecfg.max_slots + 1, ecfg.max_len)
            self.scratch_slot = ecfg.max_slots

            def step(params, tokens, positions, cache, slot_idx):
                sub = gather_slots(cfg, cache, slot_idx)
                logits, sub2 = decode_step(params, tokens, positions, cfg, sub)
                return logits, scatter_slots(cfg, cache, sub2, slot_idx)

            self._step = jax.jit(step)

    def _init_paged(self, cfg) -> None:
        ecfg = self.ecfg
        ps = self.page_size
        if ps < 1:
            raise ValueError(f"page_size must be >= 1, got {ps}")
        if ecfg.max_len % ps:
            raise ValueError(
                f"max_len={ecfg.max_len} must be a multiple of page_size={ps}"
            )
        leaves = set(cache_struct(cfg, 1, ps))
        if leaves != {"k", "v"}:
            raise ValueError(
                f"paged KV requires a plain k/v attention cache; family "
                f"{cfg.family!r} has leaves {sorted(leaves)}"
            )
        self.pages_per_req = ecfg.max_len // ps
        n_pages = ecfg.cache_pages or ecfg.max_slots * self.pages_per_req
        self.cache = None
        # +1: the last pool page is non-allocatable scratch for pad lanes
        # and unused page-table tails
        self.pool = self.model.init_cache(n_pages + 1, ps)
        self.scratch_page = n_pages
        self.pages = PageAllocator(page_size=ps, n_pages=n_pages)
        # the engine-owned radix cache: nodes map prefix blocks to live
        # pages in `self.pool` (contrast the session/router caches, which
        # are accounting-only). It doubles as the allocator's pressure
        # evictor via the constructor hookup.
        self.prefix = PrefixCache(block=ps, pages=self.pages)

        def step_paged(params, tokens, positions, pool, page_idx):
            sub = gather_pages(cfg, pool, page_idx)
            logits, sub2 = decode_step(params, tokens, positions, cfg, sub)
            return logits, scatter_pages(cfg, pool, sub2, page_idx)

        self._step = jax.jit(step_paged)

    @property
    def paged(self) -> bool:
        return self.page_size is not None

    def reserve(self, lr: LiveRequest) -> bool:
        """Reserve decode capacity for lr without copying KV into it yet.

        The disagg fleet reserves at transfer *start* so a handoff never
        arrives at a full decode server; `attach` completes the copy. Slot
        mode charges the token budget (prefix hits granted back as a
        credit); paged mode builds the page table, linking shared prefix
        pages instead of drawing fresh ones.
        """
        r = lr.req
        if self.pages is None:
            need = r.input_len + r.output_len
            # prefix-cache credit: tokens matched at submit time share KV
            # with an earlier prompt and don't charge the budget
            slot = self.alloc.alloc(need, credit=r.prefix_hit_tokens)
            if slot is None:
                return False
            lr.slot = slot
            return True
        slot = self.alloc.alloc(0)
        if slot is None:
            return False
        shared = tuple(lr.shared_pages or ())
        if lr.kv_src is not self:
            # a foreign pool's page ids mean nothing here; the seeded
            # prefill cache carries the head bytes, attach writes them
            shared = ()
        need = min(r.input_len + r.output_len, self.ecfg.max_len)
        table = self.pages.alloc_table(slot, need, shared)
        if table is None:
            self.alloc.release(slot)
            return False
        lr.slot = slot
        lr.page_table = table
        return True

    def attach(self, lr: LiveRequest) -> None:
        """Copy lr's prefill cache (1, max_len) into its reserved slot/pages."""
        if self.pages is None:
            sub = jax.tree.map(lambda x: x, lr.prefill_cache)
            self.cache = scatter_slots(
                self.model.cfg, self.cache, sub, jnp.asarray([lr.slot], jnp.int32)
            )
            lr.prefill_cache = None
            return
        r = lr.req
        ps = self.page_size
        table = lr.page_table
        n_shared = len(lr.shared_pages or ())  # already live in this pool?
        if lr.kv_src is not self:
            n_shared = 0  # head bytes were seeded from another engine's pool
        if len(table) > n_shared:
            fresh = jnp.asarray(table[n_shared:], jnp.int32)
            for name, leaf in self.pool.items():
                src = lr.prefill_cache[name]  # (L, 1, max_len, ...)
                blocks = src.reshape(
                    src.shape[0], self.ecfg.max_len // ps, ps, *src.shape[3:]
                )
                self.pool[name] = leaf.at[:, fresh].set(
                    blocks[:, n_shared : len(table)]
                )
        lr.prefill_cache = None
        # index the landed prompt in the radix cache: later prompts sharing
        # this head link these pages instead of recomputing the KV
        self.prefix.assign_pages(lr.tokens[: r.input_len], table)

    def admit(self, lr: LiveRequest) -> bool:
        """Transfer prefill KV into decode capacity (the PD handoff)."""
        if not self.reserve(lr):
            return False
        self.attach(lr)
        return True

    def release(self, lr: LiveRequest) -> None:
        if self.prefix is not None:
            # drop the rid's radix pins whether or not it ever got a slot
            # (queue-stage cancels release before reserve succeeds)
            self.prefix.release(lr.req.rid)
        if lr.slot is not None:
            if self.pages is not None:
                self.pages.release_table(lr.slot)
                lr.page_table = None
            self.alloc.release(lr.slot)
            lr.slot = None

    def step(self, batch: List[LiveRequest], key) -> np.ndarray:
        """One decode step over the scheduler-chosen sub-batch."""
        ecfg = self.ecfg
        bs = _bucket(len(batch), ecfg.decode_buckets)
        toks = [lr.tokens[-1] for lr in batch] + [0] * (bs - len(batch))
        pos = [lr.req.seq_len - 1 for lr in batch] + [0] * (bs - len(batch))
        if self.pages is not None:
            p, sp = self.pages_per_req, self.scratch_page
            rows = [lr.page_table + [sp] * (p - len(lr.page_table)) for lr in batch]
            rows += [[sp] * p] * (bs - len(batch))  # pad lanes write scratch only
            logits, self.pool = self._step(
                self.params,
                jnp.asarray(toks, jnp.int32)[:, None],
                jnp.asarray(pos, jnp.int32),
                self.pool,
                jnp.asarray(rows, jnp.int32),
            )
        else:
            slots = [lr.slot for lr in batch] + [self.scratch_slot] * (bs - len(batch))
            logits, self.cache = self._step(
                self.params,
                jnp.asarray(toks, jnp.int32)[:, None],
                jnp.asarray(pos, jnp.int32),
                self.cache,
                jnp.asarray(slots, jnp.int32),
            )
        toks_out = sample(logits, temperature=ecfg.temperature, key=key)
        return np.asarray(toks_out)[: len(batch)]


class DisaggServer:
    """End-to-end disaggregated server on real JAX compute (CPU demo-scale).

    Virtual time = (wall time since start) * time_scale, so SLO arithmetic
    runs unchanged while CPU steps are orders slower than the H200 testbed.
    """

    def __init__(
        self,
        model: Model,
        params: Dict,
        ecfg: EngineConfig,
        clock: Optional[Clock] = None,
        trace: Optional["TraceRecorder"] = None,
    ):
        self.model, self.ecfg = model, ecfg
        self.clock: Clock = clock if clock is not None else MonotonicClock()
        # default trace sink for sessions built over this server (see
        # repro.obs): ServeSession picks it up via getattr, so an offline
        # `serve()` call traces without the caller threading a recorder
        self.trace = trace
        self.prefill = PrefillEngine(model, params, ecfg)
        self.decode = DecodeEngine(model, params, ecfg)
        self._init_sched_state()
        # transfer pricing shared with the simulator: one formula for both
        # the in-server admission handoff and the fleet's cross-server copy
        from repro.sim.costmodel import CalibratedCostModel  # no import cycle

        self.cost = CalibratedCostModel(
            transfer_lat=ecfg.transfer_lat,
            kv_bytes_per_token=ecfg.kv_bytes_per_token,
            transfer_bw=ecfg.transfer_bw,
        )
        self._t0 = self.clock.monotonic()
        self.last_session = None  # ServeSession of the most recent serve()

    def _init_sched_state(self) -> None:
        """(Re)build every piece of adaptive scheduling state — shared by
        construction and `reset_for_restart` so a restarted replica is
        indistinguishable from a freshly built one."""
        ecfg = self.ecfg
        # schedulers come from the shared policy registry — the same specs
        # (and the same classes) the simulator constructs from
        self.prefill_sched = make_prefill(ecfg.prefill_policy)
        analytic = lambda b, s: 1e-3 * (1 + 0.05 * b + s / 4096.0)
        self.lut = StepTimeLUT(analytic=analytic, seq_buckets=[16, 32, 64, 128, 256, 512])
        # slo_margin is a soft default: applied to policies that take it
        # (slack variants), dropped for those that don't (continuous)
        self.decode_sched = make_decode(
            ecfg.decode_policy, self.lut, slo_margin=ecfg.slo_margin
        )
        self.mu = PrefillThroughputEstimator(mu=2000.0)
        self._key = jax.random.key(0)

    # ------------------------------------------------------------------ time
    def _now(self) -> float:
        return (self.clock.monotonic() - self._t0) * self.ecfg.time_scale

    def peek_now(self) -> float:
        """Observation-free virtual now: the control plane's clock read.
        Unlike `_now` this never charges a `ManualClock.auto_step`, so a
        fleet controller may poll at any frequency without perturbing the
        replica's deterministic timing (see serving/clock.py)."""
        return (self.clock.peek() - self._t0) * self.ecfg.time_scale

    def reset_clock(self) -> None:
        """Re-zero virtual time (arrivals are relative to this origin).
        Virtual clocks re-zero *exactly* to their construction origin so
        timings are invariant to how many construction-time reads preceded
        the session."""
        if hasattr(self.clock, "reset"):
            origin = self.clock.reset()
            # pre-origin-contract clocks returned None from reset(); their
            # construction value was always 0.0
            self._t0 = 0.0 if origin is None else origin
        else:
            self._t0 = self.clock.monotonic()

    # --------------------------------------------------------------- restart
    def reset_for_restart(self) -> None:
        """Return the server to its just-constructed state: the live half of
        `dist/fault.py::plan_recovery`'s final step. Drops every decode slot
        (the KV is gone — survivors re-prefill restored requests), rebuilds
        the adaptive scheduler state, and re-zeroes the clock so the
        restarted replica's timing is pinnable against a fresh build."""
        ecfg = self.ecfg
        if self.decode.paged:
            # the pool, allocator, and radix cache are one consistent unit:
            # rebuild all three (the KV is gone, so are the page bindings)
            self.decode._init_paged(self.model.cfg)
        else:
            self.decode.cache = self.model.init_cache(ecfg.max_slots + 1, ecfg.max_len)
        self.decode.alloc = SlotAllocator(ecfg.max_slots, ecfg.kv_cap_tokens)
        self._init_sched_state()
        self.last_session = None
        self.reset_clock()

    # ------------------------------------------------------------------ serve
    def serve(self, requests: List[Tuple[Request, List[int]]]) -> Dict[int, List[int]]:
        """Serve (Request, prompt_tokens) pairs; returns rid -> output tokens.

        Requests arrive at req.arrival (virtual seconds). This is a thin
        offline wrapper over `ServeSession.run` (repro.serving.session).
        With the default unbounded `EngineConfig.admission_queue_depth`
        nothing is ever shed; if a depth IS configured, shed requests end
        in ``Phase.FAILED`` and are absent from the returned dict — inspect
        ``self.last_session.summary()`` (kept after every serve) for the
        rejection metrics.
        """
        from repro.serving.session import ServeSession  # avoid import cycle

        for req, prompt in requests:
            if req.input_len != len(prompt):
                raise ValueError(
                    f"request rid={req.rid} declares input_len={req.input_len} "
                    f"but prompt has {len(prompt)} tokens"
                )
        session = ServeSession(self)
        self.last_session = session
        return session.run(requests)


def reference_generate(
    model: Model, params: Dict, prompt: List[int], n_new: int, max_len: int, eos: int = 1
) -> List[int]:
    """Scheduling-free greedy reference: prefill + sequential decode."""
    cfg = model.cfg
    batch = dict(inputs=jnp.asarray([prompt], jnp.int32))
    logits, _ = model.prefill(params, batch)
    cache = model.init_cache(1, max_len)
    # rebuild cache by chunk-prefilling the whole prompt at once
    logits2, cache = chunk_prefill_step(
        params,
        jnp.asarray([prompt], jnp.int32),
        jnp.asarray([0], jnp.int32),
        jnp.asarray([len(prompt)], jnp.int32),
        cfg,
        cache,
    )
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(logits2, np.float32), rtol=2e-2, atol=2e-2
    )
    out = [int(np.argmax(np.asarray(logits2[0])))]
    toks = list(prompt) + out
    for _ in range(n_new - 1):
        if out[-1] == eos or len(toks) >= max_len - 1:
            break
        lg, cache = decode_step(
            params,
            jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray([len(toks) - 1], jnp.int32),
            cfg,
            cache,
        )
        tok = int(np.argmax(np.asarray(lg[0])))
        out.append(tok)
        toks.append(tok)
    return out
