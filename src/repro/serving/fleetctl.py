"""Fleet control plane: replica failover + SLO-driven elastic autoscaling.

`FleetSession` extends `RouterSession` with the two control loops a real
serving fleet runs above its router (ROADMAP: fault tolerance; PAPER §6):

  * **Failover.** `kill_replica(i)` injects a replica death mid-flight
    (`AsyncServeSession.kill` — no goodbyes, no terminal events). The
    controller then runs the `repro.dist.fault.plan_recovery` narrative
    against the *live* session: drain (the dead stepper is gone; nothing
    new lands there), checkpoint (`SlotAllocator.snapshot` of the dead
    decode allocator — the KV bookkeeping a restore would replay),
    re-mesh (`plan_mesh` over the surviving "pods"), restart
    (`DisaggServer.reset_for_restart` rebuilds the carcass's engine
    state). Every request that was in flight on the dead replica is
    re-submitted onto a survivor as a *twin* request and its client
    stream is spliced: tokens the client already holds are skipped
    (greedy temperature-0 decoding regenerates the identical prefix), so
    the client sees exactly-once delivery with no duplicated or dropped
    tokens and the rid reaches exactly one terminal event fleet-wide.
  * **Autoscaling.** An `AutoscaleController` periodically feeds
    `repro.obs.slo.windowed_slo` output — the same windowed telemetry an
    operator's dashboard shows, never session internals — to a registered
    `AutoscalerPolicy` (`repro.policies.autoscale`: ``static``,
    ``queue-threshold``, ``slo-attainment-pid``). Scale-up builds a fresh
    replica from ``server_factory`` and warms its prefix state from the
    survivors (`PrefixCache.merge_from` on both the routing index and the
    session cache) so ``prefix-affinity`` routing treats it as a peer from
    its first request; scale-down drains the least-loaded replica and
    retires it only once idle.

Time-aware routing. Unlike `RouterSession` (which routes each submission
the moment ``submit`` is awaited — correct for open-loop parity runs),
`FleetSession` defers the routing decision until the fleet's virtual time
reaches the request's scheduled arrival, so placement sees the liveness
and load that exist *at arrival*: a replica killed at t=2 receives none of
the t>2 arrivals, and a replica scaled up at t=3 starts absorbing the
crowd immediately. Fleet time is observed with `DisaggServer.peek_now`
(observation-only: no clock auto-step, no perturbation of replica
timelines — the controller can poll as often as it likes).

Event vocabulary (`repro.obs.events`): REPLICA_DOWN / REPLICA_UP for
membership changes, RESTORE per re-homed rid (with its stream splice
point), SCALE per applied autoscaler decision. A restored rid re-emits
SUBMIT/ADMIT on its new replica; the windowed queue gauge keeps the dead
replica's undecremented admissions — deliberately, since a standing
post-kill gauge is exactly the evidence ``queue-threshold`` should scale
up on. See docs/OPERATORS.md for the operator-facing runbook.
"""
from __future__ import annotations

import asyncio
import heapq
from typing import (
    Any,
    AsyncIterator,
    Dict,
    List,
    Optional,
    Sequence,
    Tuple,
    Union,
)

from repro.core.request import TERMINAL_PHASES, Phase, Request
from repro.dist.fault import POD_CHIPS, FleetState, plan_recovery
from repro.obs.events import EventType, TraceRecorder
from repro.obs.slo import windowed_slo
from repro.policies import PolicySpec, make_autoscaler
from repro.serving.engine import DisaggServer
from repro.serving.frontend import _EOS, AsyncServeSession, RequestHandle
from repro.serving.prefixcache import DEFAULT_PREFIX_BLOCK, PrefixCache
from repro.serving.router import ReplicaState, RouterSession
from repro.serving.session import FROM_CONFIG


class FleetHandle:
    """The client's view of one request submitted to a `FleetSession`.

    Same surface as `repro.serving.frontend.RequestHandle` (``admitted`` /
    ``stream`` / ``result`` / ``cancel`` / ``cancel_reason``), but decoupled
    from any single replica: a background *pump* task forwards tokens from
    whichever replica currently owns the request, and failover re-points the
    pump at the survivor without the client noticing. ``delivered`` counts
    tokens actually handed to this queue — the stream splice point a restore
    must skip past.
    """

    def __init__(self, fleet: "FleetSession", request: Request, buffer: int):
        self._fleet = fleet
        self.request = request
        # mirror RequestHandle's reserved slots: final token + EOS must land
        # even when the advertised buffer is full
        self._queue: asyncio.Queue = asyncio.Queue(maxsize=buffer + 2)
        self._admit_event = asyncio.Event()
        self._accepted: Optional[bool] = None
        self._closed = False
        self.cancel_reason: Optional[str] = None
        self.delivered = 0  # tokens put into this queue (client-visible)
        # tokens harvested from a dead replica's buffer, owed to the client
        # before the survivor's stream resumes
        self._pending: List[int] = []

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def tokens(self) -> List[int]:
        """Tokens produced so far, from whichever replica owns the rid."""
        return self._fleet.outputs.get(self.rid, [])

    async def admitted(self) -> bool:
        await self._admit_event.wait()
        return bool(self._accepted)

    async def stream(self) -> AsyncIterator[int]:
        """Yield tokens until the request finishes — across failovers.

        A shed request yields nothing; leaving early cancels the request
        on whichever replica currently owns it.
        """
        if not await self.admitted():
            return
        try:
            while True:
                item = await self._queue.get()
                if item is _EOS:
                    break
                yield item
        finally:
            self.cancel()  # no-op once the request is terminal

    async def result(self) -> List[int]:
        """Drain the stream; returns exactly the tokens delivered to this
        client (the no-duplication/no-drop guarantee is on this list)."""
        out: List[int] = []
        async for tok in self.stream():
            out.append(tok)
        return out

    def cancel(self) -> None:
        """Withdraw the request (idempotent; no-op after DONE/FAILED)."""
        if self.request.phase in TERMINAL_PHASES:
            return
        while not self._queue.empty():  # wake a pump parked on a full buffer
            try:
                self._queue.get_nowait()
            except asyncio.QueueEmpty:  # pragma: no cover - single-threaded
                break
        self._fleet.cancel(self.rid)

    # ---- fleet-side plumbing (pump / controller only) --------------------
    def _resolve(self, accepted: bool) -> None:
        if self._accepted is not None:  # idempotent across failovers
            return
        self._accepted = accepted
        self._admit_event.set()
        if not accepted:
            self._close_now()

    def _close_now(self) -> None:
        if self._closed:
            return
        self._closed = True
        while not self._queue.empty():
            self._queue.get_nowait()
        self._queue.put_nowait(_EOS)

    async def _finish(self) -> None:
        if self._closed:
            return
        self._closed = True
        await self._queue.put(_EOS)


class _FleetIntent:
    """A fleet submission waiting for its routing moment."""

    __slots__ = ("at", "seq", "request", "prompt", "handle", "cancelled")

    def __init__(self, at: float, seq: int, request: Request,
                 prompt: List[int], handle: FleetHandle):
        self.at, self.seq = at, seq
        self.request, self.prompt, self.handle = request, prompt, handle
        self.cancelled = False

    def __lt__(self, other: "_FleetIntent") -> bool:
        return (self.at, self.seq) < (other.at, other.seq)


class AutoscaleController:
    """Telemetry-in, membership-out: the autoscaling decision loop.

    Every ``interval`` virtual seconds it computes `windowed_slo` over the
    fleet's shared event stream and asks its `AutoscalerPolicy` for a target
    replica count. The target is clamped to ``[n_min, n_max]`` and applied
    at most one replica per tick (scale thrash is worse than a slow ramp).
    The controller never reads replica/session internals — only the event
    stream — so any policy that works here works on an offline trace too.
    """

    def __init__(
        self,
        fleet: "FleetSession",
        policy: Union[str, PolicySpec] = "static",
        interval: float = 0.5,
        window: float = 0.5,
        n_min: int = 1,
        n_max: int = 8,
    ):
        if interval < 0:
            raise ValueError(f"autoscale interval must be >= 0, got {interval}")
        if window <= 0:
            raise ValueError(f"slo window must be > 0, got {window}")
        if not 1 <= n_min <= n_max:
            raise ValueError(f"need 1 <= n_min <= n_max, got [{n_min}, {n_max}]")
        self.fleet = fleet
        self.policy = make_autoscaler(policy)
        self.interval = float(interval)
        self.window = float(window)
        self.n_min = int(n_min)
        self.n_max = int(n_max)
        self._next_eval = self.interval
        self.decisions: List[Dict[str, Any]] = []

    async def maybe_tick(self, now: float) -> None:
        if self.interval <= 0 or now < self._next_eval:
            return
        self._next_eval = (int(now / self.interval) + 1) * self.interval
        slo = windowed_slo(self.fleet.trace.events, self.window)
        n_live = self.fleet.n_live
        target = int(self.policy.decide(slo, n_live, self.n_min, self.n_max))
        target = max(self.n_min, min(self.n_max, target))
        if target == n_live:
            return
        action = "up" if target > n_live else "down"
        last = slo["windows"][-1] if slo["windows"] else {}
        evidence = dict(
            n_windows=slo["n_windows"],
            queue_depth_max=last.get("queue_depth_max", 0),
            queue_depth_last=last.get("queue_depth_last", 0),
            e2e=last.get("e2e", 0.0),
            done=last.get("done", 0),
            shed=last.get("shed", 0),
        )
        if action == "up":
            applied = await self.fleet._scale_up(now)
        else:
            applied = self.fleet._begin_scale_down(now)
        self.decisions.append(
            dict(t=now, policy=self.policy.name, action=action,
                 applied=applied, n_before=n_live, n_target=target)
        )
        self.fleet.trace.emit(
            EventType.SCALE, now, pool="fleet",
            policy=self.policy.name, action=action, applied=applied,
            n_before=n_live, n_after=self.fleet.n_live, evidence=evidence,
        )


class FleetSession(RouterSession):
    """`RouterSession` + failover + elastic autoscaling (module docstring).

    Extra parameters over `RouterSession`:

    autoscaler          AutoscalerPolicy spec (name / (name, kwargs) / dict)
    n_min, n_max        live-replica bounds the controller may move between
    autoscale_interval  virtual seconds between autoscaler evaluations
                        (0 disables evaluation; kill_schedule still fires)
    slo_window          window (virtual s) for the telemetry the policy sees
    kill_schedule       iterable of ``(t, replica_index)`` fault injections,
                        fired when fleet time first reaches ``t``
    server_factory      zero-arg callable building a fresh `DisaggServer`
                        for scale-up (None: scale-up decisions are recorded
                        but not applied)
    """

    def __init__(
        self,
        servers: Sequence[DisaggServer],
        policy: Union[str, PolicySpec] = "round-robin",
        autoscaler: Union[str, PolicySpec] = "static",
        n_min: int = 1,
        n_max: int = 8,
        autoscale_interval: float = 0.5,
        slo_window: float = 0.5,
        kill_schedule: Sequence[Tuple[float, int]] = (),
        server_factory: Optional[Any] = None,
        max_queue_depth: Any = FROM_CONFIG,
        tenant_queue_depth: Any = FROM_CONFIG,
        stream_buffer: int = 16,
        backpressure: str = "block",
        prefix_block: int = DEFAULT_PREFIX_BLOCK,
        prefix_cache_blocks: Optional[int] = None,
        trace: Optional[TraceRecorder] = None,
    ):
        # the control plane runs ON the telemetry: a fleet always records
        super().__init__(
            servers,
            policy=policy,
            max_queue_depth=max_queue_depth,
            tenant_queue_depth=tenant_queue_depth,
            stream_buffer=stream_buffer,
            backpressure=backpressure,
            prefix_block=prefix_block,
            prefix_cache_blocks=prefix_cache_blocks,
            trace=trace if trace is not None else TraceRecorder(),
        )
        self.server_factory = server_factory
        self.stream_buffer = stream_buffer
        self._prefix_cache_blocks = prefix_cache_blocks
        # kwargs a scale-up replica's frontend is built with
        self._fe_kwargs = dict(
            max_queue_depth=max_queue_depth,
            tenant_queue_depth=tenant_queue_depth,
            stream_buffer=stream_buffer,
            backpressure=backpressure,
        )
        self.controller = AutoscaleController(
            self, policy=autoscaler, interval=autoscale_interval,
            window=slo_window, n_min=n_min, n_max=n_max,
        )
        self._kills: List[Tuple[float, int]] = sorted(
            (float(t), int(i)) for t, i in kill_schedule
        )
        self.kills_skipped: List[Tuple[float, int]] = []
        self._virtual = all(
            hasattr(rep.frontend.session.server.clock, "advance")
            for rep in self.replicas
        )

        self._seq = 0
        self._pending: List[_FleetIntent] = []  # heap: (at, seq)
        self._unrouted: Dict[int, _FleetIntent] = {}
        self._fleet_handles: Dict[int, FleetHandle] = {}
        self._pumps: Dict[int, asyncio.Task] = {}
        self._old_pumps: List[asyncio.Task] = []
        self._draining_idx: set = set()
        self._ctl: Optional[asyncio.Task] = None

        self.recoveries: List[Dict[str, Any]] = []
        self.kill_count = 0
        self.restore_count = 0
        self.reschedule_count = 0
        self.scale_ups = 0
        self.scale_downs = 0
        self.preroute_cancelled = 0

    # ------------------------------------------------------------ liveness
    @property
    def n_live(self) -> int:
        return sum(1 for r in self.replicas if r.alive and not r.draining)

    def _fleet_now(self) -> float:
        """Observation-only fleet time: the furthest live replica clock.
        `peek_now` never auto-steps, so polling here cannot perturb any
        replica's timeline."""
        ts = [
            rep.frontend.session.server.peek_now()
            for rep in self.replicas
            if rep.alive
        ]
        return max(ts) if ts else 0.0

    def _fleet_idle(self) -> bool:
        """True when no live replica has admitted work or queued intents —
        the next thing that can happen in virtual time is a future fleet
        intent, so the router may dispatch it early and let the owning
        replica idle-advance to its arrival."""
        for rep in self.replicas:
            if not rep.alive:
                continue
            fe = rep.frontend
            if fe.session.has_work or fe._scheduled or fe._submit_intents:
                return False
        return True

    # ----------------------------------------------------------- lifecycle
    def start(self) -> None:
        super().start()
        self._ctl = asyncio.get_running_loop().create_task(
            self._control_loop(), name="fleet-controller"
        )

    async def drain(self) -> None:
        """Route every pending fleet intent, drain the replicas (the
        controller keeps running, so kills/scale decisions scheduled inside
        the workload still fire mid-drain), then stop the controller and
        settle every pump. Kill-schedule entries the run never reached are
        recorded in ``kills_skipped``."""
        while self._pending:
            if self._ctl is not None and self._ctl.done():
                self._ctl.result()  # surface a controller crash
            await asyncio.sleep(0)
        await super().drain()
        await self._stop_controller(surface=True)
        self.kills_skipped.extend(self._kills)
        self._kills.clear()
        pumps = [t for t in self._pumps.values() if not t.done()]
        if pumps:
            await asyncio.gather(*pumps)

    async def aclose(self) -> None:
        await self._stop_controller(surface=False)
        for task in list(self._pumps.values()) + self._old_pumps:
            task.cancel()
        await asyncio.gather(
            *self._pumps.values(), *self._old_pumps, return_exceptions=True
        )
        for intent in self._unrouted.values():
            intent.cancelled = True
            intent.handle.cancel_reason = intent.handle.cancel_reason or "client"
            intent.handle._resolve(False)
        self._pending.clear()
        self._unrouted.clear()
        await super().aclose()
        for fh in self._fleet_handles.values():
            fh._close_now()

    async def _stop_controller(self, surface: bool) -> None:
        ctl, self._ctl = self._ctl, None
        if ctl is None:
            return
        if ctl.done():
            if surface:
                ctl.result()
            return
        ctl.cancel()
        try:
            await ctl
        except asyncio.CancelledError:
            pass

    # -------------------------------------------------------------- submit
    async def submit(  # type: ignore[override]
        self, request: Request, prompt: Sequence[int], at: Optional[float] = None
    ) -> FleetHandle:
        """Accept a request into the fleet; routing happens when fleet time
        reaches ``at`` (None: next controller pass), so placement sees the
        replica set as it exists at arrival — not at submission."""
        if self._ctl is None:
            raise RuntimeError("fleet not started (use `async with` or start())")
        if request.input_len != len(prompt):
            raise ValueError(
                f"request rid={request.rid} declares input_len={request.input_len} "
                f"but prompt has {len(prompt)} tokens"
            )
        fh = FleetHandle(self, request, self.stream_buffer)
        intent = _FleetIntent(
            float("-inf") if at is None else at, self._seq, request,
            list(prompt), fh,
        )
        self._seq += 1
        heapq.heappush(self._pending, intent)
        self._unrouted[request.rid] = intent
        self._fleet_handles[request.rid] = fh
        return fh

    def cancel(self, rid: int) -> bool:
        """Withdraw a request wherever it currently lives: still waiting for
        its routing moment (terminates here, CANCEL stage="pre-route"), or
        on whichever replica owns it."""
        intent = self._unrouted.get(rid)
        if intent is not None:
            if intent.cancelled:
                return True
            intent.cancelled = True
            del self._unrouted[rid]
            req = intent.request
            if req.phase not in TERMINAL_PHASES:
                req.phase = Phase.CANCELLED
                self.preroute_cancelled += 1
                # same SUBMIT+CANCEL pair the frontend's pre-admission path
                # emits, stamped with the declared arrival (no clock read)
                self.trace.emit(
                    EventType.SUBMIT, req.arrival, rid=req.rid,
                    tenant=req.tenant, pool="fleet", arrival=req.arrival,
                    input_len=req.input_len, output_len=req.output_len,
                    slo_ttft=req.slo.ttft, slo_tpot=req.slo.tpot,
                    slo_class=req.slo_class,
                )
                self.trace.emit(
                    EventType.CANCEL, req.arrival, rid=req.rid,
                    tenant=req.tenant, pool="fleet", stage="pre-route",
                )
            intent.handle.cancel_reason = "client"
            intent.handle._resolve(False)
            return True
        return super().cancel(rid)

    # ------------------------------------------------------------- routing
    async def _route_intent(self, intent: _FleetIntent) -> None:
        if intent.cancelled:
            return
        fh = intent.handle
        at = None if intent.at == float("-inf") else intent.at
        try:
            inner = await RouterSession.submit(self, intent.request, intent.prompt, at=at)
        except RuntimeError:
            # no live replica to route to: fail the stream, don't kill the
            # controller — the fleet may grow again
            fh.cancel_reason = fh.cancel_reason or "error"
            fh._resolve(False)
            self._unrouted.pop(intent.request.rid, None)
            return
        self._unrouted.pop(intent.request.rid, None)
        self._bind_pump(fh, inner, skip=0)

    def _bind_pump(
        self,
        fh: FleetHandle,
        inner: RequestHandle,
        skip: int,
        orig: Optional[Request] = None,
    ) -> None:
        task = asyncio.get_running_loop().create_task(
            self._pump(fh, inner, skip, orig), name=f"fleet-pump-{fh.rid}"
        )
        self._pumps[fh.rid] = task

    async def _pump(
        self, fh: FleetHandle, inner: RequestHandle, skip: int,
        orig: Optional[Request],
    ) -> None:
        """Forward one replica handle into the fleet handle. ``skip`` tokens
        of the inner stream are dropped (the client already holds them from
        before a failover — greedy decoding regenerates the identical
        prefix); tokens harvested from the dead replica's buffer
        (``fh._pending``) are delivered first."""
        ok = await inner.admitted()
        fh._resolve(ok)
        if ok:
            while fh._pending:
                tok = fh._pending.pop(0)
                await fh._queue.put(tok)
                fh.delivered += 1
            seen = 0
            async for tok in inner.stream():
                seen += 1
                if seen <= skip:
                    continue
                await fh._queue.put(tok)
                fh.delivered += 1
        fh.cancel_reason = fh.cancel_reason or inner.cancel_reason
        if orig is not None:
            self._mirror_terminal(orig, inner.request, skip)
        await fh._finish()

    @staticmethod
    def _mirror_terminal(orig: Request, twin: Request, skip: int) -> None:
        """Copy the twin's terminal fate back onto the original `Request`
        object the client (and any harness bookkeeping) still holds. Token
        times splice at the failover point: the first ``skip`` stamps are
        from the dead replica's timeline, the rest from the survivor's."""
        orig.phase = twin.phase
        orig.done_time = twin.done_time
        orig.n_generated = twin.n_generated
        orig.n_decoded = twin.n_decoded
        orig.prefilled_tokens = twin.prefilled_tokens
        orig.token_times = list(orig.token_times[:skip]) + list(twin.token_times[skip:])
        if orig.first_token_time is None:
            orig.first_token_time = twin.first_token_time
        if orig.prefill_finish is None:
            orig.prefill_finish = twin.prefill_finish
        orig.restarts += 1

    # ------------------------------------------------------------- control
    async def _control_loop(self) -> None:
        try:
            while True:
                now = self._fleet_now()
                progressed = False
                while self._kills and self._kills[0][0] <= now:
                    _t, idx = self._kills.pop(0)
                    if 0 <= idx < len(self.replicas) and self.replicas[idx].alive:
                        await self.kill_replica(idx)
                    progressed = True
                while self._pending and self._pending[0].cancelled:
                    heapq.heappop(self._pending)
                while self._pending and self._pending[0].at <= now:
                    await self._route_intent(heapq.heappop(self._pending))
                    progressed = True
                    while self._pending and self._pending[0].cancelled:
                        heapq.heappop(self._pending)
                if not progressed and self._pending and self._fleet_idle():
                    # nothing is running anywhere and the next arrival is in
                    # the future: dispatch it and let its replica idle-step
                    # forward — this is what advances fleet time through gaps
                    await self._route_intent(heapq.heappop(self._pending))
                    progressed = True
                await self.controller.maybe_tick(now)
                await self._reap_draining(now)
                # virtual fleets spin on the event loop (time is advanced by
                # the steppers); wall-clock fleets must actually sleep
                await asyncio.sleep(0 if self._virtual else 0.005)
        except asyncio.CancelledError:
            raise
        except BaseException:
            for intent in self._unrouted.values():
                if intent.handle._accepted is None:
                    intent.cancelled = True
                    intent.handle.cancel_reason = (
                        intent.handle.cancel_reason or "error"
                    )
                    intent.handle._resolve(False)
            self._pending.clear()
            self._unrouted.clear()
            raise

    # ------------------------------------------------------------ failover
    async def kill_replica(self, index: int, reason: str = "killed") -> Dict[str, Any]:
        """Inject a replica death and fail its in-flight work over.

        Runs the `plan_recovery` sequence against the live session: the
        dead stepper is cancelled mid-step (drain — nothing else lands
        there), its `SlotAllocator` is snapshotted (checkpoint), the
        surviving replicas are re-meshed (`plan_mesh` narrative), the
        carcass's engine state is rebuilt (`reset_for_restart`), and every
        request that was in flight is restored onto a survivor with its
        client stream spliced at the delivered-token count. Returns the
        recovery record (also appended to ``recoveries``)."""
        rep = self.replicas[index]
        if not rep.alive:
            raise RuntimeError(f"replica {index} is already dead")
        if self.n_live <= 1 and not rep.draining:
            raise RuntimeError(
                "refusing to kill the last live replica: nowhere to restore "
                "its in-flight requests"
            )
        rep.alive = False
        rep.draining = False
        self._draining_idx.discard(index)
        fe = rep.frontend
        sess = fe.session
        server = sess.server

        # -- drain + checkpoint: stop the stepper where the crash found it,
        #    snapshot the KV bookkeeping a restore would replay
        await fe.kill()
        snap = server.decode.alloc.snapshot()
        t_kill = server.peek_now()

        # -- harvest: admitted work (queue/transfer/decode) and submissions
        #    the dead stepper never admitted
        victims = [lr for lr in sess.queue + sess.waiting_adm + sess.active]
        unadmitted = [
            it for it in list(fe._scheduled) + list(fe._submit_intents)
            if not it.cancelled and it.handle._accepted is None
        ]
        lost_cancels = list(fe._cancel_intents)

        restored: List[int] = []
        rescheduled: List[int] = []
        plans: List[Tuple[Request, List[int], Optional[float], int, Optional[Request]]] = []

        m = sess.metrics
        for lr in victims:
            orig = lr.req
            rid = orig.rid
            fh = self._fleet_handles.get(rid)
            if fh is None:  # not fleet-submitted (defensive): drop silently
                continue
            await self._retire_pump(rid)
            inner = fe._handles.get(rid)
            if inner is not None:  # salvage generated-but-undelivered tokens
                while not inner._queue.empty():
                    item = inner._queue.get_nowait()
                    if item is not _EOS:
                        fh._pending.append(item)
            delivered = fh.delivered + len(fh._pending)
            # the books move with the request: un-count it from the dead
            # session so fleet aggregates don't double-count the twin
            m.submitted -= 1
            m.accepted -= 1
            tcount = m.submitted_by_tenant.get(orig.tenant, 0) - 1
            if tcount > 0:
                m.submitted_by_tenant[orig.tenant] = tcount
            else:
                m.submitted_by_tenant.pop(orig.tenant, None)
            if orig in sess.requests:
                sess.requests.remove(orig)
            sess.outputs.pop(rid, None)
            twin = Request(
                rid=orig.rid, arrival=orig.arrival,
                input_len=orig.input_len, output_len=orig.output_len,
                slo=orig.slo, tenant=orig.tenant, slo_class=orig.slo_class,
                prefix_group=orig.prefix_group, prefix_frac=orig.prefix_frac,
            )
            prompt = list(lr.tokens[: orig.input_len])
            plans.append((twin, prompt, t_kill, delivered, orig))
            restored.append(rid)
        for it in unadmitted:
            rid = it.request.rid
            if rid not in self._fleet_handles:
                continue
            await self._retire_pump(rid)
            at = None if it.at == float("-inf") else it.at
            plans.append((it.request, list(it.prompt), at, 0, None))
            rescheduled.append(rid)

        # -- clear the carcass: undo the router's books for harvested rids,
        #    wipe frontend/session state so nothing double-terminates later
        for rid in restored + rescheduled:
            self._handles.pop(rid, None)
            self._owner.pop(rid, None)
            rep.assigned -= 1
        harvested = set(restored) | set(rescheduled)
        rep.routed = [r for r in rep.routed if r.rid not in harvested]
        fe._handles.clear()
        fe._scheduled.clear()
        fe._submit_intents.clear()
        fe._cancel_intents.clear()
        sess.queue.clear()
        sess.waiting_adm.clear()
        sess.active.clear()

        # -- re-mesh narrative + restart: the dead "pod" reports 0 healthy
        #    chips; survivors re-plan, the carcass's engine state is rebuilt
        pods = tuple(POD_CHIPS if r.alive else 0 for r in self.replicas)
        plan = plan_recovery(FleetState(pods=pods))
        server.reset_for_restart()

        # -- restore: twins re-route through the normal policy path, each
        #    pump spliced at its client's delivered-token count
        for twin, prompt, at, delivered, orig in plans:
            fh = self._fleet_handles[twin.rid]
            try:
                inner = await RouterSession.submit(self, twin, prompt, at=at)
            except RuntimeError:  # pragma: no cover - guarded by n_live check
                fh.cancel_reason = fh.cancel_reason or "error"
                fh._resolve(False)
                fh._close_now()
                continue
            self._bind_pump(fh, inner, skip=delivered, orig=orig)
            self.trace.emit(
                EventType.RESTORE, t_kill, rid=twin.rid, tenant=twin.tenant,
                pool=f"replica:{self._owner[twin.rid]}",
                src=index, dst=self._owner[twin.rid], delivered=delivered,
                stage=("scheduled" if orig is None else orig.phase.value),
            )
        for rid in lost_cancels:  # client cancels the dead stepper never saw
            self.cancel(rid)

        record = dict(
            replica=index, t=t_kill, reason=reason,
            snapshot=dict(
                slots_live=len(snap["live_tokens"]),
                free_slots=len(snap["free"]),
                kv_tokens=sum(snap["live_tokens"].values()),
            ),
            restored=restored, rescheduled=rescheduled,
            mesh=dict(
                shape=list(plan.mesh.shape),
                axes=list(plan.mesh.axes),
                dropped_pods=list(plan.mesh.dropped_pods),
            ),
            steps=[list(s) for s in plan.steps]
            + [["restore", f"re-prefill {len(restored)} in-flight + "
                           f"{len(rescheduled)} queued request(s) on survivors"]],
        )
        self.recoveries.append(record)
        self.kill_count += 1
        self.restore_count += len(restored)
        self.reschedule_count += len(rescheduled)
        self.trace.emit(
            EventType.REPLICA_DOWN, t_kill, pool=f"replica:{index}",
            reason=reason, restored=len(restored),
            rescheduled=len(rescheduled),
            slots_live=len(snap["live_tokens"]),
        )
        return record

    async def _retire_pump(self, rid: int) -> None:
        task = self._pumps.pop(rid, None)
        if task is None:
            return
        if not task.done():
            task.cancel()
            try:
                await task
            except asyncio.CancelledError:
                pass
        self._old_pumps.append(task)

    # ----------------------------------------------------------- scaling
    async def _scale_up(self, now: float) -> bool:
        if self.server_factory is None:
            return False
        # un-drain first: a replica on its way out is cheaper to keep than
        # a cold one is to build
        for rep in self.replicas:
            if rep.alive and rep.draining:
                rep.draining = False
                self._draining_idx.discard(rep.index)
                return True
        srv = self.server_factory()
        idx = len(self.replicas)
        fe = AsyncServeSession(
            srv,
            prefix_cache=PrefixCache(
                block=self.prefix_block, max_blocks=self._prefix_cache_blocks
            ),
            trace=self.trace,
            trace_label=f"replica:{idx}",
            **self._fe_kwargs,
        )
        rep = ReplicaState(
            index=idx,
            frontend=fe,
            route_index=PrefixCache(
                block=self.prefix_block, max_blocks=self._prefix_cache_blocks
            ),
        )
        # warm start: inherit the survivors' prefix state so affinity
        # routing treats the newcomer as a peer from its first request
        warmed = 0
        for donor in self.replicas:
            if not donor.alive:
                continue
            warmed += rep.route_index.merge_from(donor.route_index)
            cache = donor.frontend.session.prefix_cache
            if cache is not None and fe.session.prefix_cache is not None:
                fe.session.prefix_cache.merge_from(cache)
        self.replicas.append(rep)
        fe.start()
        self.scale_ups += 1
        self.trace.emit(
            EventType.REPLICA_UP, now, pool=f"replica:{idx}",
            warmed_blocks=warmed, reason="scale-up",
        )
        return True

    def _begin_scale_down(self, now: float) -> bool:
        cands = [r for r in self.replicas if r.alive and not r.draining]
        if len(cands) <= self.controller.n_min:
            return False
        victim = min(cands, key=lambda r: (r.in_flight, -r.index))
        victim.draining = True
        self._draining_idx.add(victim.index)
        return True

    async def _reap_draining(self, now: float) -> None:
        for idx in sorted(self._draining_idx):
            rep = self.replicas[idx]
            fe = rep.frontend
            if fe.session.has_work or fe._scheduled or fe._submit_intents:
                continue  # still working; check again next tick
            await fe.drain()
            rep.alive = False
            rep.draining = False
            self._draining_idx.discard(idx)
            self.scale_downs += 1
            self.trace.emit(
                EventType.REPLICA_DOWN, now, pool=f"replica:{idx}",
                reason="scale-down", restored=0, rescheduled=0, slots_live=0,
            )

    # ------------------------------------------------------------- metrics
    @property
    def outputs(self) -> Dict[int, List[int]]:
        """rid -> output tokens; the owning replica's copy wins (after a
        failover both the carcass and the survivor may know a rid)."""
        merged: Dict[int, List[int]] = {}
        for rep in self.replicas:
            for rid, toks in rep.frontend.session.outputs.items():
                merged[rid] = list(toks)
        for rid, idx in self._owner.items():
            toks = self.replicas[idx].frontend.session.outputs.get(rid)
            if toks is not None:
                merged[rid] = list(toks)
        return merged

    def summary(self) -> Dict[str, Any]:
        out = super().summary()
        out["fleet"] = dict(
            autoscaler=self.controller.policy.name,
            n_min=self.controller.n_min,
            n_max=self.controller.n_max,
            autoscale_interval=self.controller.interval,
            slo_window=self.controller.window,
            replicas_total=len(self.replicas),
            replicas_live=self.n_live,
            kills=self.kill_count,
            kills_skipped=[list(k) for k in self.kills_skipped],
            restored=self.restore_count,
            rescheduled=self.reschedule_count,
            scale_ups=self.scale_ups,
            scale_downs=self.scale_downs,
            preroute_cancelled=self.preroute_cancelled,
            decisions=list(self.controller.decisions),
            recoveries=list(self.recoveries),
        )
        return out
