"""Fleet-level P/D disaggregation: prefill pool + decode pool + KV handoff.

`RouterSession` (repro.serving.router) fronts N *whole* replicas; this
module splits the fleet the way production disaggregated systems do (SGLang
PD disaggregation, SNIPPETS.md): a **prefill pool** of servers that only run
chunked prefill, a **decode pool** that only decodes, and an explicit
**KV handoff** stage between them —

    submit -> [deflection decision] -> prefill worker queue
           -> chunked prefill (prefill pool, or a decode worker if deflected)
           -> handoff queue -> bounded in-flight transfer window
              (decode slot reserved at transfer START; KV priced by
               CostModel.transfer_time = lat + tokens*bytes/bw; the real
               slot-to-slot copy lands at completion)
           -> decode worker active set -> tokens stream out

Handoff state machine (DESIGN.md §disagg): a prefill-finished request is
*queued* the instant its prompt completes; it *starts* when the in-flight
window has room AND a decode slot reserves (destination = least-loaded
decode worker; the prefilling worker itself for deflected requests); it
*completes* — KV scattered into the reserved slot, request decoding — once
`transfer_time` has elapsed on the fleet clock. Starts that fail (window
full or no slot) park in the handoff queue and retry every step: handoff
backpressure is a first-class scheduling signal (`HandoffMetrics.queue_*`).

**Prefill deflection** (Microsoft's load-aware prefill deflection,
PAPERS.md) is the policy axis the split unlocks: under prefill-pool
pressure, short prompts prefill directly on an underutilized decode server
— their handoff is then local (no cross-server copy). Policies live in the
fourth registry side (`repro.policies.deflection`; `@register_deflection`)
and consume *this* session as their fleet view.

`DisaggSession` duck-types `ServeSession` (submit/step/cancel/outputs/
metrics/summary + a `server` facade), so `DisaggFleetSession` reuses the
whole `AsyncServeSession` machinery — streaming handles, backpressure,
cancellation, open-loop replay — via frontend session injection.

Determinism: every server in the fleet shares ONE clock (enforced), the
fleet's `_now()`/`reset_clock()` read it exactly like a single
`DisaggServer`, and `step()` mirrors `ServeSession.step` read-for-read per
worker — so a 1P:1D fleet under `never` deflection reproduces a single
replica's TTFT/TPOT bit-for-bit on a `ManualClock` (pinned in
tests/test_disagg.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.core.request import Phase, Request
from repro.obs.events import EventType, TraceRecorder
from repro.policies import PolicySpec, make_deflection
from repro.serving.engine import DisaggServer, LiveRequest
from repro.serving.frontend import AsyncServeSession
from repro.serving.session import FROM_CONFIG, SessionMetrics

TokenCallback = Callable[[Request, int, float], None]


@dataclass
class HandoffMetrics:
    """KV-handoff counters for one fleet session's lifetime."""

    transfers_started: int = 0
    transfers_completed: int = 0
    transfers_cancelled: int = 0  # cancelled while queued or in flight
    cross_transfers: int = 0  # prefill-pool -> decode-pool copies
    local_transfers: int = 0  # deflected: KV already on the decode server
    bytes_transferred: float = 0.0  # input_len * kv_bytes_per_token, started
    queue_wait_total: float = 0.0  # virtual seconds spent queued-not-started
    queue_wait_max: float = 0.0
    queued_peak: int = 0  # high-water mark of the handoff queue
    inflight_peak: int = 0  # high-water mark of the transfer window


@dataclass
class PoolWorker:
    """One server's slot in a pool, plus the fleet's live view of it.

    The view properties are what deflection policies consult — pure reads
    of request/allocator state, no clock access, so decisions replay.
    """

    server: DisaggServer
    label: str  # "prefill:0" / "decode:1" — the pool label in reports
    pool: str  # "prefill" | "decode"
    queue: List[LiveRequest] = field(default_factory=list)  # awaiting/in prefill
    active: List[LiveRequest] = field(default_factory=list)  # decoding (decode pool)
    assigned: int = 0  # lifetime placements, the idle-pool round-robin tiebreak

    @property
    def queue_len(self) -> int:
        return len(self.queue)

    @property
    def pending_prefill_tokens(self) -> int:
        """Prompt tokens queued on this worker whose prefill hasn't run —
        the backlog signal deflection watermarks trigger on."""
        return sum(lr.req.remaining_prefill_tokens for lr in self.queue)

    @property
    def mu(self) -> float:
        """The server's online prefill-throughput estimate (tokens/s)."""
        return self.server.mu.mu

    @property
    def free_slots(self) -> int:
        return len(self.server.decode.alloc.free)


@dataclass
class _Transfer:
    """One KV handoff moving through queued -> in-flight -> attached."""

    lr: LiveRequest
    src: PoolWorker
    queued_at: float
    dst: Optional[PoolWorker] = None  # chosen (and slot reserved) at start
    started_at: Optional[float] = None
    ready_at: Optional[float] = None  # started_at + cost.transfer_time


class _FleetClock:
    """The fleet's single-server disguise for timing purposes.

    `AsyncServeSession`'s stepper and the session metrics only need
    ``server.clock`` / ``_now()`` / ``reset_clock()``; this facade provides
    them over the *shared* fleet clock so the whole fleet advances one
    timeline. All servers must share one Clock instance — per-server clocks
    would let `monotonic()` auto-steps diverge between pools and destroy
    replay determinism.
    """

    def __init__(self, servers: Sequence[DisaggServer]):
        if not servers:
            raise ValueError("disagg fleet needs at least one server")
        if len({id(s.clock) for s in servers}) != 1:
            raise ValueError(
                "disagg fleet servers must share one Clock instance; "
                "per-server clocks desynchronize the pools"
            )
        self.servers = list(servers)
        self.clock = self.servers[0].clock
        self.ecfg = self.servers[0].ecfg
        self.cost = self.servers[0].cost
        self.reset_clock()

    def _now(self) -> float:
        return self.servers[0]._now()

    def reset_clock(self) -> None:
        """Re-zero virtual time for the whole fleet via ONE reset — N
        per-server resets of a wall clock would skew the pools by the gap
        between reads. The single `DisaggServer.reset_clock` carries the
        virtual-clock exact-zero rule; the others just copy its origin."""
        self.servers[0].reset_clock()
        for s in self.servers[1:]:
            s._t0 = self.servers[0]._t0


class DisaggSession:
    """The fleet-level serve loop over a prefill pool and a decode pool.

    Duck-types `ServeSession` — same submit/step/cancel/outputs/metrics/
    summary surface, same per-step clock discipline — over P+D servers.
    Also *is* the fleet view deflection policies receive: ``prefill_pool``,
    ``decode_pool`` (PoolWorker views) and ``decode_has_capacity()``.
    """

    def __init__(
        self,
        prefill_servers: Sequence[DisaggServer],
        decode_servers: Sequence[DisaggServer],
        deflection: Union[str, PolicySpec] = "never",
        max_queue_depth: Any = FROM_CONFIG,
        tenant_queue_depth: Any = FROM_CONFIG,
        on_token: Optional[TokenCallback] = None,
        max_inflight_transfers: int = 8,
        trace: Optional[TraceRecorder] = None,
        trace_label: str = "fleet",
    ):
        if not prefill_servers or not decode_servers:
            raise ValueError("disagg fleet needs >= 1 prefill and >= 1 decode server")
        if max_inflight_transfers < 1:
            raise ValueError("max_inflight_transfers must be >= 1")
        self.server = _FleetClock(list(prefill_servers) + list(decode_servers))
        self.ecfg = self.server.ecfg
        if max_queue_depth is FROM_CONFIG:
            max_queue_depth = self.ecfg.admission_queue_depth
        self.max_queue_depth = max_queue_depth  # None = unbounded, per worker
        if tenant_queue_depth is FROM_CONFIG:
            tenant_queue_depth = self.ecfg.tenant_queue_depth
        self.tenant_queue_depth = tenant_queue_depth
        self.prefill_pool = [
            PoolWorker(s, f"prefill:{i}", "prefill")
            for i, s in enumerate(prefill_servers)
        ]
        self.decode_pool = [
            PoolWorker(s, f"decode:{i}", "decode")
            for i, s in enumerate(decode_servers)
        ]
        self.deflect = make_deflection(deflection)
        self.max_inflight_transfers = max_inflight_transfers
        self.pending_handoff: List[_Transfer] = []  # queued, not yet started
        self.inflight: List[_Transfer] = []  # started, KV on the wire

        self.outputs: Dict[int, List[int]] = {}
        self.requests: List[Request] = []
        self.metrics = SessionMetrics()
        self.handoff = HandoffMetrics()
        self.deflected = 0
        self.deflected_rids: List[int] = []
        self._deflected_by_dst: Dict[str, int] = {}
        # rid -> worker label: where prefill ran / where decode ran (the
        # pool labels per-pool attainment groups by)
        self._prefill_worker_of: Dict[int, str] = {}
        self._decode_worker_of: Dict[int, str] = {}
        # paged fleets: rid -> the decode worker whose radix cache matched
        # the prompt at submit. The request's shared pages live in THAT
        # worker's pool, so its handoff must land there (enforced in
        # `_start_transfer`) and its pins release there (`_finish_cancel`
        # or decode completion).
        self._kv_dst: Dict[int, PoolWorker] = {}
        self.on_token = on_token
        self._callbacks: Dict[int, TokenCallback] = {}
        # observability (repro.obs): one recorder shared by every worker,
        # each event stamped with the emitting worker's pool label
        # ("prefill:0" / "decode:1"); session-level events (SUBMIT) carry
        # `trace_label`. None = tracing off.
        self.trace = trace
        self.trace_label = trace_label

    # --------------------------------------------------------- fleet view
    @property
    def paged(self) -> bool:
        return self.decode_pool[0].server.decode.paged

    def decode_has_capacity(self) -> bool:
        """Some decode worker can absorb a deflected prefill: free decode
        slots exceed its already-deflected backlog (the natural watermark —
        deflection must not out-queue the capacity that attracted it)."""
        return any(w.free_slots > w.queue_len for w in self.decode_pool)

    def pool_labels(self) -> Dict[str, Dict[int, str]]:
        """rid -> worker label, for the prefill and decode legs (deflected
        requests carry a decode-pool label in both)."""
        return dict(
            prefill=dict(self._prefill_worker_of),
            decode=dict(self._decode_worker_of),
        )

    def _pick_prefill_worker(self, request: Request) -> PoolWorker:
        """Join-shortest-token-backlog with a least-assigned tiebreak.

        Backlog (not a mu-scaled ETA) is the primary key: per-server mu
        estimates drift apart as one worker sees more traffic, and an
        ETA key then routes *everything* to the historically faster
        worker. The ``assigned`` tiebreak round-robins the common case of
        a fully drained pool instead of letting the label tiebreak pin
        every idle-time arrival to worker 0."""
        return min(
            self.prefill_pool,
            key=lambda w: (
                w.pending_prefill_tokens,
                w.queue_len,
                w.assigned,
                w.label,
            ),
        )

    def _pick_deflection_worker(self) -> PoolWorker:
        """Underutilized decode worker for a deflected prefill: most spare
        slots after its current load, label tiebreak."""
        return min(
            self.decode_pool,
            key=lambda w: (self._dst_load(w) - w.free_slots, self._dst_load(w), w.label),
        )

    def _dst_load(self, w: PoolWorker) -> int:
        """Requests a handoff to `w` would queue behind: decoding + deflected
        prefills + transfers already bound for it."""
        return (
            len(w.active)
            + len(w.queue)
            + sum(1 for tr in self.inflight if tr.dst is w)
        )

    # ------------------------------------------------------------- submit
    def submit(
        self,
        request: Request,
        prompt: Sequence[int],
        on_token: Optional[TokenCallback] = None,
    ) -> bool:
        """Place a request on a worker (deflection decides which pool);
        returns False and sheds it when the chosen worker's queue is at
        ``max_queue_depth`` or the tenant quota is hit — the same per-queue
        admission rule `ServeSession.submit` applies to its single queue."""
        if request.input_len != len(prompt):
            raise ValueError(
                f"request rid={request.rid} declares input_len={request.input_len} "
                f"but prompt has {len(prompt)} tokens; the SLO/urgency arithmetic "
                f"is computed from input_len, so they must agree"
            )
        m = self.metrics
        m.submitted += 1
        m._bump(m.submitted_by_tenant, request.tenant)
        self.requests.append(request)
        tr = self.trace
        if tr is not None:
            # t = declared arrival — submit paths never read the fleet clock
            tr.emit(
                EventType.SUBMIT, request.arrival, rid=request.rid,
                tenant=request.tenant, pool=self.trace_label,
                arrival=request.arrival, input_len=request.input_len,
                output_len=request.output_len, slo_ttft=request.slo.ttft,
                slo_tpot=request.slo.tpot, slo_class=request.slo_class,
            )
        # paged fleets probe every decode worker's radix cache for the
        # longest live-page prefix BEFORE placement: a hit fixes the
        # request's decode destination (the pages are physically in that
        # worker's pool) and lets its prefill skip the cached head. Pure
        # peeks — no insertion, no clock reads — so shed requests leave no
        # trace. First-worker wins ties, keeping placement deterministic.
        kv_dst: Optional[PoolWorker] = None
        kv_hit = 0
        kv_pages = ()
        if self.paged:
            for w in self.decode_pool:
                hit, pages = w.server.decode.prefix.match_pages(prompt)
                if hit > kv_hit:
                    kv_dst, kv_hit, kv_pages = w, hit, pages
        deflected = self.deflect.decide(self, request, prompt)
        if deflected and kv_dst is not None:
            # deflect onto the worker that already holds the prefix pages:
            # prefill AND decode both stay local to the KV
            target = kv_dst
        elif deflected:
            target = self._pick_deflection_worker()
        else:
            target = self._pick_prefill_worker(request)
        shed_global = (
            self.max_queue_depth is not None
            and target.queue_len >= self.max_queue_depth
        )
        shed_tenant = False
        if not shed_global and self.tenant_queue_depth is not None:
            queued = sum(1 for lr in target.queue if lr.req.tenant == request.tenant)
            shed_tenant = queued >= self.tenant_queue_depth
        if shed_global or shed_tenant:
            request.phase = Phase.FAILED
            m.rejected += 1
            if shed_global:
                m.rejected_global += 1
            else:
                m.rejected_tenant += 1
            m.rejected_rids.append(request.rid)
            m._bump(m.rejected_by_tenant, request.tenant)
            if tr is not None:
                tr.emit(
                    EventType.SHED, request.arrival, rid=request.rid,
                    tenant=request.tenant, pool=target.label,
                    scope="global" if shed_global else "tenant",
                    queue_depth=target.queue_len,
                )
            return False
        m.accepted += 1
        if tr is not None and deflected:
            # DEFLECT precedes ADMIT: the placement decision is made before
            # the worker's queue accepts the request (only accepted requests
            # count as deflected — a deflected-then-shed one does not)
            tr.emit(
                EventType.DEFLECT, request.arrival, rid=request.rid,
                tenant=request.tenant, pool=target.label,
                policy=self.deflect.name,
            )
        lr = LiveRequest(req=request, tokens=list(prompt))
        if self.paged:
            m.prefix_lookups += 1
            block = self.decode_pool[0].server.decode.page_size
            m.prefix_lookup_tokens += (len(prompt) // block) * block
            if kv_dst is not None:
                # pin the matched path on the owning worker until the
                # request leaves the fleet, and carry the shared pages so
                # prefill seeds from (and reserve links into) its pool
                kv_dst.server.decode.prefix.pin_match(prompt, request.rid)
                request.prefix_hit_tokens = kv_hit
                request.prefix_cached_tokens = kv_hit
                lr.shared_pages = kv_pages
                lr.kv_src = kv_dst.server.decode
                self._kv_dst[request.rid] = kv_dst
                m.prefix_hits += 1
                m.prefix_hit_tokens += kv_hit
                m.prefix_cached_tokens += kv_hit
        target.queue.append(lr)
        target.assigned += 1
        self._prefill_worker_of[request.rid] = target.label
        if tr is not None:
            tr.emit(
                EventType.ADMIT, request.arrival, rid=request.rid,
                tenant=request.tenant, pool=target.label,
                queue_depth=target.queue_len,
            )
        if deflected:
            self.deflected += 1
            self.deflected_rids.append(request.rid)
            d = self._deflected_by_dst
            d[target.label] = d.get(target.label, 0) + 1
        if on_token is not None:
            self._callbacks[request.rid] = on_token
        return True

    # ------------------------------------------------------------- cancel
    def cancel(self, rid: int) -> bool:
        """Withdraw an in-flight request wherever it lives: a worker's
        prefill queue, the handoff queue, the in-flight transfer window
        (the reserved decode slot is released), or a decode active set.
        Terminal in ``Phase.CANCELLED``; no slot leaks in either pool."""
        for w in (*self.prefill_pool, *self.decode_pool):
            for lr in w.queue:
                if lr.req.rid == rid:
                    w.queue.remove(lr)
                    lr.prefill_cache = None
                    self._finish_cancel(lr, "queue", w.label)
                    return True
            for lr in w.active:
                if lr.req.rid == rid:
                    w.active.remove(lr)
                    slot = lr.slot
                    w.server.decode.release(lr)
                    self._finish_cancel(lr, "decode", w.label, slot=slot)
                    return True
        for tr in self.pending_handoff:
            if tr.lr.req.rid == rid:
                self.pending_handoff.remove(tr)
                tr.lr.prefill_cache = None
                self.handoff.transfers_cancelled += 1
                self._finish_cancel(tr.lr, "handoff", tr.src.label)
                return True
        for tr in self.inflight:
            if tr.lr.req.rid == rid:
                self.inflight.remove(tr)
                tr.dst.server.decode.release(tr.lr)  # reserved at start
                tr.lr.prefill_cache = None
                self.handoff.transfers_cancelled += 1
                self._finish_cancel(tr.lr, "inflight", tr.dst.label)
                return True
        return False

    def _finish_cancel(
        self, lr: LiveRequest, stage: str, pool: str, slot: Optional[int] = None
    ) -> None:
        # queue/handoff-stage cancels never reach decode.release on the
        # pinning worker, so the radix unpin happens here (idempotent)
        kv_dst = self._kv_dst.pop(lr.req.rid, None)
        if kv_dst is not None:
            kv_dst.server.decode.prefix.release(lr.req.rid)
        lr.req.phase = Phase.CANCELLED
        lr.req.done_time = self.server._now()
        self._callbacks.pop(lr.req.rid, None)
        m = self.metrics
        m.cancelled += 1
        m.cancelled_rids.append(lr.req.rid)
        m._bump(m.cancelled_by_tenant, lr.req.tenant)
        if self.trace is not None:
            # every cancel path funnels here, so a cancel — mid-handoff
            # included — emits exactly one terminal event
            self.trace.emit(
                EventType.CANCEL, lr.req.done_time, rid=lr.req.rid,
                tenant=lr.req.tenant, pool=pool, slot=slot, stage=stage,
            )

    # -------------------------------------------------------------- state
    @property
    def has_work(self) -> bool:
        return bool(
            self.pending_handoff
            or self.inflight
            or any(w.queue or w.active for w in (*self.prefill_pool, *self.decode_pool))
        )

    def _emit(self, req: Request, tok: int, t: float) -> None:
        self.outputs.setdefault(req.rid, []).append(tok)
        cb = self._callbacks.get(req.rid)
        if cb is not None:
            cb(req, tok, t)
        if self.on_token is not None:
            self.on_token(req, tok, t)

    # ------------------------------------------------------------ handoff
    def _start_transfer(self, tr: _Transfer, at: float) -> bool:
        """Try to move a queued handoff into the in-flight window: needs
        window room and a reserved decode slot. Destination is the least
        loaded decode worker (the prefilling worker itself when deflected —
        its KV never crosses servers)."""
        if len(self.inflight) >= self.max_inflight_transfers:
            return False
        kv_dst = self._kv_dst.get(tr.lr.req.rid)
        if kv_dst is not None:
            # shared prefix pages are physically in this worker's pool;
            # landing anywhere else would orphan them (a foreign pool can't
            # link them). Park and retry rather than fall through.
            candidates = [kv_dst]
        elif tr.src.pool == "decode":
            candidates = [tr.src]
        else:
            candidates = sorted(
                self.decode_pool, key=lambda w: (self._dst_load(w), w.label)
            )
        for dst in candidates:
            if dst.server.decode.reserve(tr.lr):
                break
        else:
            return False
        tr.dst = dst
        tr.started_at = at
        # cached-prefix pages never cross the wire — only computed tokens
        # are priced (prefix_cached_tokens is 0 on non-paged fleets)
        tr.ready_at = at + tr.src.server.cost.transfer_time(
            tr.lr.req.input_len - tr.lr.req.prefix_cached_tokens
        )
        tr.lr.transfer_ready_at = tr.ready_at
        self.inflight.append(tr)
        self._decode_worker_of[tr.lr.req.rid] = dst.label
        if self.trace is not None:
            self.trace.emit(
                EventType.HANDOFF_START, at, rid=tr.lr.req.rid,
                tenant=tr.lr.req.tenant, pool=dst.label, slot=tr.lr.slot,
                src=tr.src.label, ready_at=tr.ready_at,
            )
        h = self.handoff
        h.transfers_started += 1
        if dst is tr.src:
            h.local_transfers += 1
        else:
            h.cross_transfers += 1
        h.bytes_transferred += (
            tr.lr.req.input_len - tr.lr.req.prefix_cached_tokens
        ) * self.ecfg.kv_bytes_per_token
        wait = max(0.0, at - tr.queued_at)
        h.queue_wait_total += wait
        h.queue_wait_max = max(h.queue_wait_max, wait)
        h.inflight_peak = max(h.inflight_peak, len(self.inflight))
        return True

    def _enqueue_handoff(self, lr: LiveRequest, src: PoolWorker, at: float) -> None:
        tr = _Transfer(lr=lr, src=src, queued_at=at)
        if self.trace is not None:
            self.trace.emit(
                EventType.HANDOFF_QUEUED, at, rid=lr.req.rid,
                tenant=lr.req.tenant, pool=src.label,
            )
        if not self._start_transfer(tr, at):
            self.pending_handoff.append(tr)
            self.handoff.queued_peak = max(
                self.handoff.queued_peak, len(self.pending_handoff)
            )

    # ---------------------------------------------------------------- step
    def step(self) -> List[int]:
        """Advance the fleet one round; returns rids completed this round.

        Per-worker stage bodies mirror `ServeSession.step` *read-for-read*
        (same clock calls in the same order per worker) — the basis of the
        1P:1D bit-parity contract. Do not add or reorder clock reads here
        without updating that test.
        """
        ecfg = self.ecfg
        clock = self.server.clock
        completed: List[int] = []
        now = self.server._now()

        # ---- prefill stage: the prefill pool, then deflected prompts on
        # decode workers (a deflected prefill runs the same chunked loop,
        # just on a decode server's prefill engine)
        trc = self.trace
        for w in (*self.prefill_pool, *self.decode_pool):
            if not w.queue:
                continue
            srv = w.server
            pq = [lr.req for lr in w.queue]
            sel = srv.prefill_sched.select(pq, now, srv.mu.mu, ecfg.chunk_size)
            t0 = clock.monotonic()
            total = 0
            for req, take in sel:
                lr = next(l for l in w.queue if l.req is req)
                if trc is not None and req.prefilled_tokens == 0:
                    trc.emit(
                        EventType.PREFILL_START, now, rid=req.rid,
                        tenant=req.tenant, pool=w.label, take=take,
                    )
                logits = srv.prefill.run_chunk(lr, take)
                total += take
                if logits is not None:
                    fin = srv._now()
                    req.prefill_finish = fin
                    req.first_token_time = fin
                    tok = int(np.argmax(logits))
                    lr.tokens.append(tok)
                    req.n_generated = 1
                    req.token_times.append(fin)
                    req.phase = Phase.TRANSFER
                    w.queue.remove(lr)
                    if trc is not None:
                        trc.emit(
                            EventType.PREFILL_END, fin, rid=req.rid,
                            tenant=req.tenant, pool=w.label,
                            queue_depth=len(w.queue),
                        )
                    self._enqueue_handoff(lr, w, fin)
                    if trc is not None:
                        trc.emit(
                            EventType.TOKEN, fin, rid=req.rid,
                            tenant=req.tenant, pool=w.label,
                        )
                    self._emit(req, tok, fin)
            elapsed = (clock.monotonic() - t0) * ecfg.time_scale
            self.metrics.prefill_computed_tokens += total
            if total:
                srv.mu.update(total, max(elapsed, 1e-9))

        # ---- handoff completions (the fleet's admission sweep) ----------
        admitted = False
        for tr in list(self.inflight):
            if now < tr.ready_at:
                continue  # KV still on the wire
            self.inflight.remove(tr)
            lr = tr.lr
            tr.dst.server.decode.attach(lr)  # the real slot-to-slot copy
            lr.req.phase = Phase.DECODE
            lr.req.decode_start = self.server._now()
            tr.dst.active.append(lr)
            self.handoff.transfers_completed += 1
            admitted = True
            if trc is not None:
                trc.emit(
                    EventType.HANDOFF_ATTACH, lr.req.decode_start,
                    rid=lr.req.rid, tenant=lr.req.tenant,
                    pool=tr.dst.label, slot=lr.slot,
                )
        # retry queued handoffs (window room / slots may have freed); each
        # may target a different worker, so later entries aren't blocked by
        # an earlier one waiting on a different destination
        for tr in list(self.pending_handoff):
            if self._start_transfer(tr, now):
                self.pending_handoff.remove(tr)

        # ---- decode stage ------------------------------------------------
        for w in self.decode_pool:
            if not w.active:
                continue
            srv = w.server
            batch_reqs, _ = srv.decode_sched.select(
                [l.req for l in w.active], srv._now()
            )
            batch = [l for l in w.active if l.req in batch_reqs]
            srv._key, sub = jax.random.split(srv._key)
            t0 = clock.monotonic()
            toks = srv.decode.step(batch, sub)
            step_t = (clock.monotonic() - t0) * ecfg.time_scale
            tend = srv._now()
            srv.decode_sched.observe([l.req for l in batch], step_t)
            if trc is not None and batch:
                trc.emit(
                    EventType.DECODE_STEP, tend, pool=w.label,
                    batch=len(batch), step_time=step_t,
                    active=len(w.active),
                    tpot_budget=min(l.req.slo.tpot for l in batch),
                )
            for lr, tok in zip(batch, toks, strict=True):
                r = lr.req
                tok = int(tok)
                lr.tokens.append(tok)
                r.n_generated += 1
                r.n_decoded += 1
                r.token_times.append(tend)
                if trc is not None:
                    trc.emit(
                        EventType.TOKEN, tend, rid=r.rid, tenant=r.tenant,
                        pool=w.label, slot=lr.slot,
                    )
                self._emit(r, tok, tend)
                done = (
                    tok == ecfg.eos_token
                    or r.n_generated >= r.output_len
                    or r.seq_len >= ecfg.max_len - 1
                )
                if done:
                    r.phase = Phase.DONE
                    r.done_time = tend
                    slot = lr.slot
                    srv.decode.release(lr)  # also unpins r.rid's radix hold
                    self._kv_dst.pop(r.rid, None)
                    w.active.remove(lr)
                    self.metrics.completed += 1
                    self.metrics._bump(self.metrics.completed_by_tenant, r.tenant)
                    completed.append(r.rid)
                    if trc is not None:
                        trc.emit(
                            EventType.DONE, tend, rid=r.rid, tenant=r.tenant,
                            pool=w.label, slot=slot, n_generated=r.n_generated,
                        )

        # when the only remaining work is KV on the wire, nudge the clock
        # toward the earliest ready_at — same rule as `ServeSession.step`
        if (
            (self.inflight or self.pending_handoff)
            and not admitted
            and not any(w.queue or w.active for w in (*self.prefill_pool, *self.decode_pool))
        ):
            nxt = min((tr.ready_at for tr in self.inflight), default=now)
            clock.sleep(min(0.001, max(0.0, nxt - self.server._now())))
        return completed

    # ------------------------------------------------------------- metrics
    def handoff_summary(self) -> Dict[str, Any]:
        h = self.handoff
        return dict(
            transfers_started=h.transfers_started,
            transfers_completed=h.transfers_completed,
            transfers_cancelled=h.transfers_cancelled,
            cross_transfers=h.cross_transfers,
            local_transfers=h.local_transfers,
            inflight_cap=self.max_inflight_transfers,
            bytes_transferred=h.bytes_transferred,
            queue_wait_total=h.queue_wait_total,
            queue_wait_max=h.queue_wait_max,
            queued_peak=h.queued_peak,
            inflight_peak=h.inflight_peak,
            by_dst={
                w.label: sum(
                    1 for lbl in self._decode_worker_of.values() if lbl == w.label
                )
                for w in self.decode_pool
            },
        )

    def deflection_summary(self) -> Dict[str, Any]:
        return dict(
            policy=self.deflect.name,
            deflected=self.deflected,
            deflected_rids=list(self.deflected_rids),
            by_dst=dict(self._deflected_by_dst),
        )

    def summary(self) -> Dict[str, Any]:
        """`ServeSession.summary`'s keys (so every downstream consumer of a
        session summary keeps working) plus the fleet blocks: ``pools``,
        ``handoff``, ``deflection``, and per-request pool labels."""
        labels = self.pool_labels()
        per = [
            dict(
                rid=r.rid,
                tenant=r.tenant,
                slo_class=r.slo_class,
                phase=r.phase.value,
                ttft=r.ttft(),
                mean_tpot=r.mean_tpot(),
                meets_e2e=r.meets_e2e() if r.phase == Phase.DONE else False,
                prefill_pool=labels["prefill"].get(r.rid),
                decode_pool=labels["decode"].get(r.rid),
            )
            for r in self.requests
        ]
        m = self.metrics
        return dict(
            submitted=m.submitted,
            accepted=m.accepted,
            rejected=m.rejected,
            rejected_global=m.rejected_global,
            rejected_tenant=m.rejected_tenant,
            completed=m.completed,
            cancelled=m.cancelled,
            backpressure_shed=m.backpressure_shed,
            rejected_rids=list(m.rejected_rids),
            cancelled_rids=list(m.cancelled_rids),
            submitted_by_tenant=dict(m.submitted_by_tenant),
            rejected_by_tenant=dict(m.rejected_by_tenant),
            completed_by_tenant=dict(m.completed_by_tenant),
            cancelled_by_tenant=dict(m.cancelled_by_tenant),
            prefix=dict(
                lookups=m.prefix_lookups,
                hits=m.prefix_hits,
                hit_tokens=m.prefix_hit_tokens,
                lookup_tokens=m.prefix_lookup_tokens,
                hit_rate=(
                    m.prefix_hit_tokens / m.prefix_lookup_tokens
                    if m.prefix_lookup_tokens
                    else 0.0
                ),
            ),
            prefix_cached_tokens=m.prefix_cached_tokens,
            prefill_computed_tokens=m.prefill_computed_tokens,
            pages=self._pages_summary(),
            pools=dict(
                prefill=len(self.prefill_pool), decode=len(self.decode_pool)
            ),
            handoff=self.handoff_summary(),
            deflection=self.deflection_summary(),
            requests=per,
        )

    def _pages_summary(self) -> Optional[Dict[str, Any]]:
        """Decode-pool-wide page accounting (None on non-paged fleets)."""
        if not self.paged:
            return None
        allocs = [w.server.decode for w in self.decode_pool]
        return dict(
            page_size=allocs[0].pages.page_size,
            total=sum(d.pages.n_pages for d in allocs),
            free=sum(d.pages.free_pages for d in allocs),
            used_tokens=sum(d.pages.used_tokens for d in allocs),
            shared_links=sum(d.pages.shared_links for d in allocs),
            pressure_evictions=sum(d.pages.pressure_evictions for d in allocs),
            cached_blocks=sum(len(d.prefix) for d in allocs),
        )


class DisaggFleetSession(AsyncServeSession):
    """Async streaming frontend over a `DisaggSession` core.

    The entire client surface — ``submit -> RequestHandle``, streaming,
    cancellation, ``replay``, ``drain``/``aclose`` — is inherited from
    `AsyncServeSession` via session injection; only construction differs:
    two server pools, a deflection policy, and the transfer window bound.
    """

    def __init__(
        self,
        prefill_servers: Sequence[DisaggServer],
        decode_servers: Sequence[DisaggServer],
        deflection: Union[str, PolicySpec] = "never",
        max_queue_depth: Any = FROM_CONFIG,
        tenant_queue_depth: Any = FROM_CONFIG,
        stream_buffer: int = 16,
        backpressure: str = "block",
        idle_wait: float = 0.001,
        max_inflight_transfers: int = 8,
        trace: Optional[TraceRecorder] = None,
    ):
        core = DisaggSession(
            prefill_servers,
            decode_servers,
            deflection=deflection,
            max_queue_depth=max_queue_depth,
            tenant_queue_depth=tenant_queue_depth,
            max_inflight_transfers=max_inflight_transfers,
            trace=trace,
        )
        super().__init__(
            core.server,  # unused when a session is injected; kept for repr
            stream_buffer=stream_buffer,
            backpressure=backpressure,
            idle_wait=idle_wait,
            session=core,
        )

    @property
    def core(self) -> DisaggSession:
        return self.session
