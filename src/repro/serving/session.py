"""Streaming serve session: the non-blocking face of `DisaggServer`.

The monolithic ``DisaggServer.serve(requests)`` loop is now a thin driver
over this class. A `ServeSession` owns the in-flight request state and
exposes the three primitives an online frontend needs:

    submit(request, prompt)   admit (or shed) a request, at any time
    step()                    advance prefill + admission + decode one round
    on_token callbacks        per-request and session-wide streaming hooks
    cancel(rid)               client disconnect: reclaim the request's
                              queue entry / decode slot, Phase.CANCELLED

(`repro.serving.frontend.AsyncServeSession` builds the online asyncio
frontend — streaming handles, backpressure, open-loop replay — on exactly
these primitives; see DESIGN.md §frontend.)

Admission control: ``max_queue_depth`` bounds the prefill queue, and
``tenant_queue_depth`` additionally bounds how many queued requests any one
tenant may hold (so a single tenant's burst can't monopolize admission). A
submit that would exceed either bound is *shed* — the request is marked
``Phase.FAILED``, counted in the session metrics (``rejected`` /
``rejected_rids``, plus the ``*_by_tenant`` breakdowns), and ``submit``
returns False. The defaults (``FROM_CONFIG``) inherit
``EngineConfig.admission_queue_depth`` / ``tenant_queue_depth``; pass
``None`` for explicitly unbounded admission regardless of the config (the
config's own defaults are unbounded, which preserves historical ``serve()``
behavior).

``submit`` validates that ``request.input_len == len(prompt)`` and raises
``ValueError`` on mismatch: the declared length feeds the SLO/urgency
arithmetic the caller set up, so silently reassigning it (as the old serve
loop did) desyncs scheduling from the caller's intent.

See DESIGN.md §session.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.request import Phase, Request
from repro.obs.events import EventType, TraceRecorder
from repro.serving.engine import DisaggServer, LiveRequest
from repro.serving.prefixcache import PrefixCache

# on_token(request, token, t_virtual) — called as each token is produced.
TokenCallback = Callable[[Request, int, float], None]

# Sentinel: inherit EngineConfig.admission_queue_depth. Distinct from None,
# which always means unbounded — so a caller can request an unbounded
# session over a server whose config sets a depth.
FROM_CONFIG: Any = object()


@dataclass
class SessionMetrics:
    """Counters for one session's lifetime (shedding included), with a
    per-tenant breakdown so multi-tenant quota decisions stay auditable."""

    submitted: int = 0
    accepted: int = 0
    # shed by admission control — always rejected_global + rejected_tenant
    # (kept as its own counter for schema compatibility); the split tells a
    # per-tenant shed report "fleet full" apart from "quota hit"
    rejected: int = 0
    rejected_global: int = 0  # global queue bound (max_queue_depth) hit
    rejected_tenant: int = 0  # per-tenant quota (tenant_queue_depth) hit
    completed: int = 0
    cancelled: int = 0  # withdrawn by the client (disconnect / cancel())
    # cancellations forced by the async frontend's backpressure policy when a
    # slow consumer's buffer overflows ("shed" policy); a subset of `cancelled`
    backpressure_shed: int = 0
    rejected_rids: List[int] = field(default_factory=list)
    cancelled_rids: List[int] = field(default_factory=list)
    submitted_by_tenant: Dict[str, int] = field(default_factory=dict)
    rejected_by_tenant: Dict[str, int] = field(default_factory=dict)
    completed_by_tenant: Dict[str, int] = field(default_factory=dict)
    cancelled_by_tenant: Dict[str, int] = field(default_factory=dict)
    # prefix-cache admission accounting (zero unless the session was built
    # with a PrefixCache); hit tokens are also granted to the SlotAllocator
    # as a KV budget credit — see serving/prefixcache.py
    prefix_lookups: int = 0
    prefix_hits: int = 0
    prefix_hit_tokens: int = 0
    prefix_lookup_tokens: int = 0
    # paged engines only: hit tokens whose KV was *linked* (prefill skipped
    # them entirely) — always <= prefix_hit_tokens, equal on paged sessions
    prefix_cached_tokens: int = 0
    # prompt tokens the prefill engine actually computed; on a paged session
    # this undershoots the admitted prompt mass by exactly the cached tokens
    # (the "reuse is real" invariant, pinned in tests/test_paged_kv.py)
    prefill_computed_tokens: int = 0

    def _bump(self, table: Dict[str, int], tenant: str) -> None:
        table[tenant] = table.get(tenant, 0) + 1


class ServeSession:
    """Incremental serving over a `DisaggServer`'s engines.

    The session never blocks: ``step()`` runs at most one prefill
    scheduling round, one admission sweep, and one decode step, then
    returns the rids that completed. Interleave ``submit``/``step`` freely
    — that is the whole point.
    """

    def __init__(
        self,
        server: DisaggServer,
        max_queue_depth: Optional[int] = FROM_CONFIG,
        on_token: Optional[TokenCallback] = None,
        tenant_queue_depth: Optional[int] = FROM_CONFIG,
        prefix_cache: Optional["PrefixCache"] = None,
        trace: Optional[TraceRecorder] = None,
        trace_label: str = "engine:0",
    ):
        self.server = server
        # observability (repro.obs): None = tracing off, the default — the
        # disabled path is a single `is not None` test per emission point.
        # Emissions only ever reuse timestamps this session already read
        # from the injected clock, so enabling a recorder cannot perturb
        # ManualClock schedules (pinned in tests/test_obs.py).
        self.trace = trace if trace is not None else getattr(server, "trace", None)
        self.trace_label = trace_label
        self.ecfg = server.ecfg
        if max_queue_depth is FROM_CONFIG:
            max_queue_depth = server.ecfg.admission_queue_depth
        self.max_queue_depth = max_queue_depth  # None = unbounded
        if tenant_queue_depth is FROM_CONFIG:
            tenant_queue_depth = server.ecfg.tenant_queue_depth
        self.tenant_queue_depth = tenant_queue_depth  # None = no per-tenant quota
        # prefix-cache-aware admission: every admitted prompt is matched then
        # inserted; matched tokens become the request's prefix_hit_tokens
        # (KV budget credit + hit metrics). None = no prefix awareness. On a
        # paged engine the cache is the engine-owned page-mapped radix trie
        # — hits link live KV pages and skip real compute, so any
        # accounting-only cache the caller passed is superseded.
        if server.decode.paged:
            prefix_cache = server.decode.prefix
        self.prefix_cache = prefix_cache
        self.on_token = on_token

        self.queue: List[LiveRequest] = []  # waiting for / in chunked prefill
        self.waiting_adm: List[LiveRequest] = []  # KV transfer -> decode slot
        self.active: List[LiveRequest] = []  # decoding
        self.outputs: Dict[int, List[int]] = {}
        self.requests: List[Request] = []  # every submitted request, shed too
        self.metrics = SessionMetrics()
        self._callbacks: Dict[int, TokenCallback] = {}

    # ------------------------------------------------------------- submit
    def submit(
        self,
        request: Request,
        prompt: Sequence[int],
        on_token: Optional[TokenCallback] = None,
    ) -> bool:
        """Admit a request; returns False (and sheds it) when the prefill
        queue is at ``max_queue_depth`` or the request's tenant already has
        ``tenant_queue_depth`` requests queued. Raises ValueError if the
        declared ``input_len`` does not match the prompt."""
        if request.input_len != len(prompt):
            raise ValueError(
                f"request rid={request.rid} declares input_len={request.input_len} "
                f"but prompt has {len(prompt)} tokens; the SLO/urgency arithmetic "
                f"is computed from input_len, so they must agree"
            )
        m = self.metrics
        m.submitted += 1
        m._bump(m.submitted_by_tenant, request.tenant)
        self.requests.append(request)
        tr = self.trace
        if tr is not None:
            # t = declared arrival: submission never reads the clock, and an
            # emission must not either (ManualClock.auto_step advances per
            # monotonic() read — a new read here would shift every schedule)
            tr.emit(
                EventType.SUBMIT, request.arrival, rid=request.rid,
                tenant=request.tenant, pool=self.trace_label,
                arrival=request.arrival, input_len=request.input_len,
                output_len=request.output_len, slo_ttft=request.slo.ttft,
                slo_tpot=request.slo.tpot, slo_class=request.slo_class,
            )
        shed_global = (
            self.max_queue_depth is not None and len(self.queue) >= self.max_queue_depth
        )
        shed_tenant = False
        if not shed_global and self.tenant_queue_depth is not None:
            queued = sum(1 for lr in self.queue if lr.req.tenant == request.tenant)
            shed_tenant = queued >= self.tenant_queue_depth
        if shed_global or shed_tenant:
            request.phase = Phase.FAILED
            m.rejected += 1
            if shed_global:
                m.rejected_global += 1
            else:
                m.rejected_tenant += 1
            m.rejected_rids.append(request.rid)
            m._bump(m.rejected_by_tenant, request.tenant)
            if tr is not None:
                tr.emit(
                    EventType.SHED, request.arrival, rid=request.rid,
                    tenant=request.tenant, pool=self.trace_label,
                    scope="global" if shed_global else "tenant",
                    queue_depth=len(self.queue),
                )
            return False
        m.accepted += 1
        lr = LiveRequest(req=request, tokens=list(prompt))
        prefix_kw: Dict[str, int] = {}
        if self.prefix_cache is not None:
            # admitted prompts only enter the trie: a shed prompt's KV never
            # materializes, so indexing it would advertise phantom reuse.
            # The rid pins the prompt's node path against eviction until the
            # request leaves the system (release in step()/cancel()).
            hit, eligible = self.prefix_cache.admit(prompt, rid=request.rid)
            request.prefix_hit_tokens = hit
            if self.server.decode.paged:
                # real reuse: prefill starts after the cached head, and the
                # matched pages are linked into the page table at reserve
                request.prefix_cached_tokens = hit
                m.prefix_cached_tokens += hit
                lr.shared_pages = self.prefix_cache.shared_pages(request.rid)
                lr.kv_src = self.server.decode
            m.prefix_lookups += 1
            m.prefix_lookup_tokens += eligible
            m.prefix_hit_tokens += hit
            if hit:
                m.prefix_hits += 1
            prefix_kw = dict(prefix_eligible=eligible, prefix_hit=hit)
        self.queue.append(lr)
        if tr is not None:
            tr.emit(
                EventType.ADMIT, request.arrival, rid=request.rid,
                tenant=request.tenant, pool=self.trace_label,
                queue_depth=len(self.queue), **prefix_kw,
            )
        if on_token is not None:
            self._callbacks[request.rid] = on_token
        return True

    # -------------------------------------------------------------- cancel
    def cancel(self, rid: int) -> bool:
        """Withdraw an in-flight request (client disconnect).

        Wherever the request currently lives — prefill queue, KV-transfer
        wait, or an active decode slot — it is removed, its decode slot and
        prefill cache are reclaimed immediately, and it terminates in
        ``Phase.CANCELLED`` (NOT ``FAILED``: cancellation is the client
        walking away, not an admission-control SLO miss, and the metrics
        keep the two apart). Returns False if ``rid`` is not in flight
        (already terminal, shed, or unknown) — cancelling twice is a no-op.
        """
        stages = ("queue", "transfer", "decode")
        for lst, stage in zip((self.queue, self.waiting_adm, self.active), stages, strict=True):
            for lr in lst:
                if lr.req.rid == rid:
                    lst.remove(lr)
                    slot = lr.slot
                    self.server.decode.release(lr)
                    if self.prefix_cache is not None:
                        self.prefix_cache.release(rid)  # idempotent unpin
                    lr.prefill_cache = None
                    lr.req.phase = Phase.CANCELLED
                    lr.req.done_time = self.server._now()
                    self._callbacks.pop(rid, None)
                    m = self.metrics
                    m.cancelled += 1
                    m.cancelled_rids.append(rid)
                    m._bump(m.cancelled_by_tenant, lr.req.tenant)
                    if self.trace is not None:
                        self.trace.emit(
                            EventType.CANCEL, lr.req.done_time, rid=rid,
                            tenant=lr.req.tenant, pool=self.trace_label,
                            slot=slot, stage=stage,
                        )
                    return True
        return False

    # -------------------------------------------------------------- state
    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.waiting_adm or self.active)

    def _emit(self, req: Request, tok: int, t: float) -> None:
        self.outputs.setdefault(req.rid, []).append(tok)
        cb = self._callbacks.get(req.rid)
        if cb is not None:
            cb(req, tok, t)
        if self.on_token is not None:
            self.on_token(req, tok, t)

    # ---------------------------------------------------------------- step
    def step(self) -> List[int]:
        """Advance the session one round; returns rids completed this round."""
        srv = self.server
        ecfg = self.ecfg
        clock = srv.clock
        completed: List[int] = []
        now = srv._now()

        # ---- prefill side ------------------------------------------------
        tr = self.trace
        pq = [lr.req for lr in self.queue]
        if pq:
            sel = srv.prefill_sched.select(pq, now, srv.mu.mu, ecfg.chunk_size)
            t0 = clock.monotonic()
            total = 0
            for req, take in sel:
                lr = next(l for l in self.queue if l.req is req)
                if tr is not None and req.prefilled_tokens == 0:
                    # first chunk of this request's prefill (t = the round's
                    # already-read `now`; no extra clock read)
                    tr.emit(
                        EventType.PREFILL_START, now, rid=req.rid,
                        tenant=req.tenant, pool=self.trace_label, take=take,
                    )
                logits = srv.prefill.run_chunk(lr, take)
                total += take
                if logits is not None:
                    fin = srv._now()
                    req.prefill_finish = fin
                    req.first_token_time = fin
                    tok = int(np.argmax(logits))
                    lr.tokens.append(tok)
                    req.n_generated = 1
                    req.token_times.append(fin)
                    req.phase = Phase.TRANSFER
                    # price the PD handoff with the simulator's formula: the
                    # KV is admissible only after lat + bytes/bw has elapsed.
                    # Cached-prefix tokens never cross the wire (their pages
                    # are already in the decode pool), so only the computed
                    # tail is priced; prefix_cached_tokens is 0 off-paged
                    lr.transfer_ready_at = fin + srv.cost.transfer_time(
                        req.input_len - req.prefix_cached_tokens
                    )
                    self.queue.remove(lr)
                    self.waiting_adm.append(lr)
                    if tr is not None:
                        lbl = self.trace_label
                        tr.emit(
                            EventType.PREFILL_END, fin, rid=req.rid,
                            tenant=req.tenant, pool=lbl,
                            queue_depth=len(self.queue),
                        )
                        # single-server handoff: the KV goes on the wire the
                        # moment prefill finishes (no bounded in-flight
                        # window), so QUEUED and START coincide at `fin`
                        tr.emit(
                            EventType.HANDOFF_QUEUED, fin, rid=req.rid,
                            tenant=req.tenant, pool=lbl,
                        )
                        tr.emit(
                            EventType.HANDOFF_START, fin, rid=req.rid,
                            tenant=req.tenant, pool=lbl,
                            ready_at=lr.transfer_ready_at,
                        )
                        tr.emit(
                            EventType.TOKEN, fin, rid=req.rid,
                            tenant=req.tenant, pool=lbl,
                        )
                    self._emit(req, tok, fin)
            elapsed = (clock.monotonic() - t0) * ecfg.time_scale
            self.metrics.prefill_computed_tokens += total
            if total:
                srv.mu.update(total, max(elapsed, 1e-9))

        # ---- admission (KV transfer) ------------------------------------
        admitted = False
        for lr in list(self.waiting_adm):
            if lr.transfer_ready_at is not None and now < lr.transfer_ready_at:
                continue  # KV still on the wire
            if srv.decode.admit(lr):
                lr.req.phase = Phase.DECODE
                lr.req.decode_start = srv._now()
                self.waiting_adm.remove(lr)
                self.active.append(lr)
                admitted = True
                if tr is not None:
                    tr.emit(
                        EventType.HANDOFF_ATTACH, lr.req.decode_start,
                        rid=lr.req.rid, tenant=lr.req.tenant,
                        pool=self.trace_label, slot=lr.slot,
                    )

        # ---- decode side -------------------------------------------------
        if self.active:
            batch_reqs, _ = srv.decode_sched.select(
                [l.req for l in self.active], srv._now()
            )
            batch = [l for l in self.active if l.req in batch_reqs]
            srv._key, sub = jax.random.split(srv._key)
            t0 = clock.monotonic()
            toks = srv.decode.step(batch, sub)
            step_t = (clock.monotonic() - t0) * ecfg.time_scale
            tend = srv._now()
            srv.decode_sched.observe([l.req for l in batch], step_t)
            if tr is not None and batch:
                # pool-level step record (rid = -1): the batch the decode
                # scheduler packed, the engine step time, and the tightest
                # TPOT budget in the batch — obs/slo.py's budget series
                tr.emit(
                    EventType.DECODE_STEP, tend, pool=self.trace_label,
                    batch=len(batch), step_time=step_t,
                    active=len(self.active),
                    tpot_budget=min(l.req.slo.tpot for l in batch),
                )
            for lr, tok in zip(batch, toks, strict=True):
                r = lr.req
                tok = int(tok)
                lr.tokens.append(tok)
                r.n_generated += 1
                r.n_decoded += 1
                r.token_times.append(tend)
                if tr is not None:
                    tr.emit(
                        EventType.TOKEN, tend, rid=r.rid, tenant=r.tenant,
                        pool=self.trace_label, slot=lr.slot,
                    )
                self._emit(r, tok, tend)
                done = (
                    tok == ecfg.eos_token
                    or r.n_generated >= r.output_len
                    or r.seq_len >= ecfg.max_len - 1
                )
                if done:
                    r.phase = Phase.DONE
                    r.done_time = tend
                    slot = lr.slot
                    srv.decode.release(lr)
                    if self.prefix_cache is not None:
                        self.prefix_cache.release(r.rid)  # idempotent unpin
                    self.active.remove(lr)
                    self.metrics.completed += 1
                    self.metrics._bump(self.metrics.completed_by_tenant, r.tenant)
                    completed.append(r.rid)
                    if tr is not None:
                        tr.emit(
                            EventType.DONE, tend, rid=r.rid, tenant=r.tenant,
                            pool=self.trace_label, slot=slot,
                            n_generated=r.n_generated,
                        )

        # when the only remaining work is KV on the wire, nudge the clock
        # toward the earliest transfer_ready_at so virtual-clock drivers
        # (ManualClock) make progress instead of spinning at `now`
        if self.waiting_adm and not admitted and not self.queue and not self.active:
            nxt = min((lr.transfer_ready_at or 0.0) for lr in self.waiting_adm)
            clock.sleep(min(0.001, max(0.0, nxt - srv._now())))
        return completed

    # ----------------------------------------------------------------- run
    def run(self, requests: Sequence) -> Dict[int, List[int]]:
        """Offline driver — the one canonical submit-when-arrived/step loop.

        Submits each (Request, prompt_tokens) pair once its ``arrival``
        (virtual seconds) passes, steps until drained, returns rid ->
        output tokens. ``DisaggServer.serve()`` and the CLI/demo drivers
        all call this rather than re-implementing the loop.
        """
        srv = self.server
        srv.reset_clock()
        pending = sorted(requests, key=lambda x: x[0].arrival)
        while pending or self.has_work:
            now = srv._now()
            while pending and pending[0][0].arrival <= now:
                req, prompt = pending.pop(0)
                self.submit(req, prompt)
            if self.has_work:
                self.step()
            elif pending:
                srv.clock.sleep(
                    min(0.001, max(0.0, pending[0][0].arrival - srv._now()))
                )
        return self.outputs

    # ------------------------------------------------------------- metrics
    def summary(self) -> Dict[str, Any]:
        """Session counters + per-request TTFT/TPOT (shed requests included,
        with null latency metrics)."""
        per = [
            dict(
                rid=r.rid,
                tenant=r.tenant,
                slo_class=r.slo_class,
                phase=r.phase.value,
                ttft=r.ttft(),
                mean_tpot=r.mean_tpot(),
                meets_e2e=r.meets_e2e() if r.phase == Phase.DONE else False,
            )
            for r in self.requests
        ]
        m = self.metrics
        decode = self.server.decode
        pages = None
        if decode.paged:
            pa = decode.pages
            pages = dict(
                page_size=pa.page_size,
                total=pa.n_pages,
                free=pa.free_pages,
                used_tokens=pa.used_tokens,
                shared_links=pa.shared_links,
                pressure_evictions=pa.pressure_evictions,
                cached_blocks=len(decode.prefix),
            )
        return dict(
            submitted=m.submitted,
            accepted=m.accepted,
            rejected=m.rejected,
            rejected_global=m.rejected_global,
            rejected_tenant=m.rejected_tenant,
            completed=m.completed,
            cancelled=m.cancelled,
            backpressure_shed=m.backpressure_shed,
            rejected_rids=list(m.rejected_rids),
            cancelled_rids=list(m.cancelled_rids),
            submitted_by_tenant=dict(m.submitted_by_tenant),
            rejected_by_tenant=dict(m.rejected_by_tenant),
            completed_by_tenant=dict(m.completed_by_tenant),
            cancelled_by_tenant=dict(m.cancelled_by_tenant),
            prefix=dict(
                lookups=m.prefix_lookups,
                hits=m.prefix_hits,
                hit_tokens=m.prefix_hit_tokens,
                lookup_tokens=m.prefix_lookup_tokens,
                hit_rate=(
                    m.prefix_hit_tokens / m.prefix_lookup_tokens
                    if m.prefix_lookup_tokens
                    else 0.0
                ),
            ),
            prefix_cached_tokens=m.prefix_cached_tokens,
            prefill_computed_tokens=m.prefill_computed_tokens,
            pages=pages,
            requests=per,
        )
