"""Slot-based KV cache management for the decode engine.

Host-side allocator tracks which slots are live and enforces a token-budget
admission cap (the paper's memory-bound decode regime); device-side helpers
gather/scatter per-slot cache slices so a scheduler-chosen sub-batch can be
decoded without touching delayed slots.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def cache_batch_dim(cfg: ModelConfig, leaf_name: str) -> int:
    """Axis of the slot/batch dimension for each cache leaf."""
    if cfg.family == "hybrid" and leaf_name in ("conv", "state"):
        return 2  # (Ns, per, B, ...)
    return 1  # (L, B, ...) attention / ssm / encdec


def gather_slots(cfg: ModelConfig, cache: Dict, slot_idx: jax.Array) -> Dict:
    out = {}
    for name, leaf in cache.items():
        ax = cache_batch_dim(cfg, name)
        out[name] = jnp.take(leaf, slot_idx, axis=ax)
    return out


def scatter_slots(cfg: ModelConfig, cache: Dict, sub: Dict, slot_idx: jax.Array) -> Dict:
    out = {}
    for name, leaf in cache.items():
        ax = cache_batch_dim(cfg, name)
        idx = [slice(None)] * leaf.ndim
        idx[ax] = slot_idx
        out[name] = leaf.at[tuple(idx)].set(sub[name])
    return out


@dataclass
class SlotAllocator:
    """Host bookkeeping: slot ids + KV token budget (admission control).

    ``credit`` on `can_admit`/`alloc` is the prefix-cache allowance
    (`repro.serving.prefixcache`): tokens whose KV is shared with an
    already-admitted prompt don't charge the budget, so a prefix-heavy
    workload admits deeper than its raw token mass suggests. The charge is
    clamped to >= 0 and remembered per slot, keeping ``release`` symmetric.
    """

    max_slots: int
    kv_cap_tokens: int

    free: List[int] = field(default_factory=list)
    live_tokens: Dict[int, int] = field(default_factory=dict)
    # running sum of live_tokens: can_admit runs per queued request per
    # step, so it must not re-sum the live set on every call
    _used: int = field(default=0, init=False, repr=False)

    def __post_init__(self) -> None:
        self.free = list(range(self.max_slots))[::-1]
        self._used = sum(self.live_tokens.values())

    @property
    def used_tokens(self) -> int:
        return self._used

    def can_admit(self, need_tokens: int, credit: int = 0) -> bool:
        charged = max(0, need_tokens - credit)
        return bool(self.free) and self._used + charged <= self.kv_cap_tokens

    def alloc(self, need_tokens: int, credit: int = 0) -> Optional[int]:
        if not self.can_admit(need_tokens, credit):
            return None
        slot = self.free.pop()
        charged = max(0, need_tokens - credit)
        self.live_tokens[slot] = charged
        self._used += charged
        return slot

    def release(self, slot: int) -> None:
        if slot in self.live_tokens:
            self._used -= self.live_tokens.pop(slot)
            self.free.append(slot)

    def snapshot(self) -> Dict:
        # the free list is part of the state: its ORDER decides which slot
        # ids future allocs hand out, and replay/failover determinism (the
        # router's restore path) depends on reproducing exactly that
        return dict(live_tokens=dict(self.live_tokens), free=list(self.free))

    def restore(self, snap: Dict) -> None:
        self.live_tokens = dict(snap["live_tokens"])
        self._used = sum(self.live_tokens.values())
        if "free" in snap:
            self.free = list(snap["free"])
        else:  # legacy snapshot without a free list: synthesize a canonical one
            live = set(self.live_tokens)
            self.free = [s for s in range(self.max_slots) if s not in live][::-1]
