"""Slot- and page-based KV cache management for the decode engine.

Host-side allocators track which slots/pages are live and enforce the
admission cap (the paper's memory-bound decode regime); device-side helpers
gather/scatter per-request cache slices so a scheduler-chosen sub-batch can
be decoded without touching delayed requests.

Two allocation substrates coexist:

  * `SlotAllocator` — the legacy contiguous layout: one ``max_len`` slot per
    request, a token-budget cap, prefix hits granted back as admission
    *credits* (accounting only, every token recomputed).
  * `PageAllocator` — fixed-size pages with per-request page tables and
    refcounted sharing (vLLM/sglang's paged-KV pattern). Matched prefix
    blocks map to *live* pages, so shared prompt heads are neither recomputed
    nor double-stored; `gather_pages`/`scatter_pages` are the page-table
    twins of `gather_slots`/`scatter_slots`.

See DESIGN.md §kvcache.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def cache_batch_dim(cfg: ModelConfig, leaf_name: str) -> int:
    """Axis of the slot/batch dimension for each cache leaf."""
    if cfg.family == "hybrid" and leaf_name in ("conv", "state"):
        return 2  # (Ns, per, B, ...)
    return 1  # (L, B, ...) attention / ssm / encdec


def gather_slots(cfg: ModelConfig, cache: Dict, slot_idx: jax.Array) -> Dict:
    out = {}
    for name, leaf in cache.items():
        ax = cache_batch_dim(cfg, name)
        out[name] = jnp.take(leaf, slot_idx, axis=ax)
    return out


def scatter_slots(cfg: ModelConfig, cache: Dict, sub: Dict, slot_idx: jax.Array) -> Dict:
    out = {}
    for name, leaf in cache.items():
        ax = cache_batch_dim(cfg, name)
        idx = [slice(None)] * leaf.ndim
        idx[ax] = slot_idx
        out[name] = leaf.at[tuple(idx)].set(sub[name])
    return out


@dataclass
class SlotAllocator:
    """Host bookkeeping: slot ids + KV token budget (admission control).

    ``credit`` on `can_admit`/`alloc` is the prefix-cache allowance
    (`repro.serving.prefixcache`): tokens whose KV is shared with an
    already-admitted prompt don't charge the budget, so a prefix-heavy
    workload admits deeper than its raw token mass suggests. The charge is
    clamped to >= 0 and remembered per slot, keeping ``release`` symmetric.
    """

    max_slots: int
    kv_cap_tokens: int

    free: List[int] = field(default_factory=list)
    live_tokens: Dict[int, int] = field(default_factory=dict)
    # running sum of live_tokens: can_admit runs per queued request per
    # step, so it must not re-sum the live set on every call
    _used: int = field(default=0, init=False, repr=False)

    def __post_init__(self) -> None:
        self.free = list(range(self.max_slots))[::-1]
        self._used = sum(self.live_tokens.values())

    @property
    def used_tokens(self) -> int:
        return self._used

    def can_admit(self, need_tokens: int, credit: int = 0) -> bool:
        charged = max(0, need_tokens - credit)
        return bool(self.free) and self._used + charged <= self.kv_cap_tokens

    def alloc(self, need_tokens: int, credit: int = 0) -> Optional[int]:
        if not self.can_admit(need_tokens, credit):
            return None
        slot = self.free.pop()
        charged = max(0, need_tokens - credit)
        self.live_tokens[slot] = charged
        self._used += charged
        return slot

    def release(self, slot: int) -> None:
        if slot in self.live_tokens:
            self._used -= self.live_tokens.pop(slot)
            self.free.append(slot)

    def snapshot(self) -> Dict:
        # the free list is part of the state: its ORDER decides which slot
        # ids future allocs hand out, and replay/failover determinism (the
        # router's restore path) depends on reproducing exactly that
        return dict(live_tokens=dict(self.live_tokens), free=list(self.free))

    def restore(self, snap: Dict) -> None:
        self.live_tokens = dict(snap["live_tokens"])
        self._used = sum(self.live_tokens.values())
        if "free" in snap:
            self.free = list(snap["free"])
        else:  # legacy snapshot without a free list: synthesize a canonical one
            live = set(self.live_tokens)
            self.free = [s for s in range(self.max_slots) if s not in live][::-1]


def gather_pages(cfg: ModelConfig, pool: Dict, page_idx: jax.Array) -> Dict:
    """Assemble per-request contiguous cache views from a page pool.

    ``pool`` leaves are ``(L, n_pages, page_size, ...)``; ``page_idx`` is the
    ``(batch, pages_per_req)`` page table (pad rows/tails use the scratch
    page). Returns leaves shaped ``(L, batch, pages_per_req * page_size,
    ...)`` — exactly what `gather_slots` hands the model, so `decode_step`
    runs unchanged on top. Positions beyond a request's valid length land in
    scratch/garbage pages, which the attention mask zeroes out exactly
    (`kv_pos < kv_valid`), keeping paged logits bit-identical to slot-mode.
    """
    b, p = page_idx.shape
    flat = page_idx.reshape(-1)
    out = {}
    for name, leaf in pool.items():
        if cache_batch_dim(cfg, name) != 1:
            raise ValueError(
                f"paged KV supports attention-style (L, B, T, ...) cache "
                f"leaves only; leaf {name!r} has its batch on another axis"
            )
        g = jnp.take(leaf, flat, axis=1)
        out[name] = g.reshape(leaf.shape[0], b, p * leaf.shape[2], *leaf.shape[3:])
    return out


def scatter_pages(cfg: ModelConfig, pool: Dict, sub: Dict, page_idx: jax.Array) -> Dict:
    """Inverse of `gather_pages`: write per-request views back to the pool.

    Shared pages appear in several rows of ``page_idx``; decode only ever
    writes at a request's *own* position (>= its private region), so every
    duplicate index carries the page's unchanged bytes and the duplicate
    ``.at[].set`` is value-deterministic. Scratch-page duplicates hold
    garbage that nothing reads back unmasked.
    """
    b, p = page_idx.shape
    flat = page_idx.reshape(-1)
    out = {}
    for name, leaf in pool.items():
        ps = leaf.shape[2]
        s = sub[name].reshape(leaf.shape[0], b * p, ps, *leaf.shape[3:])
        out[name] = leaf.at[:, flat].set(s)
    return out


@dataclass
class PageAllocator:
    """Host bookkeeping for a fixed-size KV page pool.

    Pages are the unit of both capacity and sharing: a request's table is
    ``[shared prefix pages..., private pages...]``; shared pages bump a
    refcount instead of copying, and a page returns to the free list only
    when its last reference drops. Used-token accounting is O(1) — the page
    is the granule, so ``used_tokens`` is just occupied pages x page size.

    ``evictor`` is the prefix cache's pressure hook: when the free list
    cannot cover an allocation the allocator asks the cache to surrender
    cold, unreferenced pages (never pages a live table still maps —
    refcount > its own retain) before giving up.
    """

    page_size: int
    n_pages: int

    free: List[int] = field(default_factory=list)
    refcount: Dict[int, int] = field(default_factory=dict)
    tables: Dict[int, List[int]] = field(default_factory=dict)  # owner -> pages
    evictor: Optional[Callable[[int], int]] = None  # want_pages -> freed_pages
    # cumulative telemetry (summary()/bench rows)
    shared_links: int = field(default=0, init=False)
    pressure_evictions: int = field(default=0, init=False)

    def __post_init__(self) -> None:
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {self.n_pages}")
        # mirror SlotAllocator: pop from the tail => page 0 handed out first
        self.free = list(range(self.n_pages))[::-1]

    @property
    def free_pages(self) -> int:
        return len(self.free)

    @property
    def used_tokens(self) -> int:
        return (self.n_pages - len(self.free)) * self.page_size

    def pages_needed(self, n_tokens: int) -> int:
        return -(-max(0, n_tokens) // self.page_size)

    def can_admit(self, n_tokens: int, shared: Sequence[int] = ()) -> bool:
        """Free-list check only (no eviction attempt): ``alloc_table`` may
        still succeed where this returns False by reclaiming cache pages."""
        return self.pages_needed(n_tokens) - len(shared) <= len(self.free)

    def alloc_table(
        self, owner: int, n_tokens: int, shared: Sequence[int] = ()
    ) -> Optional[List[int]]:
        """Build ``owner``'s page table for ``n_tokens`` of KV, linking
        ``shared`` prefix pages (refcount bump) and drawing the rest fresh.
        Returns None — state untouched — if even eviction can't cover it."""
        if owner in self.tables:
            raise ValueError(f"owner {owner} already holds a page table")
        need = self.pages_needed(n_tokens)
        n_fresh = need - len(shared)
        if n_fresh < 0:
            raise ValueError(
                f"{len(shared)} shared pages exceed the {need}-page need"
            )
        if n_fresh > len(self.free) and self.evictor is not None:
            self.pressure_evictions += self.evictor(n_fresh - len(self.free))
        if n_fresh > len(self.free):
            return None
        for p in shared:
            self.refcount[p] += 1
        self.shared_links += len(shared)
        table = list(shared)
        for _ in range(n_fresh):
            p = self.free.pop()
            self.refcount[p] = 1
            table.append(p)
        self.tables[owner] = table
        return list(table)

    def retain(self, page: int) -> None:
        self.refcount[page] += 1

    def release_page(self, page: int) -> None:
        rc = self.refcount[page] - 1
        if rc:
            self.refcount[page] = rc
        else:
            del self.refcount[page]
            self.free.append(page)

    def release_table(self, owner: int) -> None:
        for p in self.tables.pop(owner, ()):
            self.release_page(p)
