"""Hash-prefix trie over admitted prompts (prefix-cache-aware admission).

Prompts are chunked into fixed-size token blocks and each block is keyed by
a CRC32 chain hash of (parent hash, block tokens) — the vLLM-style scheme
where a block's identity embeds its whole prefix, so a plain dict of node
hashes behaves as a trie without storing token strings. Two call sites:

  * **Session admission** (`ServeSession.submit`): every admitted prompt is
    matched then inserted; the matched token count becomes the request's
    ``prefix_hit_tokens``, which (a) feeds the per-session hit accounting
    in `SessionMetrics` and (b) is granted back to the `SlotAllocator` as a
    KV token-budget *credit* — reused prefix KV doesn't charge the cap.
  * **Router affinity** (`repro.serving.router`): the router keeps one
    `PrefixCache` per replica as its *own* record of which prefixes it sent
    where (a real router can't see replica internals), and the
    ``prefix-affinity`` policy routes to the replica with the longest match.

Accounting-only caches (no allocator attached) never skip compute: the
engine still prefills every token, so token outputs are invariant to the
cache (the engine-wide "policy changes timing, never tokens" contract).
*Page-mapped* caches (constructed with a `PageAllocator`) additionally bind
each node to a live KV page once a request's prefill lands (`assign_pages`),
and from then on a matched block is **real reuse**: the hit request links
the page into its own table (refcount bump) and prefill genuinely skips
those tokens. Hash collisions merge paths; with CRC32 chaining over full
prefixes they are vanishingly rare at serving scale and only perturb
accounting, never correctness (a collision could at worst alias a page of
valid KV from a different prompt — the same failure class vLLM accepts).

Capacity: ``max_blocks`` bounds the trie; over budget, least-recently-used
*leaf* nodes are evicted (interior nodes are pinned by their children, so
eviction always removes a longest suffix first — the trie never holds a
block whose prefix it has dropped). Eviction never removes nodes pinned by
in-flight requests (``pin``/`admit` with a rid, released by ``release``)
nor nodes whose page is still mapped by a live table (page refcount above
the cache's own retain) — dropping either would invalidate accounting or
tear KV out from under an admitted request. Page-mapped caches also serve
as the allocator's pressure ``evictor``: when the free list runs dry the
allocator reclaims cold unpinned cached pages before failing an admission.

See DESIGN.md §router and §kvcache.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.kvcache import PageAllocator

_ROOT = 0  # chain hash of the empty prefix

# The one default block size, shared by every constructor that builds a
# cache (`PrefixCache`, `RouterSession`) so hit rates measured anywhere are
# comparable by default; the harness overrides it for its tiny engine twins
# (`HarnessConfig.prefix_block`).
DEFAULT_PREFIX_BLOCK = 16


def _chain_hash(parent: int, block: Sequence[int]) -> int:
    """CRC32 of (parent hash ‖ block tokens): a block id that encodes its
    full prefix, so equal ids mean equal token paths (modulo collisions)."""
    data = struct.pack(f"<q{len(block)}q", parent, *[int(t) for t in block])
    h = zlib.crc32(data)
    return h if h != _ROOT else 1  # never collide with the root sentinel


@dataclass
class _Node:
    parent: int
    n_children: int = 0
    last_used: int = 0
    pins: int = 0  # in-flight requests whose admitted path crosses this node
    page: Optional[int] = None  # live KV page id (page-mapped caches only)


@dataclass
class _Pin:
    """One in-flight request's hold on the trie: the node path it admitted
    against (kept un-evictable until release) and the live pages its table
    links (the engine prices/skips exactly these)."""

    path: Tuple[int, ...]
    pages: Tuple[int, ...] = ()
    hit_tokens: int = 0


@dataclass
class PrefixCacheStats:
    """Cumulative accounting for one `PrefixCache`."""

    lookups: int = 0  # admit() calls
    hits: int = 0  # admits that matched >= 1 block
    lookup_tokens: int = 0  # full-block tokens eligible for matching
    hit_tokens: int = 0  # tokens served from the trie
    inserted_blocks: int = 0
    evicted_blocks: int = 0

    @property
    def hit_rate(self) -> float:
        """Token-weighted hit rate over everything admitted so far."""
        return self.hit_tokens / self.lookup_tokens if self.lookup_tokens else 0.0

    def as_dict(self) -> Dict:
        return dict(
            lookups=self.lookups,
            hits=self.hits,
            lookup_tokens=self.lookup_tokens,
            hit_tokens=self.hit_tokens,
            hit_rate=self.hit_rate,
            inserted_blocks=self.inserted_blocks,
            evicted_blocks=self.evicted_blocks,
        )


class PrefixCache:
    """Block-hashed prefix trie with LRU leaf eviction and hit accounting.

    With ``pages`` (a `PageAllocator`) the trie is *page-mapped*: nodes carry
    live page ids, matches hand back shareable pages, and the cache doubles
    as the allocator's pressure evictor. ``block`` must equal the allocator's
    page size so one trie node == one page.
    """

    def __init__(
        self,
        block: int = DEFAULT_PREFIX_BLOCK,
        max_blocks: Optional[int] = None,
        pages: Optional[PageAllocator] = None,
    ):
        if block < 1:
            raise ValueError(f"block must be >= 1, got {block}")
        if max_blocks is not None and max_blocks < 1:
            raise ValueError(f"max_blocks must be >= 1 or None, got {max_blocks}")
        if pages is not None and pages.page_size != block:
            raise ValueError(
                f"page-mapped cache needs block == page_size "
                f"({block} != {pages.page_size})"
            )
        self.block = block
        self.max_blocks = max_blocks
        self.pages = pages
        if pages is not None:
            pages.evictor = self._evict_pages
        self.stats = PrefixCacheStats()
        self._nodes: Dict[int, _Node] = {}
        self._pins: Dict[int, _Pin] = {}  # rid -> in-flight hold
        self._tick = 0  # logical LRU clock (no wall time: determinism)

    def __len__(self) -> int:
        return len(self._nodes)

    # ---------------------------------------------------------------- match
    def _blocks(self, tokens: Sequence[int]) -> Tuple[Tuple[int, ...], ...]:
        b = self.block
        n_full = len(tokens) // b
        return tuple(tuple(tokens[i * b : (i + 1) * b]) for i in range(n_full))

    def match(self, tokens: Sequence[int]) -> int:
        """Longest cached prefix of ``tokens`` in whole tokens (full blocks
        only). Pure peek: no insertion, no stats, no LRU touch — safe for
        routing probes that will not land the request here."""
        h = _ROOT
        matched = 0
        for blk in self._blocks(tokens):
            h = _chain_hash(h, blk)
            if h not in self._nodes:
                break
            matched += len(blk)
        return matched

    def _max_hit_tokens(self, tokens: Sequence[int]) -> int:
        # page-mapped hits skip real compute, and prefill must still emit
        # the first decode logits — so at least one prompt token always runs
        return ((len(tokens) - 1) // self.block) * self.block if tokens else 0

    def match_pages(self, tokens: Sequence[int]) -> Tuple[int, Tuple[int, ...]]:
        """Page-backed variant of `match`: longest *live-page* prefix and the
        page ids backing it, clamped so >= 1 prompt token is left to prefill.
        Pure peek, like `match`."""
        if self.pages is None:
            return 0, ()
        cap = self._max_hit_tokens(tokens) // self.block
        h = _ROOT
        pages: List[int] = []
        for blk in self._blocks(tokens)[:cap]:
            h = _chain_hash(h, blk)
            node = self._nodes.get(h)
            if node is None or node.page is None:
                break
            pages.append(node.page)
        return len(pages) * self.block, tuple(pages)

    # ---------------------------------------------------------------- admit
    def admit(self, tokens: Sequence[int], rid: Optional[int] = None) -> Tuple[int, int]:
        """Match then insert an admitted prompt; returns ``(hit_tokens,
        eligible_tokens)`` where eligible is the full-block token count the
        lookup could at best have matched.

        ``rid`` pins the prompt's whole node path until ``release(rid)``:
        eviction must not drop blocks an in-flight request's accounting (or,
        page-mapped, its KV table) still references. Page-mapped caches
        count only live-page-backed blocks as hits (clamped to leave >= 1
        token of real prefill) and record the shared pages for
        ``shared_pages(rid)``; accounting-only caches keep the PR-5
        behaviour where any trie match is a credit.
        """
        blocks = self._blocks(tokens)
        eligible = sum(len(b) for b in blocks)
        paged = self.pages is not None
        cap = self._max_hit_tokens(tokens) // self.block if paged else len(blocks)
        self._tick += 1
        h = _ROOT
        hit = 0
        pages: List[int] = []
        path: List[int] = []
        matching = True
        for i, blk in enumerate(blocks):
            parent = h
            h = _chain_hash(h, blk)
            path.append(h)
            node = self._nodes.get(h)
            if node is not None:
                node.last_used = self._tick
                if matching:
                    if paged and (node.page is None or i >= cap):
                        matching = False
                    else:
                        hit += len(blk)
                        if paged:
                            pages.append(node.page)
                continue
            matching = False
            self._nodes[h] = _Node(parent=parent, last_used=self._tick)
            if parent != _ROOT:
                self._nodes[parent].n_children += 1
            self.stats.inserted_blocks += 1
        if rid is not None:
            self._pin_path(rid, path, pages, hit)
        s = self.stats
        s.lookups += 1
        s.lookup_tokens += eligible
        s.hit_tokens += hit
        if hit:
            s.hits += 1
        self._evict()
        return hit, eligible

    # ----------------------------------------------------------------- pins
    def _pin_path(
        self, rid: int, path: Sequence[int], pages: Sequence[int], hit: int
    ) -> None:
        if rid in self._pins:  # defensive: duplicate rids must not leak pins
            self.release(rid)
        for h in path:
            node = self._nodes.get(h)
            if node is not None:
                node.pins += 1
        self._pins[rid] = _Pin(path=tuple(path), pages=tuple(pages), hit_tokens=hit)

    def pin_match(self, tokens: Sequence[int], rid: int) -> Tuple[int, Tuple[int, ...]]:
        """Pin the live-page match *without inserting* the prompt — the
        disagg fleet probes decode-side caches at submit time, long before
        the prompt's own KV lands anywhere (insertion happens at `attach` via
        `assign_pages`, on whichever worker actually decodes it)."""
        hit, pages = self.match_pages(tokens)
        path = []
        h = _ROOT
        for blk in self._blocks(tokens)[: len(pages)]:
            h = _chain_hash(h, blk)
            path.append(h)
        self._pin_path(rid, path, pages, hit)
        return hit, pages

    def release(self, rid: int) -> None:
        """Drop ``rid``'s pins (idempotent). Called when the request leaves
        the system — completed, cancelled, or failed admission."""
        pin = self._pins.pop(rid, None)
        if pin is None:
            return
        for h in pin.path:
            node = self._nodes.get(h)
            if node is not None and node.pins > 0:
                node.pins -= 1
        self._evict()

    def shared_pages(self, rid: int) -> Tuple[int, ...]:
        """Live page ids ``rid``'s admit/pin matched, in prefix order."""
        pin = self._pins.get(rid)
        return pin.pages if pin is not None else ()

    @property
    def pinned_requests(self) -> int:
        return len(self._pins)

    # ---------------------------------------------------------------- pages
    def assign_pages(self, tokens: Sequence[int], table: Sequence[int]) -> int:
        """Bind a landed prompt's full blocks to its table's pages (called
        from `DecodeEngine.attach` once the KV is really in the pool).
        Missing nodes are inserted — on the disagg fleet the decode worker
        that receives a handoff never saw the prompt at admit time. Each
        newly bound page is retained (the cache's own reference); already
        page-backed nodes are left alone (first binding wins — the existing
        page holds identical KV by construction). Returns pages bound."""
        if self.pages is None:
            raise ValueError("assign_pages requires a page-mapped cache")
        self._tick += 1
        bound = 0
        h = _ROOT
        for blk, page in zip(self._blocks(tokens), table):  # noqa: B905 - table may exceed full prompt blocks; zip stops at the shorter
            parent = h
            h = _chain_hash(h, blk)
            node = self._nodes.get(h)
            if node is None:
                node = _Node(parent=parent, last_used=self._tick)
                self._nodes[h] = node
                if parent != _ROOT:
                    self._nodes[parent].n_children += 1
                self.stats.inserted_blocks += 1
            else:
                node.last_used = self._tick
            if node.page is None:
                node.page = page
                self.pages.retain(page)
                bound += 1
        self._evict()
        return bound

    # ---------------------------------------------------------------- merge
    def merge_from(self, other: "PrefixCache") -> int:
        """Copy every trie node of ``other`` into this cache (fleet warm-up:
        a freshly scaled-up replica inherits the survivors' affinity state so
        `prefix-affinity` routing can steer shared-prefix traffic at it from
        its first request, instead of treating it as a stranger for an entire
        cache-refill period). Chain hashes encode their full prefix, so node
        sets from caches with the same block size merge by plain dict union;
        mismatched block sizes would alias unrelated prefixes and raise.

        Returns the number of nodes actually added. Like the PR 5 credit
        design this is accounting-only — no KV bytes move — and the warmed
        trie deliberately *overstates* the newcomer's real cache so affinity
        traffic (re)builds its session cache fastest. Stats and the LRU
        clock are untouched; merged nodes enter at the LRU floor, first out
        under pressure."""
        if other.block != self.block:
            raise ValueError(
                f"cannot merge prefix caches with different block sizes "
                f"({other.block} into {self.block}); chain hashes would alias"
            )
        added = 0
        for h, node in other._nodes.items():
            if h in self._nodes:
                continue
            self._nodes[h] = _Node(parent=node.parent)
            added += 1
        if added:
            # recount children from scratch: on partial trie overlap the
            # per-node counts from either side undercount the union, and a
            # wrong zero would let eviction orphan a subtree
            for node in self._nodes.values():
                node.n_children = 0
            for node in self._nodes.values():
                if node.parent != _ROOT:
                    self._nodes[node.parent].n_children += 1
            self._evict()
        return added

    # ---------------------------------------------------------------- evict
    def _evictable(self, n: _Node) -> bool:
        # leaves only (a surviving block always has its whole prefix), never
        # pinned by an in-flight request, and never a page some live table
        # still maps (refcount above the cache's own retain)
        if n.n_children != 0 or n.pins != 0:
            return False
        if n.page is not None and self.pages is not None:
            return self.pages.refcount.get(n.page, 0) <= 1
        return True

    def _pop_victim(self, victim: int) -> None:
        node = self._nodes.pop(victim)
        if node.page is not None and self.pages is not None:
            self.pages.release_page(node.page)
        if node.parent != _ROOT:
            self._nodes[node.parent].n_children -= 1
        self.stats.evicted_blocks += 1

    def _evict(self) -> None:
        if self.max_blocks is None:
            return
        while len(self._nodes) > self.max_blocks:
            # LRU leaf: O(n) scan, fine at the block counts a replica holds
            candidates = [h for h, n in self._nodes.items() if self._evictable(n)]
            if not candidates:
                return  # everything pinned/shared: run over budget until released
            self._pop_victim(min(candidates, key=lambda h: (self._nodes[h].last_used, h)))

    def _evict_pages(self, want: int) -> int:
        """`PageAllocator` pressure hook: reclaim up to ``want`` cold cached
        pages (LRU order, same pin/refcount guards as `_evict`). Pageless
        unpinned leaves are dropped along the way — they cost no pages but
        shield page-backed parents from leaf-only eviction. Returns the page
        count actually freed."""
        freed = 0
        while freed < want:
            candidates = [h for h, n in self._nodes.items() if self._evictable(n)]
            if not candidates:
                break
            victim = min(candidates, key=lambda h: (self._nodes[h].last_used, h))
            if self._nodes[victim].page is not None:
                freed += 1
            self._pop_victim(victim)
        return freed
