"""Hash-prefix trie over admitted prompts (prefix-cache-aware admission).

Prompts are chunked into fixed-size token blocks and each block is keyed by
a CRC32 chain hash of (parent hash, block tokens) — the vLLM-style scheme
where a block's identity embeds its whole prefix, so a plain dict of node
hashes behaves as a trie without storing token strings. Two call sites:

  * **Session admission** (`ServeSession.submit`): every admitted prompt is
    matched then inserted; the matched token count becomes the request's
    ``prefix_hit_tokens``, which (a) feeds the per-session hit accounting
    in `SessionMetrics` and (b) is granted back to the `SlotAllocator` as a
    KV token-budget *credit* — reused prefix KV doesn't charge the cap.
  * **Router affinity** (`repro.serving.router`): the router keeps one
    `PrefixCache` per replica as its *own* record of which prefixes it sent
    where (a real router can't see replica internals), and the
    ``prefix-affinity`` policy routes to the replica with the longest match.

The credit is pure admission accounting: the engine still computes full
prefill for every prompt, so token outputs are invariant to the cache (the
engine-wide "policy changes timing, never tokens" contract). Hash
collisions merge paths; with CRC32 chaining over full prefixes they are
vanishingly rare at serving scale and only perturb accounting, never
correctness.

Capacity: ``max_blocks`` bounds the trie; over budget, least-recently-used
*leaf* nodes are evicted (interior nodes are pinned by their children, so
eviction always removes a longest suffix first — the trie never holds a
block whose prefix it has dropped).

See DESIGN.md §router.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

_ROOT = 0  # chain hash of the empty prefix

# The one default block size, shared by every constructor that builds a
# cache (`PrefixCache`, `RouterSession`) so hit rates measured anywhere are
# comparable by default; the harness overrides it for its tiny engine twins
# (`HarnessConfig.prefix_block`).
DEFAULT_PREFIX_BLOCK = 16


def _chain_hash(parent: int, block: Sequence[int]) -> int:
    """CRC32 of (parent hash ‖ block tokens): a block id that encodes its
    full prefix, so equal ids mean equal token paths (modulo collisions)."""
    data = struct.pack(f"<q{len(block)}q", parent, *[int(t) for t in block])
    h = zlib.crc32(data)
    return h if h != _ROOT else 1  # never collide with the root sentinel


@dataclass
class _Node:
    parent: int
    n_children: int = 0
    last_used: int = 0


@dataclass
class PrefixCacheStats:
    """Cumulative accounting for one `PrefixCache`."""

    lookups: int = 0  # admit() calls
    hits: int = 0  # admits that matched >= 1 block
    lookup_tokens: int = 0  # full-block tokens eligible for matching
    hit_tokens: int = 0  # tokens served from the trie
    inserted_blocks: int = 0
    evicted_blocks: int = 0

    @property
    def hit_rate(self) -> float:
        """Token-weighted hit rate over everything admitted so far."""
        return self.hit_tokens / self.lookup_tokens if self.lookup_tokens else 0.0

    def as_dict(self) -> Dict:
        return dict(
            lookups=self.lookups,
            hits=self.hits,
            lookup_tokens=self.lookup_tokens,
            hit_tokens=self.hit_tokens,
            hit_rate=self.hit_rate,
            inserted_blocks=self.inserted_blocks,
            evicted_blocks=self.evicted_blocks,
        )


class PrefixCache:
    """Block-hashed prefix trie with LRU leaf eviction and hit accounting."""

    def __init__(self, block: int = DEFAULT_PREFIX_BLOCK, max_blocks: Optional[int] = None):
        if block < 1:
            raise ValueError(f"block must be >= 1, got {block}")
        if max_blocks is not None and max_blocks < 1:
            raise ValueError(f"max_blocks must be >= 1 or None, got {max_blocks}")
        self.block = block
        self.max_blocks = max_blocks
        self.stats = PrefixCacheStats()
        self._nodes: Dict[int, _Node] = {}
        self._tick = 0  # logical LRU clock (no wall time: determinism)

    def __len__(self) -> int:
        return len(self._nodes)

    # ---------------------------------------------------------------- match
    def _blocks(self, tokens: Sequence[int]) -> Tuple[Tuple[int, ...], ...]:
        b = self.block
        n_full = len(tokens) // b
        return tuple(tuple(tokens[i * b : (i + 1) * b]) for i in range(n_full))

    def match(self, tokens: Sequence[int]) -> int:
        """Longest cached prefix of ``tokens`` in whole tokens (full blocks
        only). Pure peek: no insertion, no stats, no LRU touch — safe for
        routing probes that will not land the request here."""
        h = _ROOT
        matched = 0
        for blk in self._blocks(tokens):
            h = _chain_hash(h, blk)
            if h not in self._nodes:
                break
            matched += len(blk)
        return matched

    # ---------------------------------------------------------------- admit
    def admit(self, tokens: Sequence[int]) -> Tuple[int, int]:
        """Match then insert an admitted prompt; returns ``(hit_tokens,
        eligible_tokens)`` where eligible is the full-block token count the
        lookup could at best have matched."""
        blocks = self._blocks(tokens)
        eligible = sum(len(b) for b in blocks)
        self._tick += 1
        h = _ROOT
        hit = 0
        matching = True
        for blk in blocks:
            parent = h
            h = _chain_hash(h, blk)
            node = self._nodes.get(h)
            if node is not None:
                node.last_used = self._tick
                if matching:
                    hit += len(blk)
                continue
            matching = False
            self._nodes[h] = _Node(parent=parent, last_used=self._tick)
            if parent != _ROOT:
                self._nodes[parent].n_children += 1
            self.stats.inserted_blocks += 1
        s = self.stats
        s.lookups += 1
        s.lookup_tokens += eligible
        s.hit_tokens += hit
        if hit:
            s.hits += 1
        self._evict()
        return hit, eligible

    # ---------------------------------------------------------------- merge
    def merge_from(self, other: "PrefixCache") -> int:
        """Copy every trie node of ``other`` into this cache (fleet warm-up:
        a freshly scaled-up replica inherits the survivors' affinity state so
        `prefix-affinity` routing can steer shared-prefix traffic at it from
        its first request, instead of treating it as a stranger for an entire
        cache-refill period). Chain hashes encode their full prefix, so node
        sets from caches with the same block size merge by plain dict union;
        mismatched block sizes would alias unrelated prefixes and raise.

        Returns the number of nodes actually added. Like the PR 5 credit
        design this is accounting-only — no KV bytes move — and the warmed
        trie deliberately *overstates* the newcomer's real cache so affinity
        traffic (re)builds its session cache fastest. Stats and the LRU
        clock are untouched; merged nodes enter at the LRU floor, first out
        under pressure."""
        if other.block != self.block:
            raise ValueError(
                f"cannot merge prefix caches with different block sizes "
                f"({other.block} into {self.block}); chain hashes would alias"
            )
        added = 0
        for h, node in other._nodes.items():
            if h in self._nodes:
                continue
            self._nodes[h] = _Node(parent=node.parent)
            added += 1
        if added:
            # recount children from scratch: on partial trie overlap the
            # per-node counts from either side undercount the union, and a
            # wrong zero would let eviction orphan a subtree
            for node in self._nodes.values():
                node.n_children = 0
            for node in self._nodes.values():
                if node.parent != _ROOT:
                    self._nodes[node.parent].n_children += 1
            self._evict()
        return added

    # ---------------------------------------------------------------- evict
    def _evict(self) -> None:
        if self.max_blocks is None:
            return
        while len(self._nodes) > self.max_blocks:
            # LRU leaf: O(n) scan, fine at the block counts a replica holds;
            # leaves only, so a surviving block always has its whole prefix
            victim = min(
                (h for h, n in self._nodes.items() if n.n_children == 0),
                key=lambda h: self._nodes[h].last_used,
            )
            parent = self._nodes.pop(victim).parent
            if parent != _ROOT:
                self._nodes[parent].n_children -= 1
            self.stats.evicted_blocks += 1
