"""Async streaming frontend: the online face of `ServeSession`.

`ServeSession` (repro.serving.session) is deliberately synchronous — submit,
step, callbacks. This module puts an asyncio event loop on top of it so the
engine can serve *live* clients the way the paper's testbed does: admission
and token delivery happen concurrently with scheduling, not as a replayed
trace.

    frontend = AsyncServeSession(server)
    async with frontend:
        handle = await frontend.submit(request, prompt)
        async for token in handle.stream():
            ...                      # tokens arrive as the engine produces them

Architecture (DESIGN.md §frontend):

  * One background **stepper** task owns every interaction with the engine
    clock and the underlying `ServeSession`. Per iteration it ingests client
    intents (submit/cancel), reads virtual time ONCE, admits scheduled
    submissions whose arrival has passed, runs `session.step()`, then
    delivers the step's tokens into per-request buffers. The loop body
    mirrors `ServeSession.run()` read-for-read, so on a `ManualClock` the
    async frontend reproduces the sync session's TTFT/TPOT *bit-for-bit*
    (tested in tests/test_async_frontend.py).
  * Each request gets a `RequestHandle` with a **bounded token buffer**
    (``stream_buffer`` tokens, +1 slot reserved for the end-of-stream
    marker). When a consumer is too slow, the ``backpressure`` policy
    decides: ``"block"`` stalls the stepper until the consumer drains
    (classic backpressure — the whole engine waits), ``"shed"`` cancels the
    slow consumer's request, reclaims its slots, and records it in
    `SessionMetrics.backpressure_shed`.
  * **Cancellation**: abandoning ``handle.stream()`` (client disconnect) or
    calling ``handle.cancel()`` queues a cancel intent; the stepper calls
    `ServeSession.cancel`, which removes the request from whichever stage
    holds it, frees its decode slot / prefill cache, and terminates it in
    `Phase.CANCELLED` — distinct from admission shedding (`FAILED`).
  * **Drain/close**: ``drain()`` waits for all admitted work to finish, then
    stops the stepper; ``aclose()`` cancels everything in flight first.
    ``async with`` drains on clean exit and cancels on exception.

Timing rule: the stepper is the only code that touches ``server.clock`` —
client coroutines never read it, which is what keeps `ManualClock` runs
deterministic under arbitrary task interleavings.
"""
from __future__ import annotations

import asyncio
import heapq
from typing import Any, AsyncIterator, Dict, List, Optional, Sequence, Tuple

from repro.core.request import TERMINAL_PHASES, Phase, Request
from repro.obs.events import EventType
from repro.serving.engine import DisaggServer
from repro.serving.session import FROM_CONFIG, ServeSession, SessionMetrics

BACKPRESSURE_POLICIES: Tuple[str, ...] = ("block", "shed")

_EOS = object()  # end-of-stream marker inside handle buffers


async def drive_replay(
    submit: Any,
    pairs: Sequence[Tuple[Request, Sequence[int]]],
    clients: int = 4,
    on_client_token: Optional[Any] = None,
) -> None:
    """The one open-loop replay drive, shared by `AsyncServeSession.replay`
    and `RouterSession.replay` (one body, so their await sequences cannot
    drift — the bit-parity contracts depend on that): submit each pair at
    its arrival in stable order via ``submit(request, prompt, at=...)``,
    then drain every handle with ``clients`` concurrent consumer tasks."""
    order = sorted(range(len(pairs)), key=lambda i: pairs[i][0].arrival)
    handles = []
    for i in order:
        req, prompt = pairs[i]
        handles.append(await submit(req, prompt, at=req.arrival))

    async def consume(c: int) -> None:
        async def drain_one(h: "RequestHandle") -> None:
            async for tok in h.stream():
                if on_client_token is not None:
                    on_client_token(c, tok)

        await asyncio.gather(*(drain_one(h) for h in handles[c::clients]))

    clients = max(1, clients)
    await asyncio.gather(*(consume(c) for c in range(clients)))


class RequestHandle:
    """A client's view of one submitted request.

    ``await handle.admitted()`` resolves once admission control has run
    (False = shed). ``async for tok in handle.stream()`` yields tokens as
    the engine produces them; exiting the iteration early (break, task
    cancellation, client disconnect) cancels the request. ``cancel_reason``
    is ``None``, ``"client"``, or ``"backpressure"``.
    """

    def __init__(self, frontend: "AsyncServeSession", request: Request, buffer: int):
        self._frontend = frontend
        self.request = request
        # +2 reserved slots past the advertised buffer: a request that
        # *completes* while its buffer is full still owes the client one
        # final token plus the EOS marker, and neither may be dropped (the
        # shed policy only aborts requests that would keep producing)
        self._queue: asyncio.Queue = asyncio.Queue(maxsize=buffer + 2)
        self._admit_event = asyncio.Event()
        self._accepted: Optional[bool] = None
        self._closed = False  # EOS enqueued; no more tokens will arrive
        self.cancel_reason: Optional[str] = None

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def tokens(self) -> List[int]:
        """Tokens produced so far (including any not yet consumed)."""
        return list(self._frontend.session.outputs.get(self.rid, []))

    async def admitted(self) -> bool:
        await self._admit_event.wait()
        return bool(self._accepted)

    async def stream(self) -> AsyncIterator[int]:
        """Yield tokens in generation order until the request finishes.

        A shed request yields nothing. Leaving the loop before the stream
        is exhausted counts as a client disconnect: the request is
        cancelled and its engine resources reclaimed.
        """
        if not await self.admitted():
            return
        try:
            while True:
                item = await self._queue.get()
                if item is _EOS:
                    break
                yield item
        finally:
            self.cancel()  # no-op once the request is terminal

    async def result(self) -> List[int]:
        """Drain the stream and return the full output token list."""
        async for _ in self.stream():
            pass
        return self.tokens

    def cancel(self) -> None:
        """Withdraw the request (idempotent; no-op after DONE/FAILED)."""
        if self.request.phase in TERMINAL_PHASES:
            return
        # Discard the unread backlog first: under the "block" policy the
        # stepper may be parked in `queue.put` on OUR full buffer, and the
        # cancel intent can only be processed once that put resolves —
        # get_nowait wakes the pending putter, breaking the deadlock.
        while not self._queue.empty():
            try:
                self._queue.get_nowait()
            except asyncio.QueueEmpty:  # pragma: no cover - single-threaded
                break
        self._frontend._request_cancel(self.rid)

    # ---- frontend-side plumbing (called only from the stepper task) ------
    def _resolve_admission(self, accepted: bool) -> None:
        self._accepted = accepted
        self._admit_event.set()
        if not accepted:
            self._close_now()

    def _close_now(self) -> None:
        """Terminate the stream, discarding buffered-but-unread tokens.

        Used for shed/cancelled streams where the client no longer wants
        the backlog; normal completion enqueues EOS behind the tokens
        instead (`AsyncServeSession._finish`).
        """
        if self._closed:
            return
        self._closed = True
        while not self._queue.empty():
            self._queue.get_nowait()
        self._queue.put_nowait(_EOS)


class _Intent:
    """A submit waiting for the stepper (``at`` = virtual arrival time)."""

    __slots__ = ("at", "seq", "request", "prompt", "handle", "cancelled")

    def __init__(self, at: float, seq: int, request: Request, prompt: List[int],
                 handle: RequestHandle):
        self.at, self.seq = at, seq
        self.request, self.prompt, self.handle = request, prompt, handle
        self.cancelled = False

    def __lt__(self, other: "_Intent") -> bool:  # heap order: arrival, FIFO
        return (self.at, self.seq) < (other.at, other.seq)


class AsyncServeSession:
    """Asyncio frontend over a `ServeSession` (see module docstring).

    Parameters mirror `ServeSession` (admission bounds inherit the server's
    `EngineConfig` via ``FROM_CONFIG``), plus the streaming knobs:

    stream_buffer   per-request token buffer (tokens a consumer may lag)
    backpressure    "block" (stall the engine for slow consumers) or
                    "shed" (cancel the slow consumer's request)
    idle_wait       max virtual seconds advanced per idle iteration while
                    waiting on a scheduled arrival; 0.001 matches
                    `ServeSession.run` exactly (keep it for parity)
    """

    def __init__(
        self,
        server: DisaggServer,
        max_queue_depth: Any = FROM_CONFIG,
        tenant_queue_depth: Any = FROM_CONFIG,
        stream_buffer: int = 16,
        backpressure: str = "block",
        idle_wait: float = 0.001,
        prefix_cache: Optional[Any] = None,
        session: Optional[Any] = None,
        trace: Optional[Any] = None,
        trace_label: str = "engine:0",
    ):
        if backpressure not in BACKPRESSURE_POLICIES:
            raise ValueError(
                f"backpressure={backpressure!r}; expected one of {BACKPRESSURE_POLICIES}"
            )
        if stream_buffer < 1:
            raise ValueError("stream_buffer must be >= 1")
        if session is not None:
            # session injection: a pre-built ServeSession-shaped core (e.g.
            # repro.serving.disagg.DisaggSession) keeps this frontend's whole
            # submit/stream/cancel/replay machinery; the core only needs the
            # duck type (server/submit/step/cancel/outputs/metrics/on_token)
            session.on_token = self._collect_token
            self.session = session
        else:
            self.session = ServeSession(
                server,
                max_queue_depth=max_queue_depth,
                tenant_queue_depth=tenant_queue_depth,
                on_token=self._collect_token,
                prefix_cache=prefix_cache,
                trace=trace,
                trace_label=trace_label,
            )
        self.stream_buffer = stream_buffer
        self.backpressure = backpressure
        self.idle_wait = idle_wait
        # ManualClock-style clocks expose advance(); their sleep() returns
        # instantly, so the stepper may call it inline. A wall clock must be
        # awaited instead or it would block the entire event loop.
        self._virtual_clock = hasattr(self.session.server.clock, "advance")

        self._handles: Dict[int, RequestHandle] = {}  # admitted, streaming
        self._scheduled: List[_Intent] = []  # heap: (arrival, seq)
        self._submit_intents: List[_Intent] = []
        self._cancel_intents: List[int] = []
        self._emitted: List[Tuple[Request, int, float]] = []  # tokens of the current step
        self._seq = 0
        self._wake = asyncio.Event()
        self._drained = asyncio.Event()
        self._draining = False
        self._stepper: Optional[asyncio.Task] = None

    # ------------------------------------------------------------- metrics
    @property
    def metrics(self) -> SessionMetrics:
        return self.session.metrics

    def summary(self) -> Dict[str, Any]:
        return self.session.summary()

    # ------------------------------------------------------------ lifecycle
    async def __aenter__(self) -> "AsyncServeSession":
        self.start()
        return self

    async def __aexit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            await self.drain()
        else:
            await self.aclose()

    def start(self) -> None:
        """Re-zero virtual time and launch the background stepper task.

        Restart after a completed ``drain()`` is supported: the drain state
        is reset so the new stepper doesn't inherit a set ``_drained`` event
        and exit at its first idle moment.
        """
        if self._stepper is not None:
            raise RuntimeError("frontend already started")
        self._draining = False
        self._drained = asyncio.Event()
        self.session.server.reset_clock()
        self._stepper = asyncio.get_running_loop().create_task(
            self._run_stepper(), name="serve-stepper"
        )

    async def drain(self) -> None:
        """Wait for every admitted request to reach a terminal phase, then
        stop the stepper. Streams stay consumable afterwards (their EOS is
        already buffered). Re-raises the stepper's exception if the engine
        crashed mid-run."""
        if self._stepper is None:
            return
        self._draining = True
        self._wake.set()
        await self._drained.wait()
        stepper, self._stepper = self._stepper, None
        if stepper is not None:  # kill() mid-drain leaves nothing to await
            await stepper  # surfaces a stepper crash as a traceback

    async def aclose(self) -> None:
        """Hard stop: cancel the stepper and every in-flight request —
        including submits the stepper never got to ingest, whose handles
        must still resolve or their awaiters would hang forever."""
        if self._stepper is not None:
            self._stepper.cancel()
            try:
                await self._stepper
            except asyncio.CancelledError:
                pass
            except BaseException:
                # hard stop: the caller is already on an error path (or wants
                # teardown regardless); drain() is the error-surfacing API
                pass
            self._stepper = None
        for intent in self._submit_intents + self._scheduled:
            self._cancel_unadmitted(intent)
        self._submit_intents.clear()
        self._scheduled.clear()
        for rid, h in list(self._handles.items()):
            if self.session.cancel(rid):
                h.cancel_reason = h.cancel_reason or "client"
            h._close_now()
        self._handles.clear()

    async def kill(self) -> None:
        """Fault injection: this replica dies mid-step.

        Unlike `aclose` it emits NO terminal events and closes NO streams —
        a dead process doesn't get to say goodbye. Every piece of frontend
        and session state (scheduled intents, live handles, queue/active
        sets, the allocator) is left exactly where the crash found it, so a
        fleet controller can harvest the in-flight work and restore it onto
        survivors (`repro.serving.fleetctl.FleetSession.kill_replica`),
        which is also responsible for clearing the carcass afterwards —
        otherwise those handles would double-terminate at teardown.
        """
        if self._stepper is None:
            return
        self._stepper.cancel()
        try:
            await self._stepper
        except asyncio.CancelledError:
            pass
        except BaseException:
            pass  # a crash mid-kill is still a dead replica
        self._stepper = None
        # a drain() racing the kill must not wait forever on a stepper that
        # will never set the event; it finds _stepper already None above
        self._drained.set()

    def _cancel_unadmitted(self, intent: "_Intent") -> None:
        """Withdraw a request admission control never saw: it still ends in
        Phase.CANCELLED and still counts in the session metrics, or a
        pre-admission disconnect would vanish from every report. It is
        recorded as submitted-but-neither-accepted-nor-rejected, with a
        per-request row in ``summary()`` like every other terminal fate."""
        if intent.cancelled or intent.handle._accepted is not None:
            return
        intent.cancelled = True
        req = intent.request
        if req.phase not in TERMINAL_PHASES:
            req.phase = Phase.CANCELLED
            m = self.session.metrics
            m.submitted += 1
            m._bump(m.submitted_by_tenant, req.tenant)
            m.cancelled += 1
            m.cancelled_rids.append(req.rid)
            m._bump(m.cancelled_by_tenant, req.tenant)
            self.session.requests.append(req)
            tr = getattr(self.session, "trace", None)
            if tr is not None:
                # this path bypasses session.submit/cancel, so it must emit
                # the same SUBMIT + CANCEL pair itself or the event-derived
                # counters would diverge from SessionMetrics (pre-admission
                # cancels count as submitted+cancelled). No clock read: the
                # declared arrival timestamps both, like session.submit.
                lbl = getattr(self.session, "trace_label", "")
                tr.emit(
                    EventType.SUBMIT, req.arrival, rid=req.rid,
                    tenant=req.tenant, pool=lbl, arrival=req.arrival,
                    input_len=req.input_len, output_len=req.output_len,
                    slo_ttft=req.slo.ttft, slo_tpot=req.slo.tpot,
                    slo_class=req.slo_class,
                )
                tr.emit(
                    EventType.CANCEL, req.arrival, rid=req.rid,
                    tenant=req.tenant, pool=lbl, stage="pre-admission",
                )
        intent.handle.cancel_reason = "client"
        intent.handle._resolve_admission(False)

    # -------------------------------------------------------------- submit
    async def submit(
        self, request: Request, prompt: Sequence[int], at: Optional[float] = None
    ) -> RequestHandle:
        """Queue a request for admission and return its handle immediately.

        ``at`` schedules the submission at a virtual time (open-loop replay:
        pass ``request.arrival``); ``None`` submits on the stepper's next
        iteration. Admission control runs on the stepper — await
        ``handle.admitted()`` for the shed/accept verdict.
        """
        if self._stepper is None:
            raise RuntimeError("frontend not started (use `async with` or start())")
        if request.input_len != len(prompt):
            raise ValueError(
                f"request rid={request.rid} declares input_len={request.input_len} "
                f"but prompt has {len(prompt)} tokens; the SLO/urgency arithmetic "
                f"is computed from input_len, so they must agree"
            )
        handle = RequestHandle(self, request, self.stream_buffer)
        intent = _Intent(
            float("-inf") if at is None else at, self._seq, request, list(prompt), handle
        )
        self._seq += 1
        self._submit_intents.append(intent)
        self._wake.set()
        return handle

    def _request_cancel(self, rid: int) -> None:
        self._cancel_intents.append(rid)
        self._wake.set()

    # ------------------------------------------------------------- replay
    async def replay(
        self,
        pairs: Sequence[Tuple[Request, Sequence[int]]],
        clients: int = 4,
        on_client_token: Optional[Any] = None,
    ) -> Dict[int, List[int]]:
        """Open-loop replay of (Request, prompt) pairs against the live loop.

        Submissions are scheduled at each request's ``arrival`` in stable
        arrival order (open loop: a slow request never delays the next
        submission), and the resulting streams are drained by ``clients``
        concurrent consumer tasks — handles round-robin across clients,
        every stream drained by its own task so one stalled stream never
        blocks a client's others. ``on_client_token(client_idx, token)``
        is called for each consumed token (loadgen uses it for per-client
        accounting). Returns rid -> output tokens — the same mapping
        `ServeSession.run` returns, and (on a `ManualClock`) with identical
        per-token timestamps.
        """
        await drive_replay(self.submit, pairs, clients, on_client_token)
        return {rid: list(toks) for rid, toks in self.session.outputs.items()}

    # ------------------------------------------------------------- stepper
    def _collect_token(self, req: Request, tok: int, t: float) -> None:
        # sync callback out of session.step(); delivery (which may need to
        # await buffer space) happens right after the step returns
        self._emitted.append((req, tok, t))

    def _process_cancels(self) -> None:
        intents, self._cancel_intents = self._cancel_intents, []
        for rid in intents:
            h = self._handles.pop(rid, None)
            if h is not None:
                if self.session.cancel(rid):
                    h.cancel_reason = h.cancel_reason or "client"
                h._close_now()
                continue
            for intent in self._scheduled:  # not yet admitted
                if intent.request.rid == rid:
                    self._cancel_unadmitted(intent)

    def _ingest_submits(self) -> None:
        intents, self._submit_intents = self._submit_intents, []
        for intent in intents:
            heapq.heappush(self._scheduled, intent)

    def _admit(self, intent: _Intent) -> None:
        if intent.cancelled:
            return
        accepted = self.session.submit(intent.request, intent.prompt)
        if accepted:
            self._handles[intent.request.rid] = intent.handle
        intent.handle._resolve_admission(accepted)

    async def _deliver(self, req: Request, tok: int) -> None:
        h = self._handles.get(req.rid)
        if h is None or h._closed:
            return
        if self.backpressure == "block":
            await h._queue.put(tok)  # stalls the stepper: true backpressure
            return
        # "shed" only aborts requests that would keep producing: a request
        # that just went terminal (this is its final token) delivers into
        # the reserved slots instead — a completed request must never lose
        # tokens to the laggard policy
        if req.phase not in TERMINAL_PHASES and h._queue.qsize() >= self.stream_buffer:
            self._handles.pop(req.rid, None)
            if self.session.cancel(req.rid):
                self.session.metrics.backpressure_shed += 1
            h.cancel_reason = "backpressure"
            h._close_now()
            return
        h._queue.put_nowait(tok)

    async def _finish(self, rid: int) -> None:
        h = self._handles.pop(rid, None)
        if h is None or h._closed:
            return
        h._closed = True
        # the reserved +1 slot guarantees space under "shed"; under "block"
        # a full buffer legitimately waits for the consumer
        if self.backpressure == "block":
            await h._queue.put(_EOS)
        else:
            h._queue.put_nowait(_EOS)

    async def _idle(self, dt: float) -> None:
        if self._virtual_clock:
            # repro: allow[RPA003] ManualClock.sleep only advances virtual time
            self.session.server.clock.sleep(dt)  # returns instantly, never blocks
            await asyncio.sleep(0)  # let clients run at the new time
        else:
            await asyncio.sleep(dt)

    async def _run_stepper(self) -> None:
        """The engine-driving loop, with crash containment: an exception
        escaping the engine must unblock every awaiter (streams get their
        EOS, unresolved admissions resolve False, drain() returns) and then
        re-raise so ``drain()``/``aclose()`` surface a traceback instead of
        the whole frontend hanging silently."""
        try:
            await self._step_loop()
        except asyncio.CancelledError:
            raise  # aclose() tears down explicitly
        except BaseException:
            for intent in self._submit_intents + self._scheduled:
                if intent.handle._accepted is None:
                    intent.cancelled = True
                    intent.handle.cancel_reason = intent.handle.cancel_reason or "error"
                    intent.handle._resolve_admission(False)
            self._submit_intents.clear()
            self._scheduled.clear()
            tr = getattr(self.session, "trace", None)
            lbl = getattr(self.session, "trace_label", "")
            for h in self._handles.values():
                h.cancel_reason = h.cancel_reason or "error"
                h._close_now()
                if tr is not None and h.request.phase not in TERMINAL_PHASES:
                    # crash containment tears the request down without going
                    # through cancel(): FAIL is its single terminal event.
                    # No clock read — stamp with the request's last known
                    # event time (the run is dead; parity is moot, the
                    # one-terminal invariant is not).
                    req = h.request
                    t = req.token_times[-1] if req.token_times else req.arrival
                    tr.emit(
                        EventType.FAIL, t, rid=req.rid, tenant=req.tenant,
                        pool=lbl, reason="stepper-crash",
                    )
            self._handles.clear()
            self._drained.set()
            raise

    async def _step_loop(self) -> None:
        """Mirrors `ServeSession.run` exactly in its clock interactions:
        one `_now()` read per iteration, plus the same idle-sleep bound —
        that equivalence is what the async/sync parity test pins down."""
        srv = self.session.server
        sess = self.session
        while True:
            # ingest before cancel-processing so a cancel that raced its own
            # submit still finds the intent on the schedule
            self._ingest_submits()
            self._process_cancels()
            now = srv._now()
            while self._scheduled and self._scheduled[0].at <= now:
                self._admit(heapq.heappop(self._scheduled))
            if sess.has_work:
                completed = sess.step()
                emitted, self._emitted = self._emitted, []
                for req, tok, _t in emitted:
                    await self._deliver(req, tok)
                for rid in completed:
                    await self._finish(rid)
                await asyncio.sleep(0)  # consumers run between engine steps
            elif self._scheduled:
                nxt = self._scheduled[0]
                if nxt.cancelled:
                    heapq.heappop(self._scheduled)
                    continue
                await self._idle(min(self.idle_wait, max(0.0, nxt.at - srv._now())))
            elif self._submit_intents or self._cancel_intents:
                continue
            elif self._draining:
                self._drained.set()
                return
            else:
                self._wake.clear()
                if not (self._submit_intents or self._cancel_intents):
                    await self._wake.wait()
