"""Token sampling."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample(
    logits: jax.Array,  # (B, V) f32
    *,
    temperature: float = 0.0,
    top_k: int = 0,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Greedy (temperature 0) or temperature/top-k sampling. Returns (B,) i32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert key is not None, "sampling needs a PRNG key"
    logits = logits / temperature
    if top_k and top_k > 0 and top_k < logits.shape[-1]:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
