"""Event-stream SLO telemetry: timelines, attainment, sliding windows.

Everything here is a pure fold over a `repro.obs.events` stream — no
session, no clock, no Request objects. `per_request_timelines` rebuilds
each request's lifecycle; `attainment_from_events` recomputes the exact
`repro.sim.metrics.attainment` fractions from those timelines (pinned
equal in tests/test_obs.py — the event stream carries everything the
aggregate metrics are made of); `windowed_slo` cuts the run into
fixed-width virtual-time windows and reports per-window attainment,
queue-depth and in-flight-transfer gauges, and the per-step
decode-time-vs-TPOT-budget series. That windowed block is the live
control signal the planned failover/autoscaling loop consumes (ROADMAP:
"SLO attainment under churn is the headline metric") — a replica scaler
reads the trailing window, not the end-of-run aggregate.

Attainment semantics mirror `sim.metrics.attainment`: DONE plus
SHED/FAIL terminals form the denominator (a shed request is an SLO miss,
not a non-event), CANCEL is the client walking away — excluded from
numerator *and* denominator, surfaced as ``n_cancelled``.

TPOT caveat: events record token *generation* times; `Request.mean_tpot`
prefers delivery times when a `DeliveryPacer` reordered them. Under the
default ``"immediate"`` pacer the two are identical, which is the
configuration the equality tests pin.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional

import numpy as np

from repro.obs.events import Event, EventType, TERMINAL_EVENTS


@dataclass
class RequestTimeline:
    """One request's lifecycle, folded out of its events."""

    rid: int
    tenant: str = ""
    slo_class: str = ""
    arrival: float = 0.0
    input_len: int = 0
    output_len: int = 0
    slo_ttft: float = float("inf")
    slo_tpot: float = float("inf")
    pool: str = ""  # last pool that touched the request
    admit_t: Optional[float] = None
    prefill_start: Optional[float] = None
    prefill_end: Optional[float] = None
    handoff_queued: Optional[float] = None
    handoff_start: Optional[float] = None
    handoff_attach: Optional[float] = None
    token_times: List[float] = field(default_factory=list)
    terminal: Optional[str] = None  # "done" | "shed" | "cancel" | "fail"
    end_t: Optional[float] = None

    # --- mirrors of Request's metric methods (same None/0.0 conventions) --
    @property
    def first_token_time(self) -> Optional[float]:
        return self.token_times[0] if self.token_times else None

    def ttft(self) -> Optional[float]:
        ft = self.first_token_time
        return None if ft is None else ft - self.arrival

    def mean_tpot(self) -> Optional[float]:
        if not self.token_times:
            return None
        if len(self.token_times) < 2:
            return 0.0
        return (self.token_times[-1] - self.token_times[0]) / (len(self.token_times) - 1)

    def decode_tput(self) -> Optional[float]:
        ft = self.first_token_time
        if self.end_t is None or ft is None or self.terminal != "done":
            return None
        dur = self.end_t - ft
        if dur <= 0:
            return None
        return len(self.token_times) / dur

    def meets_ttft(self) -> bool:
        t = self.ttft()
        return t is not None and t <= self.slo_ttft

    def meets_tpot(self) -> bool:
        t = self.mean_tpot()
        return t is not None and t <= self.slo_tpot

    def meets_e2e(self) -> bool:
        return self.meets_ttft() and self.meets_tpot()


def per_request_timelines(events: Iterable[Event]) -> Dict[int, RequestTimeline]:
    """Fold the stream into rid -> `RequestTimeline` (pool-level events,
    rid == -1, are skipped)."""
    tls: Dict[int, RequestTimeline] = {}
    for ev in events:
        if ev.rid < 0:
            continue
        tl = tls.get(ev.rid)
        if tl is None:
            tl = tls[ev.rid] = RequestTimeline(rid=ev.rid, arrival=ev.t)
        if ev.tenant:
            tl.tenant = ev.tenant
        if ev.pool:
            tl.pool = ev.pool
        if ev.type is EventType.SUBMIT:
            d = ev.data
            tl.arrival = d.get("arrival", ev.t)
            tl.input_len = d.get("input_len", 0)
            tl.output_len = d.get("output_len", 0)
            tl.slo_ttft = d.get("slo_ttft", float("inf"))
            tl.slo_tpot = d.get("slo_tpot", float("inf"))
            tl.slo_class = d.get("slo_class", "")
        elif ev.type is EventType.ADMIT:
            tl.admit_t = ev.t
        elif ev.type is EventType.PREFILL_START:
            if tl.prefill_start is None:
                tl.prefill_start = ev.t
        elif ev.type is EventType.PREFILL_END:
            tl.prefill_end = ev.t
        elif ev.type is EventType.HANDOFF_QUEUED:
            tl.handoff_queued = ev.t
        elif ev.type is EventType.HANDOFF_START:
            tl.handoff_start = ev.t
        elif ev.type is EventType.HANDOFF_ATTACH:
            tl.handoff_attach = ev.t
        elif ev.type is EventType.TOKEN:
            tl.token_times.append(ev.t)
        elif ev.type in TERMINAL_EVENTS:
            tl.terminal = ev.type.value
            tl.end_t = ev.t
    return tls


def attainment_from_events(
    events: Iterable[Event], done_only: bool = False
) -> Dict[str, float]:
    """`sim.metrics.attainment(...).as_dict()` recomputed from the stream.

    DONE timelines carry the fractions; SHED and FAIL terminals are the
    ``Phase.FAILED`` misses diluting them; CANCEL is excluded from the
    denominator entirely. On a ManualClock run with the default immediate
    pacer this is *equal* (not approximately) to the session's own
    aggregate — the cross-check pinned in tests/test_obs.py.
    """
    tls = list(per_request_timelines(events).values())
    done = [t for t in tls if t.terminal == "done"]
    shed = [] if done_only else [t for t in tls if t.terminal in ("shed", "fail")]
    n_cancelled = sum(t.terminal == "cancel" for t in tls)
    n = len(done) + len(shed)
    if n == 0:
        return dict(
            ttft=0.0, tpot=0.0, e2e=0.0, decode_tput_p50=0.0,
            decode_tput_mean=0.0, n=0, n_shed=0, n_cancelled=n_cancelled,
        )
    tputs = [t for t in (tl.decode_tput() for tl in done) if t is not None]
    return dict(
        ttft=sum(t.meets_ttft() for t in done) / n,
        tpot=sum(t.meets_tpot() for t in done) / n,
        e2e=sum(t.meets_e2e() for t in done) / n,
        decode_tput_p50=float(np.percentile(tputs, 50)) if tputs else 0.0,
        decode_tput_mean=float(np.mean(tputs)) if tputs else 0.0,
        n=n,
        n_shed=len(shed),
        n_cancelled=n_cancelled,
    )


# CANCEL data["stage"] values that mean the request was still holding a
# prefill-queue entry / an in-flight transfer when the client bailed
# ("handoff" = queued-but-not-started, which never entered the window)
_QUEUE_STAGES = frozenset({"queue"})
_TRANSFER_STAGES = frozenset({"transfer", "inflight"})


def windowed_slo(events: Iterable[Event], window: float) -> Dict[str, Any]:
    """Cut the run into ``window``-second virtual-time windows.

    A request belongs to the window its *terminal* event lands in (that is
    when its TTFT/TPOT verdict exists). Gauges are folded event-by-event:
    queue depth rises on ADMIT and falls on PREFILL_END (or a queue-stage
    CANCEL); in-flight transfers rise on HANDOFF_START and fall on
    HANDOFF_ATTACH (or a transfer-stage CANCEL). DECODE_STEP events
    contribute the per-step decode-time series checked against the batch's
    tightest TPOT budget (``data["tpot_budget"]``).
    """
    evs = sorted(events, key=lambda e: e.t)
    if window <= 0:
        raise ValueError(f"slo window must be positive, got {window}")
    tls = per_request_timelines(evs)
    t_end = evs[-1].t if evs else 0.0
    n_windows = max(1, int(t_end / window) + 1) if evs else 0

    wins: List[Dict[str, Any]] = []
    for i in range(n_windows):
        wins.append(
            dict(
                t0=i * window,
                t1=(i + 1) * window,
                submitted=0,
                done=0,
                shed=0,
                cancelled=0,
                tokens=0,
                ttft=0.0,
                tpot=0.0,
                e2e=0.0,
                queue_depth_max=0,
                queue_depth_last=0,
                inflight_max=0,
                inflight_last=0,
                decode_steps=0,
                decode_time_mean=0.0,
                tpot_budget_violations=0,
            )
        )

    def wix(t: float) -> int:
        return min(n_windows - 1, max(0, int(t / window)))

    # per-window attainment numerators/denominators
    met = [[0, 0, 0] for _ in range(n_windows)]  # ttft, tpot, e2e hits
    denom = [0] * n_windows
    for tl in tls.values():
        if tl.terminal is None or tl.end_t is None:
            continue
        w = wins[wix(tl.end_t)]
        if tl.terminal == "done":
            w["done"] += 1
            i = wix(tl.end_t)
            denom[i] += 1
            met[i][0] += tl.meets_ttft()
            met[i][1] += tl.meets_tpot()
            met[i][2] += tl.meets_e2e()
        elif tl.terminal in ("shed", "fail"):
            w["shed"] += 1
            denom[wix(tl.end_t)] += 1
        else:
            w["cancelled"] += 1

    queue_depth = 0
    inflight = 0
    step_times: List[List[float]] = [[] for _ in range(n_windows)]
    for ev in evs:
        i = wix(ev.t)
        w = wins[i]
        if ev.type is EventType.SUBMIT:
            w["submitted"] += 1
        elif ev.type is EventType.TOKEN:
            w["tokens"] += 1
        elif ev.type is EventType.ADMIT:
            queue_depth += 1
        elif ev.type is EventType.PREFILL_END:
            queue_depth = max(0, queue_depth - 1)
        elif ev.type is EventType.HANDOFF_START:
            inflight += 1
        elif ev.type is EventType.HANDOFF_ATTACH:
            inflight = max(0, inflight - 1)
        elif ev.type is EventType.CANCEL:
            stage = ev.data.get("stage", "")
            if stage in _QUEUE_STAGES:
                queue_depth = max(0, queue_depth - 1)
            elif stage in _TRANSFER_STAGES:
                inflight = max(0, inflight - 1)
        elif ev.type is EventType.DECODE_STEP:
            w["decode_steps"] += 1
            st = ev.data.get("step_time", 0.0)
            step_times[i].append(st)
            budget = ev.data.get("tpot_budget", 0.0)
            if budget and st > budget:
                w["tpot_budget_violations"] += 1
        w["queue_depth_max"] = max(w["queue_depth_max"], queue_depth)
        w["queue_depth_last"] = queue_depth
        w["inflight_max"] = max(w["inflight_max"], inflight)
        w["inflight_last"] = inflight

    for i, w in enumerate(wins):
        if denom[i]:
            w["ttft"] = met[i][0] / denom[i]
            w["tpot"] = met[i][1] / denom[i]
            w["e2e"] = met[i][2] / denom[i]
        if step_times[i]:
            w["decode_time_mean"] = float(np.mean(step_times[i]))

    return dict(window=window, n_windows=n_windows, windows=wins)


def trace_cell_block(
    events: Iterable[Event], slo_window: Optional[float] = None
) -> Dict[str, Any]:
    """The ``trace`` block a harness cell embeds when tracing is enabled.

    Aggregates only — the raw stream goes to ``--trace PATH`` files, this
    block goes into the cell JSON (key set pinned by RPA005). The
    ``attainment`` sub-block is `attainment_from_events`; comparing it to
    the cell's own report is the standing cross-check that emission points
    fire once per lifecycle transition.
    """
    evs = list(events)
    by_type: Dict[str, int] = {}
    term_counts: Dict[int, int] = {}
    for ev in evs:
        by_type[ev.type.value] = by_type.get(ev.type.value, 0) + 1
        if ev.rid >= 0 and ev.type in TERMINAL_EVENTS:
            term_counts[ev.rid] = term_counts.get(ev.rid, 0) + 1
    tls = per_request_timelines(evs)
    multi_terminal = sum(1 for c in term_counts.values() if c > 1)
    out = dict(
        events=len(evs),
        requests=len(tls),
        by_type=by_type,
        attainment=attainment_from_events(evs),
        multi_terminal=multi_terminal,
    )
    if slo_window is not None:
        out["slo"] = windowed_slo(evs, slo_window)
    return out
