"""repro.obs — unified per-request event tracing and SLO telemetry.

One typed event schema (`repro.obs.events`) emitted by every serving
substrate — `DisaggSimulator`, `ServeSession`, `AsyncServeSession`,
`RouterSession`, and the `DisaggSession` fleet — so a request's lifecycle
(submit → admit/shed → prefill → KV handoff → decode steps → tokens →
done/cancel) reads identically whichever backend served it. Exporters
(`repro.obs.export`) turn the stream into JSONL or Chrome trace-event /
Perfetto JSON; `repro.obs.slo` derives windowed TTFT/TPOT/e2e attainment,
queue-depth and in-flight-transfer gauges, and the per-step
decode-time-vs-TPOT-budget series — the live control signal the planned
failover/autoscaling loop consumes. See DESIGN.md §obs.

Clock discipline (RPA001): nothing in this package reads a clock. Every
timestamp is handed to `TraceRecorder.emit` by the emitting session, which
only ever passes values it already read from its injected `Clock` — so an
enabled recorder cannot perturb scheduling, and a disabled one (the
default, `trace=None`) costs nothing at all.
"""
from repro.obs.events import (
    Event,
    EventType,
    TERMINAL_EVENTS,
    TraceRecorder,
    check_terminal_invariant,
    counters_from_events,
)
from repro.obs.export import (
    chrome_trace,
    read_jsonl,
    write_chrome_trace,
    write_jsonl,
    write_trace,
)
from repro.obs.slo import (
    attainment_from_events,
    per_request_timelines,
    trace_cell_block,
    windowed_slo,
)

__all__ = [
    "Event",
    "EventType",
    "TERMINAL_EVENTS",
    "TraceRecorder",
    "attainment_from_events",
    "check_terminal_invariant",
    "chrome_trace",
    "counters_from_events",
    "per_request_timelines",
    "read_jsonl",
    "trace_cell_block",
    "windowed_slo",
    "write_chrome_trace",
    "write_jsonl",
    "write_trace",
]
