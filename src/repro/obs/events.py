"""Typed event taxonomy + the injectable `TraceRecorder`.

The taxonomy is the request lifecycle every backend shares:

    SUBMIT          request presented to admission control (t = declared
                    arrival — submission itself never reads a clock)
    ADMIT           admission control accepted it (prefix-hit accounting
                    rides in ``data`` when a PrefixCache is attached)
    SHED            admission control rejected it (``data["scope"]`` =
                    "global" | "tenant"); terminal, Phase.FAILED
    DEFLECT         disagg fleet: prefill deflected onto a decode worker
    ROUTE           router: replica chosen for the request
    PREFILL_START   first prefill chunk of the request begins
    PREFILL_END     prompt fully prefilled; first token exists
    HANDOFF_QUEUED  prefill→decode KV handoff enters the queue
    HANDOFF_START   handoff occupies an in-flight transfer slot
                    (``data["ready_at"]`` prices the wire time)
    HANDOFF_ATTACH  KV landed in a decode slot; decoding begins
    DECODE_STEP     one engine decode step (rid = -1: a pool-level event;
                    ``data``: batch, step_time, active, tpot_budget)
    TOKEN           one token produced for a request
    CANCEL          client withdrew the request (``data["stage"]`` says
                    where it was caught); terminal, Phase.CANCELLED
    DONE            request completed; terminal
    FAIL            engine crash containment tore the request down
                    (async frontend stepper crash); terminal

Fleet-control events (rid = -1 except RESTORE; `repro.serving.fleetctl`):

    REPLICA_DOWN    a replica died (``data["reason"]``: "killed" |
                    "scale-down"; killed replicas carry recovery stats)
    REPLICA_UP      a replica joined the fleet (``data["warmed_blocks"]``:
                    prefix-trie nodes inherited from survivors)
    RESTORE         one in-flight request restored onto a survivor after
                    its replica died (rid-scoped; ``data``: src/dst
                    replica, tokens already delivered → stream splice
                    point). NOT a terminal — the survivor's DONE is.
    SCALE           an autoscaler decision was applied (``data``: policy,
                    action, n_before/n_after, the windowed-SLO evidence)

Every request reaches **exactly one** terminal event (`TERMINAL_EVENTS`),
however it dies — cancel-mid-handoff included. `counters_from_events`
rebuilds the `SessionMetrics` counters from the stream; equality against
the session's own accounting is pinned in tests/test_obs.py.

The recorder is deliberately dumb: an append-only in-memory list with no
clock, no thresholds, no sampling. Disabled tracing is ``trace=None`` at
the session — emission sites guard on that, so the disabled path allocates
nothing and the enabled path only appends (it never reads time itself,
which is what keeps ManualClock runs bit-identical with tracing on; see
the overhead guard in tests/test_obs.py).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional


class EventType(str, enum.Enum):
    SUBMIT = "submit"
    ADMIT = "admit"
    SHED = "shed"
    DEFLECT = "deflect"
    ROUTE = "route"
    PREFILL_START = "prefill_start"
    PREFILL_END = "prefill_end"
    HANDOFF_QUEUED = "handoff_queued"
    HANDOFF_START = "handoff_start"
    HANDOFF_ATTACH = "handoff_attach"
    DECODE_STEP = "decode_step"
    TOKEN = "token"
    CANCEL = "cancel"
    DONE = "done"
    FAIL = "fail"
    REPLICA_DOWN = "replica_down"
    REPLICA_UP = "replica_up"
    RESTORE = "restore"
    SCALE = "scale"


# the events after which a request will never produce another event
TERMINAL_EVENTS = frozenset(
    {EventType.SHED, EventType.CANCEL, EventType.DONE, EventType.FAIL}
)


@dataclass
class Event:
    """One trace record. ``t`` is *virtual* time from the emitter's injected
    Clock (sim cost-model time for the simulator) — never host wall time.
    ``pool`` is the emitting track: "engine:0", "replica:1", "prefill:0",
    "decode:1", or "sim". ``rid`` is -1 for pool-level events
    (DECODE_STEP)."""

    type: EventType
    t: float
    rid: int = -1
    tenant: str = ""
    pool: str = ""
    slot: Optional[int] = None
    data: Dict[str, Any] = field(default_factory=dict)

    def as_dict(self) -> Dict[str, Any]:
        return dict(
            type=self.type.value,
            t=self.t,
            rid=self.rid,
            tenant=self.tenant,
            pool=self.pool,
            slot=self.slot,
            data=dict(self.data),
        )

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Event":
        return cls(
            type=EventType(d["type"]),
            t=float(d["t"]),
            rid=int(d.get("rid", -1)),
            tenant=d.get("tenant", ""),
            pool=d.get("pool", ""),
            slot=d.get("slot"),
            data=dict(d.get("data") or {}),
        )


class TraceRecorder:
    """Append-only in-memory event sink, injectable into every backend.

    Sessions default to ``trace=None`` (tracing off, zero cost); pass one
    recorder to as many sessions/pools as should share a timeline — the
    router hands the same recorder to every replica, the disagg fleet to
    every worker, each stamping its own ``pool`` label.
    """

    def __init__(self) -> None:
        self.events: List[Event] = []

    def emit(
        self,
        etype: EventType,
        t: float,
        rid: int = -1,
        tenant: str = "",
        pool: str = "",
        slot: Optional[int] = None,
        **data: Any,
    ) -> None:
        self.events.append(
            Event(type=etype, t=t, rid=rid, tenant=tenant, pool=pool, slot=slot, data=data)
        )

    def __len__(self) -> int:
        return len(self.events)

    def by_type(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for ev in self.events:
            counts[ev.type.value] = counts.get(ev.type.value, 0) + 1
        return counts

    def for_rid(self, rid: int) -> List[Event]:
        return [ev for ev in self.events if ev.rid == rid]

    def clear(self) -> None:
        self.events.clear()


def _bump(table: Dict[str, int], tenant: str) -> None:
    table[tenant] = table.get(tenant, 0) + 1


def counters_from_events(events: Iterable[Event]) -> Dict[str, Any]:
    """Rebuild the `SessionMetrics` counter block purely from the stream.

    The keys mirror `repro.serving.session.SessionMetrics` (minus
    ``backpressure_shed``, which is a frontend-policy annotation the session
    counts separately — its cancels still appear here as CANCEL events).
    Equality against a live session's metrics is the cross-check test that
    every emission point fires exactly once per lifecycle transition.
    """
    out: Dict[str, Any] = dict(
        submitted=0,
        accepted=0,
        rejected=0,
        rejected_global=0,
        rejected_tenant=0,
        completed=0,
        cancelled=0,
        failed=0,
        deflected=0,
        rejected_rids=[],
        cancelled_rids=[],
        submitted_by_tenant={},
        rejected_by_tenant={},
        completed_by_tenant={},
        cancelled_by_tenant={},
        prefix_lookups=0,
        prefix_hits=0,
        prefix_hit_tokens=0,
        prefix_lookup_tokens=0,
    )
    for ev in events:
        if ev.type is EventType.SUBMIT:
            out["submitted"] += 1
            _bump(out["submitted_by_tenant"], ev.tenant)
        elif ev.type is EventType.ADMIT:
            out["accepted"] += 1
            if "prefix_eligible" in ev.data:
                out["prefix_lookups"] += 1
                out["prefix_lookup_tokens"] += ev.data["prefix_eligible"]
                hit = ev.data.get("prefix_hit", 0)
                out["prefix_hit_tokens"] += hit
                if hit:
                    out["prefix_hits"] += 1
        elif ev.type is EventType.SHED:
            out["rejected"] += 1
            out["rejected_rids"].append(ev.rid)
            _bump(out["rejected_by_tenant"], ev.tenant)
            if ev.data.get("scope") == "tenant":
                out["rejected_tenant"] += 1
            else:
                out["rejected_global"] += 1
        elif ev.type is EventType.DONE:
            out["completed"] += 1
            _bump(out["completed_by_tenant"], ev.tenant)
        elif ev.type is EventType.CANCEL:
            out["cancelled"] += 1
            out["cancelled_rids"].append(ev.rid)
            _bump(out["cancelled_by_tenant"], ev.tenant)
        elif ev.type is EventType.FAIL:
            out["failed"] += 1
        elif ev.type is EventType.DEFLECT:
            out["deflected"] += 1
    return out


def check_terminal_invariant(events: Iterable[Event]) -> Dict[int, List[str]]:
    """rid -> terminal event types seen. A well-formed stream has exactly
    one terminal per rid that ever reached SUBMIT; violations (0 for a
    drained run, or 2+, e.g. a double cancel) are what the invariant test
    hunts for."""
    seen: Dict[int, List[str]] = {}
    for ev in events:
        if ev.rid < 0:
            continue
        seen.setdefault(ev.rid, [])
        if ev.type in TERMINAL_EVENTS:
            seen[ev.rid].append(ev.type.value)
    return seen
