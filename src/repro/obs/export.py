"""Trace exporters: JSONL event log + Chrome trace-event / Perfetto JSON.

Two formats off one stream:

  * ``write_jsonl`` — one `Event.as_dict()` per line, lossless; round-trips
    through ``read_jsonl`` for offline analysis (`repro.obs.slo` runs on the
    re-read stream unchanged).
  * ``write_chrome_trace`` — the Chrome trace-event JSON object format
    (``{"traceEvents": [...]}``) that https://ui.perfetto.dev and
    ``chrome://tracing`` load directly. Pools/replicas are *processes*,
    decode slots are *threads* (tid = slot + 1; tid 0 is the pool's
    scheduler track), and each request renders as three slices — prefill,
    handoff, decode — plus a TTFT flow arrow from its SUBMIT instant to its
    first TOKEN. Queue depth and in-flight transfers render as counter
    tracks.

``write_trace`` dispatches on the path suffix: ``.jsonl`` writes the event
log, anything else the Chrome JSON. Timestamps are emitted in microseconds
(the trace-event unit) from the events' virtual-time seconds; traceEvents
are sorted by timestamp (metadata first), so per-track timestamps are
monotone — the shape CI's smoke job validates.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs.events import Event, EventType

# instants worth a mark on the scheduler track
_INSTANT_TYPES = (
    EventType.SUBMIT,
    EventType.SHED,
    EventType.DEFLECT,
    EventType.ROUTE,
    EventType.CANCEL,
    EventType.FAIL,
    EventType.REPLICA_DOWN,
    EventType.REPLICA_UP,
    EventType.RESTORE,
    EventType.SCALE,
)


def write_jsonl(events: Iterable[Event], path: str) -> None:
    with open(path, "w", encoding="utf-8") as f:
        for ev in events:
            f.write(json.dumps(ev.as_dict(), sort_keys=True) + "\n")


def read_jsonl(path: str) -> List[Event]:
    out: List[Event] = []
    with open(path, encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(Event.from_dict(json.loads(line)))
            except (ValueError, KeyError) as e:
                raise ValueError(f"{path}:{i}: malformed trace event: {e}") from None
    return out


def _us(t: float) -> float:
    return t * 1e6


def _pid_table(events: Sequence[Event]) -> Dict[str, int]:
    """Stable pool-label -> pid assignment (sorted; '' last as 'session')."""
    labels = sorted({ev.pool for ev in events})
    return {label: i + 1 for i, label in enumerate(labels)}


def _tid(ev: Event) -> int:
    return 0 if ev.slot is None else ev.slot + 1


def chrome_trace(events: Sequence[Event]) -> Dict[str, Any]:
    """Build the Chrome trace-event JSON object for one event stream."""
    pids = _pid_table(events)
    out: List[Dict[str, Any]] = []

    # ---- metadata: name every process and thread we will reference ------
    tids_by_pid: Dict[int, set] = {}
    for ev in events:
        tids_by_pid.setdefault(pids[ev.pool], set()).add(_tid(ev))
    for label, pid in pids.items():
        out.append(
            dict(
                name="process_name", ph="M", pid=pid, tid=0, ts=0.0,
                args=dict(name=label or "session"),
            )
        )
        for tid in sorted(tids_by_pid.get(pid, {0})):
            out.append(
                dict(
                    name="thread_name", ph="M", pid=pid, tid=tid, ts=0.0,
                    args=dict(name="scheduler" if tid == 0 else f"slot {tid - 1}"),
                )
            )

    # ---- per-request phase boundaries (for the three slices + TTFT flow)
    start_of: Dict[Tuple[int, str], Event] = {}
    first_token: Dict[int, Event] = {}
    submit: Dict[int, Event] = {}
    body: List[Dict[str, Any]] = []
    queue_depth = 0
    inflight = 0

    def slice_ev(name: str, a: Event, b: Event, *, tid: Optional[int] = None,
                 args: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        return dict(
            name=name, cat="request", ph="X",
            ts=_us(a.t), dur=max(0.0, _us(b.t) - _us(a.t)),
            pid=pids[b.pool], tid=_tid(b) if tid is None else tid,
            args=dict(rid=a.rid, **(args or {})),
        )

    for ev in events:
        pid, tid = pids[ev.pool], _tid(ev)
        if ev.type in _INSTANT_TYPES:
            body.append(
                dict(
                    name=f"{ev.type.value} r{ev.rid}", cat="lifecycle", ph="i",
                    ts=_us(ev.t), pid=pid, tid=tid, s="t",
                    args=dict(rid=ev.rid, tenant=ev.tenant, **ev.data),
                )
            )
        if ev.type is EventType.SUBMIT:
            submit[ev.rid] = ev
        elif ev.type is EventType.PREFILL_START:
            start_of[(ev.rid, "prefill")] = ev
        elif ev.type is EventType.PREFILL_END:
            a = start_of.pop((ev.rid, "prefill"), None)
            if a is not None:
                body.append(slice_ev(f"prefill r{ev.rid}", a, ev))
        elif ev.type is EventType.HANDOFF_START:
            start_of[(ev.rid, "handoff")] = ev
            inflight += 1
            body.append(
                dict(
                    name="inflight_transfers", ph="C", ts=_us(ev.t),
                    pid=pid, tid=0, args=dict(value=inflight),
                )
            )
        elif ev.type is EventType.HANDOFF_ATTACH:
            a = start_of.pop((ev.rid, "handoff"), None)
            if a is not None:
                body.append(
                    slice_ev(f"handoff r{ev.rid}", a, ev,
                             args=dict(dst=ev.pool))
                )
            start_of[(ev.rid, "decode")] = ev
            inflight = max(0, inflight - 1)
            body.append(
                dict(
                    name="inflight_transfers", ph="C", ts=_us(ev.t),
                    pid=pid, tid=0, args=dict(value=inflight),
                )
            )
        elif ev.type is EventType.TOKEN:
            if ev.rid not in first_token:
                first_token[ev.rid] = ev
                sub = submit.get(ev.rid)
                if sub is not None:
                    # TTFT flow arrow: submit instant -> first token
                    fid = ev.rid + 1  # flow ids must be non-zero
                    body.append(
                        dict(
                            name="ttft", cat="slo", ph="s", id=fid,
                            ts=_us(sub.t), pid=pids[sub.pool], tid=_tid(sub),
                            args=dict(rid=ev.rid),
                        )
                    )
                    body.append(
                        dict(
                            name="ttft", cat="slo", ph="f", bp="e", id=fid,
                            ts=_us(ev.t), pid=pid, tid=tid,
                            args=dict(rid=ev.rid, ttft=ev.t - sub.data.get("arrival", sub.t)),
                        )
                    )
        elif ev.type in (EventType.DONE, EventType.CANCEL, EventType.FAIL):
            a = start_of.pop((ev.rid, "decode"), None)
            if a is not None:
                body.append(
                    slice_ev(f"decode r{ev.rid}", a, ev, tid=_tid(a),
                             args=dict(outcome=ev.type.value))
                )
        elif ev.type is EventType.DECODE_STEP:
            body.append(
                dict(
                    name="decode_step", cat="engine", ph="i",
                    ts=_us(ev.t), pid=pid, tid=tid, s="p",
                    args=dict(ev.data),
                )
            )
        # queue-depth gauge: sessions sample it into ADMIT / PREFILL_END data
        if "queue_depth" in ev.data:
            queue_depth = ev.data["queue_depth"]
            body.append(
                dict(
                    name="queue_depth", ph="C", ts=_us(ev.t),
                    pid=pid, tid=0, args=dict(value=queue_depth),
                )
            )

    body.sort(key=lambda e: e["ts"])
    return dict(
        traceEvents=out + body,
        displayTimeUnit="ms",
        otherData=dict(generator="repro.obs", events=len(events)),
    )


def write_chrome_trace(events: Sequence[Event], path: str) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(chrome_trace(events), f, indent=1, sort_keys=True)
        f.write("\n")


def write_trace(events: Sequence[Event], path: str) -> str:
    """Write ``path`` in the format its suffix implies: ``.jsonl`` = raw
    event log, anything else = Chrome trace-event JSON. Returns the format
    written ("jsonl" | "chrome")."""
    if str(path).endswith(".jsonl"):
        write_jsonl(events, path)
        return "jsonl"
    write_chrome_trace(events, path)
    return "chrome"
