"""Unified model API over all families.

  model = build_model(cfg)
  params = model.init(key)
  logits = model.forward_train(params, batch)       # batch: dict
  logits, kv = model.prefill(params, batch)
  logits, cache = model.decode(params, tokens, positions, cache)
  cache = model.init_cache(batch, max_len)          # zeros, allocated
  spec  = cache_struct(cfg, batch, max_len)         # ShapeDtypeStructs only
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.models.layers import dtype_of


def _materialize(shapes: Dict, make_leaf):
    return jax.tree.map(
        make_leaf,
        shapes,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[1], str),
    )


def _cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    if cfg.is_encdec:
        return encdec.cache_shapes(cfg, batch, max_len)
    return transformer.cache_shapes(cfg, batch, max_len)


def cache_struct(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    return _materialize(
        _cache_shapes(cfg, batch, max_len),
        lambda sd: jax.ShapeDtypeStruct(sd[0], dtype_of(sd[1])),
    )


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict:
        if self.cfg.is_encdec:
            return encdec.init_params(self.cfg, key)
        return transformer.init_params(self.cfg, key)

    def init_cache(self, batch: int, max_len: int) -> Dict:
        return _materialize(
            _cache_shapes(self.cfg, batch, max_len),
            lambda sd: jnp.zeros(sd[0], dtype_of(sd[1])),
        )

    # ----------------------------------------------------------------- train
    def forward_train(self, params: Dict, batch: Dict, remat: bool = True) -> jax.Array:
        if self.cfg.is_encdec:
            return encdec.forward_train(params, batch["src"], batch["tgt"], self.cfg)
        return transformer.forward_train(params, batch["inputs"], self.cfg, remat=remat)

    def loss(self, params: Dict, batch: Dict, remat: bool = True) -> jax.Array:
        logits = self.forward_train(params, batch, remat=remat)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    # --------------------------------------------------------------- serving
    def prefill(self, params: Dict, batch: Dict, valid_len: Optional[jax.Array] = None):
        if self.cfg.is_encdec:
            return encdec.prefill_step(params, batch["src"], batch["tgt"], self.cfg, tgt_valid=valid_len)
        return transformer.prefill_step(params, batch["inputs"], self.cfg, valid_len)

    def decode(self, params: Dict, tokens: jax.Array, positions: jax.Array, cache: Dict):
        if self.cfg.is_encdec:
            return encdec.decode_step(params, tokens, positions, self.cfg, cache)
        return transformer.decode_step(params, tokens, positions, self.cfg, cache)

    # ------------------------------------------------------------------ misc
    def param_struct(self, key=None) -> Dict:
        """ShapeDtypeStruct pytree of params via eval_shape (no allocation)."""
        k = jax.random.key(0) if key is None else key
        return jax.eval_shape(self.init, k)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
