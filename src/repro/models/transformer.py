"""Decoder trunk: dense / MoE / SSM / hybrid families, scan-over-layers.

Three entry points (all shape-polymorphic over batch):
  forward_train(params, inputs, cfg)                -> logits (B, S, V)
  prefill_step(params, inputs, cfg, valid_len)      -> (last_logits (B,V), kv_out)
  decode_step(params, tokens, positions, cfg, cache)-> (logits (B,V), cache')

Prefill produces the KV pytree that a disaggregated deployment ships to the
decode instance; decode consumes/updates a preallocated cache.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.act_sharding import constrain_batch
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    attention,
    dense_init,
    dtype_of,
    embed_init,
    gated_mlp,
    rms_norm,
    rope,
    softcap,
)
from repro.models.moe import init_moe_params, moe_ffn

_GLOBAL_WINDOW = 1 << 30  # "no window" sentinel for traced window values


# ----------------------------------------------------------------------------
# Param init
# ----------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, dtype) -> Dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = dict(
        wq=dense_init(ks[0], (d, hq * hd), dtype),
        wk=dense_init(ks[1], (d, hkv * hd), dtype),
        wv=dense_init(ks[2], (d, hkv * hd), dtype),
        wo=dense_init(ks[3], (hq * hd, d), dtype),
    )
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _init_mlp(key, cfg: ModelConfig, dtype) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return dict(
        w_gate=dense_init(ks[0], (d, f), dtype),
        w_up=dense_init(ks[1], (d, f), dtype),
        w_down=dense_init(ks[2], (f, d), dtype),
    )


def _init_dense_layer(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return dict(
        attn=_init_attn(k1, cfg, dtype),
        mlp=_init_mlp(k2, cfg, dtype),
        pre_attn_norm=jnp.zeros((cfg.d_model,), dtype),
        pre_mlp_norm=jnp.zeros((cfg.d_model,), dtype),
    )


def _init_moe_layer(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return dict(
        attn=_init_attn(k1, cfg, dtype),
        moe=init_moe_params(k2, cfg, dtype),
        pre_attn_norm=jnp.zeros((cfg.d_model,), dtype),
        pre_mlp_norm=jnp.zeros((cfg.d_model,), dtype),
    )


def _init_ssm_layer(key, cfg: ModelConfig, dtype) -> Dict:
    return dict(
        ssm=ssm_mod.init_ssm_params(key, cfg, dtype),
        pre_norm=jnp.zeros((cfg.d_model,), dtype),
    )


def _stack_layers(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def init_params(cfg: ModelConfig, key) -> Dict:
    dtype = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 5)
    params: Dict = dict(
        embed=embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        final_norm=jnp.zeros((cfg.d_model,), dtype),
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype)

    if cfg.family in ("dense", "vlm"):
        params["layers"] = _stack_layers(
            ks[2], cfg.num_layers, partial(_init_dense_layer, cfg=cfg, dtype=dtype)
        )
    elif cfg.family == "moe":
        params["layers"] = _stack_layers(
            ks[2], cfg.num_layers, partial(_init_moe_layer, cfg=cfg, dtype=dtype)
        )
    elif cfg.family == "ssm":
        params["layers"] = _stack_layers(
            ks[2], cfg.num_layers, partial(_init_ssm_layer, cfg=cfg, dtype=dtype)
        )
    elif cfg.family == "hybrid":
        ns, per = _hybrid_blocks(cfg)
        inner = _stack_layers(
            ks[2], ns * per, partial(_init_ssm_layer, cfg=cfg, dtype=dtype)
        )
        params["layers"] = jax.tree.map(
            lambda x: x.reshape(ns, per, *x.shape[1:]), inner
        )
        params["shared_attn"] = _init_dense_layer(ks[3], cfg, dtype)
    else:
        raise ValueError(f"family {cfg.family} not handled by transformer trunk")
    return params


def _hybrid_blocks(cfg: ModelConfig) -> Tuple[int, int]:
    per = cfg.hybrid_period
    assert cfg.num_layers % per == 0, "hybrid depth must divide period"
    return cfg.num_layers // per, per


# ----------------------------------------------------------------------------
# Blocks
# ----------------------------------------------------------------------------

def _attn_qkv(p: Dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block(
    p: Dict,
    x: jax.Array,
    positions: jax.Array,  # (B, S)
    cfg: ModelConfig,
    *,
    window=None,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (B,M,Hkv,Dh) x2
    kv_valid: Optional[jax.Array] = None,  # (B,)
    q_seg=None,
    kv_seg=None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Attention sublayer. Returns (out, (k, v)).

    Without kv_cache: self-attention within the chunk; returns chunk K/V.
    With kv_cache: scatter this chunk's K/V into the cache at `positions`,
    attend against the whole cache; returns the updated cache K/V.
    """
    b, s, _ = x.shape
    q, k, v = _attn_qkv(p, x, positions, cfg)
    if kv_cache is None:
        out = attention(
            q, k, v, positions, kv_valid,
            window=window, causal=True, logit_cap=cfg.attn_logit_softcap,
            q_seg=q_seg, kv_seg=kv_seg, impl=cfg.attn_impl,
        )
        new_kv = (k, v)
    else:
        ck, cv = kv_cache
        if s == 1:
            # one-hot (select) update instead of scatter: SPMD cannot
            # partition a per-batch scatter into a sharded cache and falls
            # back to all-gathering the whole cache every step (measured
            # 170 GB/chip/step); the elementwise select partitions cleanly.
            m = ck.shape[1]
            hit = (
                jax.lax.broadcasted_iota(jnp.int32, (b, m), 1)
                == positions[:, :1]
            )[:, :, None, None]
            ck = jnp.where(hit, k[:, 0][:, None], ck)
            cv = jnp.where(hit, v[:, 0][:, None], cv)
        else:
            start = positions[:, 0]
            ck = jax.vmap(
                lambda c, kk, st: jax.lax.dynamic_update_slice(c, kk, (st, 0, 0))
            )(ck, k, start)
            cv = jax.vmap(
                lambda c, vv, st: jax.lax.dynamic_update_slice(c, vv, (st, 0, 0))
            )(cv, v, start)
        out = attention(
            q, ck, cv, positions, kv_valid,
            window=window, causal=True, logit_cap=cfg.attn_logit_softcap,
            impl=cfg.attn_impl,
        )
        new_kv = (ck, cv)
    out = out.reshape(b, s, cfg.num_heads * cfg.resolved_head_dim)
    return jnp.einsum("bse,ed->bsd", out, p["wo"]), new_kv


def _ffn(layer: Dict, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    hn = rms_norm(h, layer["pre_mlp_norm"], cfg.norm_eps)
    if cfg.family == "moe":
        return moe_ffn(hn, layer["moe"], cfg)
    return gated_mlp(hn, layer["mlp"]["w_gate"], layer["mlp"]["w_up"], layer["mlp"]["w_down"], cfg.act)


def _layer_window(cfg: ModelConfig, is_local):
    """Per-layer effective window (traced int32) for alternating local/global."""
    if not cfg.alternate_local_global:
        return cfg.sliding_window if cfg.sliding_window else None
    return jnp.where(is_local, cfg.sliding_window, _GLOBAL_WINDOW).astype(jnp.int32)


def _layer_flags(cfg: ModelConfig, n: int) -> jax.Array:
    """is_local flag per layer (gemma2: even layers local)."""
    if cfg.alternate_local_global:
        return (jnp.arange(n) % 2 == 0)
    return jnp.zeros((n,), bool)


# ----------------------------------------------------------------------------
# Trunk application (train / prefill: no external cache)
# ----------------------------------------------------------------------------

def _embed_inputs(params: Dict, inputs: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.input_mode == "embeddings":
        return inputs.astype(dtype_of(cfg.dtype))
    return params["embed"][inputs]


def logits_from_hidden(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["lm_head"])
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return logits


def _trunk_nocache(
    params: Dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    valid_len: Optional[jax.Array],
    collect_kv: bool,
    remat: bool,
    q_seg=None,
    kv_seg=None,
):
    """Scan over layers without an external cache. Returns (x, kv_stack)."""

    if cfg.family in ("dense", "vlm", "moe"):

        def body(h, xs):
            layer, is_local = xs
            win = _layer_window(cfg, is_local)
            a_out, (k, v) = attn_block(
                layer["attn"],
                rms_norm(h, layer["pre_attn_norm"], cfg.norm_eps),
                positions, cfg, window=win, kv_valid=valid_len,
                q_seg=q_seg, kv_seg=kv_seg,
            )
            h = h + a_out
            h = h + _ffn(layer, h, cfg)
            ys = (k, v) if collect_kv else None
            return h, ys

        if remat:
            body = jax.checkpoint(body)
        x, kv = jax.lax.scan(body, x, (params["layers"], _layer_flags(cfg, cfg.num_layers)))
        return x, kv

    if cfg.family == "ssm":

        def body(h, layer):
            o, cache = ssm_mod.ssm_forward(
                layer["ssm"], rms_norm(h, layer["pre_norm"], cfg.norm_eps), cfg, valid_len
            )
            return h + o, cache if collect_kv else None

        if remat:
            body = jax.checkpoint(body)
        x, caches = jax.lax.scan(body, x, params["layers"])
        return x, caches

    if cfg.family == "hybrid":
        shared = params["shared_attn"]

        def inner(h, layer):
            o, cache = ssm_mod.ssm_forward(
                layer["ssm"], rms_norm(h, layer["pre_norm"], cfg.norm_eps), cfg, valid_len
            )
            return h + o, cache if collect_kv else None

        def super_body(h, xs):
            layers_blk = xs
            h, ssm_caches = jax.lax.scan(inner, h, layers_blk)
            a_out, (k, v) = attn_block(
                shared["attn"],
                rms_norm(h, shared["pre_attn_norm"], cfg.norm_eps),
                positions, cfg, kv_valid=valid_len,
            )
            h = h + a_out
            h = h + gated_mlp(
                rms_norm(h, shared["pre_mlp_norm"], cfg.norm_eps),
                shared["mlp"]["w_gate"], shared["mlp"]["w_up"], shared["mlp"]["w_down"], cfg.act,
            )
            ys = (ssm_caches, (k, v)) if collect_kv else None
            return h, ys

        if remat:
            super_body = jax.checkpoint(super_body)
        x, caches = jax.lax.scan(super_body, x, params["layers"])
        return x, caches

    raise ValueError(cfg.family)


def forward_train(
    params: Dict, inputs: jax.Array, cfg: ModelConfig, remat: bool = True
) -> jax.Array:
    """Full causal forward; returns logits (B, S, V)."""
    x = constrain_batch(_embed_inputs(params, inputs, cfg))
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _ = _trunk_nocache(params, x, positions, cfg, None, collect_kv=False, remat=remat)
    return logits_from_hidden(params, x, cfg)


def prefill_step(
    params: Dict,
    inputs: jax.Array,
    cfg: ModelConfig,
    valid_len: Optional[jax.Array] = None,
):
    """One-shot prefill: returns (last-token logits (B, V), kv pytree).

    The kv pytree is what gets transferred to the decode instance:
      attention families: (k, v) stacked (L, B, S, Hkv, Dh)
      ssm: dict(conv=(L,B,W-1,C), state=(L,B,H,P,N))
      hybrid: (ssm_caches, attn_kv) stacked by super-block
    """
    x = constrain_batch(_embed_inputs(params, inputs, cfg))
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if valid_len is None:
        valid_len = jnp.full((b,), s, jnp.int32)
    x, kv = _trunk_nocache(params, x, positions, cfg, valid_len, collect_kv=True, remat=False)
    last = jnp.take_along_axis(x, (valid_len - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return logits_from_hidden(params, last, cfg), kv


# ----------------------------------------------------------------------------
# Decode (external cache)
# ----------------------------------------------------------------------------

def decode_step(
    params: Dict,
    tokens: jax.Array,  # (B, 1) int32
    positions: jax.Array,  # (B,) current write position (= tokens generated so far + prompt len)
    cfg: ModelConfig,
    cache: Dict,
):
    """Single-token decode. Returns (logits (B, V), updated cache)."""
    x = constrain_batch(params["embed"][tokens])
    b = tokens.shape[0]
    pos2 = positions[:, None]
    kv_valid = positions + 1

    if cfg.family in ("dense", "vlm", "moe"):
        if "k_local" in cache:
            return _decode_step_windowed(params, x, positions, cfg, cache)

        def body(h, xs):
            layer, is_local, ck, cv = xs
            win = _layer_window(cfg, is_local)
            a_out, (ck2, cv2) = attn_block(
                layer["attn"],
                rms_norm(h, layer["pre_attn_norm"], cfg.norm_eps),
                pos2, cfg, window=win, kv_cache=(ck, cv), kv_valid=kv_valid,
            )
            h = h + a_out
            h = h + _ffn(layer, h, cfg)
            return h, (ck2, cv2)

        x, (ck, cv) = jax.lax.scan(
            body, x, (params["layers"], _layer_flags(cfg, cfg.num_layers), cache["k"], cache["v"])
        )
        new_cache = dict(k=ck, v=cv)

    elif cfg.family == "ssm":

        def body(h, xs):
            layer, conv, state = xs
            o, c2 = ssm_mod.ssm_decode_step(
                layer["ssm"], rms_norm(h, layer["pre_norm"], cfg.norm_eps), cfg,
                dict(conv=conv, state=state),
            )
            return h + o, (c2["conv"], c2["state"])

        x, (conv, state) = jax.lax.scan(body, x, (params["layers"], cache["conv"], cache["state"]))
        new_cache = dict(conv=conv, state=state)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def inner(h, xs):
            layer, conv, state = xs
            o, c2 = ssm_mod.ssm_decode_step(
                layer["ssm"], rms_norm(h, layer["pre_norm"], cfg.norm_eps), cfg,
                dict(conv=conv, state=state),
            )
            return h + o, (c2["conv"], c2["state"])

        def super_body(h, xs):
            layers_blk, conv_blk, state_blk, ck, cv = xs
            h, (conv2, state2) = jax.lax.scan(inner, h, (layers_blk, conv_blk, state_blk))
            a_out, (ck2, cv2) = attn_block(
                shared["attn"],
                rms_norm(h, shared["pre_attn_norm"], cfg.norm_eps),
                pos2, cfg, kv_cache=(ck, cv), kv_valid=kv_valid,
            )
            h = h + a_out
            h = h + gated_mlp(
                rms_norm(h, shared["pre_mlp_norm"], cfg.norm_eps),
                shared["mlp"]["w_gate"], shared["mlp"]["w_up"], shared["mlp"]["w_down"], cfg.act,
            )
            return h, (conv2, state2, ck2, cv2)

        x, (conv, state, ck, cv) = jax.lax.scan(
            super_body, x,
            (params["layers"], cache["conv"], cache["state"], cache["k"], cache["v"]),
        )
        new_cache = dict(conv=conv, state=state, k=ck, v=cv)
    else:
        raise ValueError(cfg.family)

    logits = logits_from_hidden(params, x[:, 0], cfg)
    return logits, new_cache


def chunk_prefill_step(
    params: Dict,
    tokens: jax.Array,  # (B, C) — one chunk per request, right-padded
    start: jax.Array,  # (B,) context offset (tokens already prefilled)
    valid: jax.Array,  # (B,) valid tokens in this chunk (<= C)
    cfg: ModelConfig,
    cache: Dict,
):
    """Chunked prefill (Sarathi-style): writes this chunk's KV into the cache
    at `start` and attends to cache[0 : start+valid]. Returns
    (last-valid-token logits (B, V), updated cache). Attention families only
    (the SSM prefill path carries state through ssm_forward instead)."""
    assert cfg.family in ("dense", "vlm", "moe"), cfg.family
    x = constrain_batch(params["embed"][tokens])
    b, c = tokens.shape
    positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    kv_valid = start + valid

    def body(h, xs):
        layer, is_local, ck, cv = xs
        win = _layer_window(cfg, is_local)
        a_out, (ck2, cv2) = attn_block(
            layer["attn"],
            rms_norm(h, layer["pre_attn_norm"], cfg.norm_eps),
            positions, cfg, window=win, kv_cache=(ck, cv), kv_valid=kv_valid,
        )
        h = h + a_out
        h = h + _ffn(layer, h, cfg)
        return h, (ck2, cv2)

    x, (ck, cv) = jax.lax.scan(
        body, x, (params["layers"], _layer_flags(cfg, cfg.num_layers), cache["k"], cache["v"])
    )
    last = jnp.take_along_axis(
        x, jnp.maximum(valid - 1, 0)[:, None, None].astype(jnp.int32), axis=1
    )[:, 0]
    return logits_from_hidden(params, last, cfg), dict(k=ck, v=cv)


# ----------------------------------------------------------------------------
# Cache structure
# ----------------------------------------------------------------------------

def _decode_step_windowed(params: Dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig, cache: Dict):
    """Decode for alternating local/global archs with a ring cache for the
    local layers: scan over (local, global) layer pairs. The ring stores the
    last `W = sliding_window` positions; slot j holds absolute position
    a_j = pos - ((pos - j) mod W), valid iff a_j >= 0."""
    from repro.models.layers import naive_attention  # local import, no cycle

    b = x.shape[0]
    W = cfg.sliding_window
    half = cfg.num_layers // 2
    pos2 = positions[:, None]
    kv_valid = positions + 1
    pairs = jax.tree.map(lambda a: a.reshape(half, 2, *a.shape[1:]), params["layers"])

    def local_attn(layer, h):
        hn = rms_norm(h, layer["pre_attn_norm"], cfg.norm_eps)
        q, k, v = _attn_qkv(layer["attn"], hn, pos2, cfg)
        return q, k, v

    def body(h, xs):
        pair, ck_l, cv_l, ck_g, cv_g = xs
        loc = jax.tree.map(lambda a: a[0], pair)
        glo = jax.tree.map(lambda a: a[1], pair)

        # ---- local layer: ring cache ------------------------------------
        q, k, v = local_attn(loc, h)
        slot = jnp.mod(positions, W)  # (B,)
        hit = (
            jax.lax.broadcasted_iota(jnp.int32, (b, W), 1) == slot[:, None]
        )[:, :, None, None]
        ck_l = jnp.where(hit, k[:, 0][:, None], ck_l)
        cv_l = jnp.where(hit, v[:, 0][:, None], cv_l)
        jj = jax.lax.broadcasted_iota(jnp.int32, (b, W), 1)
        a_j = positions[:, None] - jnp.mod(positions[:, None] - jj, W)
        mask = (a_j >= 0)[:, None, :]  # (B, 1, W); causality is structural
        a_out = naive_attention(q, ck_l, cv_l, mask, cfg.attn_logit_softcap)
        a_out = a_out.reshape(b, 1, cfg.num_heads * cfg.resolved_head_dim)
        h = h + jnp.einsum("bse,ed->bsd", a_out, loc["attn"]["wo"])
        h = h + _ffn(loc, h, cfg)

        # ---- global layer: standard full cache ---------------------------
        a_out, (ck_g2, cv_g2) = attn_block(
            glo["attn"],
            rms_norm(h, glo["pre_attn_norm"], cfg.norm_eps),
            pos2, cfg, kv_cache=(ck_g, cv_g), kv_valid=kv_valid,
        )
        h = h + a_out
        h = h + _ffn(glo, h, cfg)
        return h, (ck_l, cv_l, ck_g2, cv_g2)

    x, (ck_l, cv_l, ck_g, cv_g) = jax.lax.scan(
        body, x, (pairs, cache["k_local"], cache["v_local"], cache["k"], cache["v"])
    )
    logits = logits_from_hidden(params, x[:, 0], cfg)
    return logits, dict(k=ck_g, v=cv_g, k_local=ck_l, v_local=cv_l)


def _use_windowed_cache(cfg: ModelConfig, max_len: int) -> bool:
    return (
        cfg.alternate_local_global
        and cfg.sliding_window > 0
        and max_len > cfg.sliding_window
        and cfg.num_layers % 2 == 0
    )


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Returns a pytree of (shape, dtype-name) describing the decode cache.

    Alternating local/global archs (gemma2) get a windowed ring cache for
    the local layers: half the layers only ever attend to the last
    `sliding_window` positions, so storing (and more importantly *reading*,
    every decode step) their full-context KV wastes ~0.5x of the decode
    memory roofline (§Perf iteration D6)."""
    hd = cfg.resolved_head_dim
    if cfg.family in ("dense", "vlm", "moe"):
        if _use_windowed_cache(cfg, max_len):
            half = cfg.num_layers // 2
            kv_g = (half, batch, max_len, cfg.num_kv_heads, hd)
            kv_l = (half, batch, cfg.sliding_window, cfg.num_kv_heads, hd)
            return dict(
                k=(kv_g, cfg.dtype),
                v=(kv_g, cfg.dtype),
                k_local=(kv_l, cfg.dtype),
                v_local=(kv_l, cfg.dtype),
            )
        kv = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
        return dict(k=(kv, cfg.dtype), v=(kv, cfg.dtype))
    if cfg.family == "ssm":
        s = ssm_mod.ssm_cache_shape(cfg, batch)
        return dict(
            conv=((cfg.num_layers, *s["conv"][0]), s["conv"][1]),
            state=((cfg.num_layers, *s["state"][0]), s["state"][1]),
        )
    if cfg.family == "hybrid":
        ns, per = _hybrid_blocks(cfg)
        s = ssm_mod.ssm_cache_shape(cfg, batch)
        kv = (ns, batch, max_len, cfg.num_kv_heads, hd)
        return dict(
            conv=((ns, per, *s["conv"][0]), s["conv"][1]),
            state=((ns, per, *s["state"][0]), s["state"][1]),
            k=(kv, cfg.dtype),
            v=(kv, cfg.dtype),
        )
    raise ValueError(cfg.family)
