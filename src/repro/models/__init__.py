from repro.models.model import Model, build_model, cache_struct

__all__ = ["Model", "build_model", "cache_struct"]
