"""Mamba2 (SSD, state-space duality) block in pure JAX.

Implements the chunked SSD algorithm (intra-chunk quadratic + inter-chunk
state recurrence via lax.scan) for train/prefill and the O(1)-per-token
recurrent form for decode. Matches the reference `ssd_minimal_discrete`
semantics from the Mamba2 paper.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, gated_rms_norm


def conv_channels(cfg: ModelConfig) -> int:
    return cfg.ssm_d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state_dim


def init_ssm_params(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    h = cfg.ssm_num_heads
    g, n, w = cfg.ssm_ngroups, cfg.ssm_state_dim, cfg.ssm_conv_width
    cch = conv_channels(cfg)
    d_in_proj = 2 * di + 2 * g * n + h
    ks = jax.random.split(key, 6)
    # dt bias: inverse-softplus of dt ~ U[1e-3, 1e-1]
    dt = jnp.exp(
        jax.random.uniform(ks[0], (h,), jnp.float32)
        * (math.log(0.1) - math.log(1e-3))
        + math.log(1e-3)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return dict(
        in_proj=dense_init(ks[1], (d, d_in_proj), dtype),
        conv_w=(jax.random.normal(ks[2], (w, cch), jnp.float32) / math.sqrt(w)).astype(dtype),
        conv_b=jnp.zeros((cch,), dtype),
        A_log=jnp.log(
            jax.random.uniform(ks[3], (h,), jnp.float32, minval=1.0, maxval=16.0)
        ),
        D=jnp.ones((h,), jnp.float32),
        dt_bias=dt_bias,
        norm=jnp.zeros((di,), dtype),
        out_proj=dense_init(ks[4], (di, d), dtype),
    )


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., T). Returns (..., T, T) with [i,j] = sum_{k=j+1..i} x_k for
    j<=i, -inf above the diagonal."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(T)
    tril = ii[:, None] >= ii[None, :]
    return jnp.where(tril, seg, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) — already softplus'd, zero on padded slots
    A: jax.Array,  # (H,) negative
    B: jax.Array,  # (B, L, G, N)
    C: jax.Array,  # (B, L, G, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N) f32
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    nc = -(-l // chunk)
    pad = nc * chunk - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))

    f32 = jnp.float32
    rep = h // g
    xc = x.reshape(b, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(b, nc, chunk, h).astype(f32)
    Bh = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3).astype(f32)
    Ch = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3).astype(f32)

    dA = dtc * A.astype(f32)  # (b, nc, cs, h)
    dA_cs = jnp.cumsum(dA, axis=2)
    xdt = xc * dtc[..., None]

    # 1. intra-chunk (quadratic within chunk)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (b, nc, h, cs, cs)
    scores = jnp.einsum("bcshn,bcthn->bchst", Ch, Bh)
    Y_diag = jnp.einsum("bchst,bcthp->bcshp", scores * L, xdt)

    # 2. per-chunk input states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b, nc, cs, h)
    states = jnp.einsum("bcthn,bcth,bcthp->bchpn", Bh, decay_states, xc * dtc[..., None])

    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (b, nc, h)
    s0 = (
        init_state.astype(f32)
        if init_state is not None
        else jnp.zeros((b, h, p, n), f32)
    )

    def scan_fn(s, inp):
        dec, st = inp  # (b,h), (b,h,p,n)
        s_out = s  # state at chunk start
        s_next = s * dec[..., None, None] + st
        return s_next, s_out

    cd = chunk_decay.transpose(1, 0, 2)  # (nc, b, h)
    sts = states.transpose(1, 0, 2, 3, 4)  # (nc, b, h, p, n)
    final_state, s_in = jax.lax.scan(scan_fn, s0, (cd, sts))
    s_in = s_in.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)

    # 4. state -> output within chunk
    state_decay_out = jnp.exp(dA_cs)  # (b, nc, cs, h)
    Y_off = jnp.einsum("bcshn,bchpn,bcsh->bcshp", Ch, s_in, state_decay_out)

    y = (Y_diag + Y_off).reshape(b, nc * chunk, h, p)
    if pad:
        y = y[:, :l]
    return y.astype(x.dtype), final_state


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, L, C); w: (W, C); b: (C,)."""
    W = w.shape[0]
    xt = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    L = x.shape[1]
    for i in range(W):  # W is tiny (4); unrolled adds, no conv primitive games
        out = out + xt[:, i : i + L, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def _split_proj(zxbcdt: jax.Array, cfg: ModelConfig):
    di = cfg.ssm_d_inner
    gn = cfg.ssm_ngroups * cfg.ssm_state_dim
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : di + di + 2 * gn]
    dt_raw = zxbcdt[..., di + di + 2 * gn :]
    return z, xBC, dt_raw


def ssm_forward(
    params: Dict,
    x_in: jax.Array,  # (B, L, D)
    cfg: ModelConfig,
    valid_len: Optional[jax.Array] = None,  # (B,) — mask dt beyond this
    init_cache: Optional[Dict] = None,  # dict(conv=(B,W-1,Cch), state=(B,H,P,N))
) -> Tuple[jax.Array, Dict]:
    """Full-sequence / chunked-prefill SSD pass. Returns (y (B,L,D), cache)."""
    b, l, d = x_in.shape
    h, p = cfg.ssm_num_heads, cfg.ssm_head_dim
    g, n, W = cfg.ssm_ngroups, cfg.ssm_state_dim, cfg.ssm_conv_width

    zxbcdt = jnp.einsum("bld,de->ble", x_in, params["in_proj"])
    z, xBC, dt_raw = _split_proj(zxbcdt, cfg)

    if init_cache is not None:
        xBC_ext = jnp.concatenate([init_cache["conv"].astype(xBC.dtype), xBC], axis=1)
        conv_out = causal_conv(xBC_ext, params["conv_w"], params["conv_b"])[:, W - 1 :]
        new_conv = jax.lax.dynamic_slice_in_dim(xBC_ext, xBC_ext.shape[1] - (W - 1), W - 1, axis=1)
    else:
        conv_out = causal_conv(xBC, params["conv_w"], params["conv_b"])
        new_conv = xBC[:, -(W - 1) :, :] if l >= W - 1 else jnp.pad(xBC, ((0, 0), (W - 1 - l, 0), (0, 0)))

    xs = conv_out[..., : cfg.ssm_d_inner].reshape(b, l, h, p)
    Bmat = conv_out[..., cfg.ssm_d_inner : cfg.ssm_d_inner + g * n].reshape(b, l, g, n)
    Cmat = conv_out[..., cfg.ssm_d_inner + g * n :].reshape(b, l, g, n)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,L,H)
    if valid_len is not None:
        pos = jnp.arange(l, dtype=jnp.int32)[None, :, None]
        dt = jnp.where(pos < valid_len[:, None, None], dt, 0.0)
    A = -jnp.exp(params["A_log"])

    init_state = init_cache["state"] if init_cache is not None else None
    y, final_state = ssd_chunked(xs, dt, A, Bmat, Cmat, cfg.ssm_chunk, init_state)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, l, cfg.ssm_d_inner).astype(x_in.dtype)
    y = gated_rms_norm(y, z, params["norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    return out, dict(conv=new_conv.astype(x_in.dtype), state=final_state)


def ssm_decode_step(
    params: Dict,
    x_in: jax.Array,  # (B, 1, D)
    cfg: ModelConfig,
    cache: Dict,  # conv (B, W-1, Cch), state (B, H, P, N)
) -> Tuple[jax.Array, Dict]:
    b = x_in.shape[0]
    h, p = cfg.ssm_num_heads, cfg.ssm_head_dim
    g, n, W = cfg.ssm_ngroups, cfg.ssm_state_dim, cfg.ssm_conv_width
    di = cfg.ssm_d_inner

    zxbcdt = jnp.einsum("bld,de->ble", x_in, params["in_proj"])[:, 0]
    z, xBC, dt_raw = _split_proj(zxbcdt, cfg)

    window = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC[:, None, :]], axis=1)  # (B, W, C)
    conv_out = jnp.einsum(
        "bwc,wc->bc", window.astype(jnp.float32), params["conv_w"].astype(jnp.float32)
    )
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32)).astype(x_in.dtype)
    new_conv = window[:, 1:, :]

    xs = conv_out[..., :di].reshape(b, h, p)
    Bv = conv_out[..., di : di + g * n].reshape(b, g, n)
    Cv = conv_out[..., di + g * n :].reshape(b, g, n)
    rep = h // g
    Bh = jnp.repeat(Bv, rep, axis=1)  # (B, H, N)
    Ch = jnp.repeat(Cv, rep, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B, H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)  # (B, H)

    f32 = jnp.float32
    state = cache["state"].astype(f32)
    inc = jnp.einsum("bhp,bhn->bhpn", xs.astype(f32) * dt[..., None], Bh.astype(f32))
    state = state * dA[..., None, None] + inc
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(f32), state)
    y = y + params["D"][None, :, None] * xs.astype(f32)
    y = y.reshape(b, di).astype(x_in.dtype)
    y = gated_rms_norm(y, z, params["norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"])[:, None, :]
    return out, dict(conv=new_conv.astype(x_in.dtype), state=state)


def ssm_cache_shape(cfg: ModelConfig, batch: int):
    return dict(
        conv=((batch, cfg.ssm_conv_width - 1, conv_channels(cfg)), "bfloat16"),
        state=((batch, cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state_dim), "float32"),
    )
