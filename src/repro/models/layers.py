"""Shared neural-net layers (pure JAX, functional params-as-pytrees).

Conventions
-----------
- Weights live in bf16 (cfg.dtype); norms/softmax run in fp32.
- Attention tensors are (batch, seq, heads, head_dim).
- Every layer is shape-polymorphic over batch/seq so the same code serves
  train (full seq), chunked prefill (chunk + cache) and decode (seq=1).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# Above this many query*key positions per head we switch to the blockwise
# (flash-style, lax.scan) attention path to avoid materializing S_q x S_kv.
_NAIVE_ATTN_LIMIT = 8192 * 8192
_KV_BLOCK = 1024
_Q_BLOCK = 512

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(orig)


def gated_rms_norm(x: jax.Array, gate: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Mamba2-style RMSNorm(x * silu(gate))."""
    orig = x.dtype
    x = x.astype(jnp.float32) * jax.nn.silu(gate.astype(jnp.float32))
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(orig)


# ----------------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = jnp.exp(-math.log(theta) * (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Attention
# ----------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return cap * jnp.tanh(x / cap)
    return x


def _expand_kv(k: jax.Array, q_heads: int) -> jax.Array:
    """(B,S,Hkv,D) -> (B,S,Hq,D) by repeating each kv head q_per_kv times."""
    b, s, hkv, d = k.shape
    rep = q_heads // hkv
    if rep == 1:
        return k
    k = jnp.repeat(k, rep, axis=2)
    return k


def _window_active(window) -> bool:
    """True if a sliding window should be applied. `window` may be a python
    int (0/None => global) or a traced int32 scalar (always applied; callers
    pass a huge value for global layers, e.g. gemma2's alternating pattern
    inside a scan)."""
    if window is None:
        return False
    if isinstance(window, int):
        return window > 0
    return True  # traced value


def attention_mask(
    q_pos: jax.Array,  # (B, Sq) int32
    kv_len: int,
    kv_valid: Optional[jax.Array] = None,  # (B,) valid kv length
    window=0,
    causal: bool = True,
    q_seg: Optional[jax.Array] = None,  # (B, Sq) packed-segment ids
    kv_seg: Optional[jax.Array] = None,  # (B, Skv)
) -> jax.Array:
    """Boolean mask (B, Sq, Skv); True = attend."""
    kv_pos = jnp.arange(kv_len, dtype=jnp.int32)[None, None, :]
    qp = q_pos[:, :, None]
    mask = jnp.ones((q_pos.shape[0], q_pos.shape[1], kv_len), dtype=bool)
    if causal:
        mask &= kv_pos <= qp
    if _window_active(window):
        mask &= kv_pos > qp - window
    if kv_valid is not None:
        mask &= kv_pos < kv_valid[:, None, None]
    if q_seg is not None and kv_seg is not None:
        mask &= q_seg[:, :, None] == kv_seg[:, None, :]
    return mask


def naive_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,
    mask: jax.Array,  # (B, Sq, Skv) bool
    logit_cap: float = 0.0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Grouped-head GQA attention: never materializes repeated K/V (a
    (B, Skv, Hq, D) repeat is GBs at decode shapes)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, hkv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32)
    scores = softcap(scores * scale, logit_cap)
    scores = jnp.where(mask[:, None, None, :, :], scores, _NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, d)


def blockwise_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,
    q_pos: jax.Array,  # (B, Sq)
    kv_valid: Optional[jax.Array],
    window,
    causal: bool,
    logit_cap: float,
    scale: Optional[float] = None,
    kv_block: int = _KV_BLOCK,
) -> jax.Array:
    """Flash-style exact attention: lax.scan over KV blocks, online softmax.

    Never materializes (Sq, Skv); memory per step is (B, H, Sq, kv_block).
    Wrapped in jax.checkpoint by callers for training so the backward pass
    recomputes block scores instead of saving them.
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    nblk = -(-skv // kv_block)
    pad = nblk * kv_block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = _expand_kv(k, hq).reshape(b, nblk, kv_block, hq, d).transpose(1, 0, 2, 3, 4)
    vb = _expand_kv(v, hq).reshape(b, nblk, kv_block, hq, d).transpose(1, 0, 2, 3, 4)

    kv_valid_eff = kv_valid if kv_valid is not None else jnp.full((b,), skv, jnp.int32)

    def step(carry, inputs):
        acc, m, l = carry  # (B,H,Sq,D) f32, (B,H,Sq), (B,H,Sq)
        blk_idx, kblk, vblk = inputs
        kv_pos = blk_idx * kv_block + jnp.arange(kv_block, dtype=jnp.int32)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kblk, preferred_element_type=jnp.float32)
        scores = softcap(scores * scale, logit_cap)
        msk = kv_pos[None, None, :] < kv_valid_eff[:, None, None]  # (B,1,kblk)
        if causal:
            msk &= kv_pos[None, None, :] <= q_pos[:, :, None]
        if _window_active(window):
            msk &= kv_pos[None, None, :] > q_pos[:, :, None] - window
        scores = jnp.where(msk[:, None, :, :].transpose(0, 1, 2, 3), scores, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vblk.dtype), vblk, preferred_element_type=jnp.float32
        )
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    m0 = jnp.full((b, hq, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (jnp.arange(nblk, dtype=jnp.int32), kb, vb)
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Sq,H,D)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_valid: Optional[jax.Array] = None,
    *,
    window=0,
    causal: bool = True,
    logit_cap: float = 0.0,
    scale: Optional[float] = None,
    q_seg: Optional[jax.Array] = None,
    kv_seg: Optional[jax.Array] = None,
    impl: str = "auto",
) -> jax.Array:
    """Dispatch between naive / blockwise / Pallas-kernel attention.

    impl="pallas" routes to the flash kernels (TPU target; interpret mode on
    CPU). Only the kernel-supported case qualifies — causal, no window, no
    packed segments, static-int window — otherwise falls through to the jnp
    paths. Packed-segment masks force the naive path (segments only occur in
    the CPU engine where sequences are short).
    """
    sq, skv = q.shape[1], k.shape[1]
    if (
        impl == "pallas"
        and q_seg is None
        and causal
        and not _window_active(window)
    ):
        from repro.kernels.prefill_attention.ops import prefill_attention

        kv_valid_eff = (
            kv_valid if kv_valid is not None
            else jnp.full((q.shape[0],), skv, jnp.int32)
        )
        return prefill_attention(
            q, k, v, q_pos, kv_valid_eff, scale=scale, logit_cap=logit_cap
        )
    use_blockwise = impl == "blockwise" or (
        impl in ("auto", "pallas") and q_seg is None and sq * skv > _NAIVE_ATTN_LIMIT
    )
    if use_blockwise:
        return blockwise_attention(
            q, k, v, q_pos, kv_valid, window, causal, logit_cap, scale
        )
    mask = attention_mask(q_pos, skv, kv_valid, window, causal, q_seg, kv_seg)
    return naive_attention(q, k, v, mask, logit_cap, scale)


# ----------------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[name]


def gated_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array, act: str) -> jax.Array:
    g = act_fn(act)(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


# ----------------------------------------------------------------------------
# Initializers
# ----------------------------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = 0) -> jax.Array:
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
