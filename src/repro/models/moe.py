"""Token-choice top-k MoE with capacity + grouped one-hot dispatch.

GShard/MaxText-style dense dispatch: tokens are split into groups; within a
group each token picks top-k experts; per-expert positions are assigned by
cumulative sum with k=0 choices taking priority; tokens past an expert's
capacity are dropped (their combine weight is zero, the residual stream
carries them through). Dispatch/combine are einsums so the HLO is static and
shards cleanly (group dim -> data axis, expert ff dim -> model axis).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import act_fn, dense_init


def init_moe_params(key, cfg: ModelConfig, dtype) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return dict(
        router=dense_init(ks[0], (d, e), jnp.float32),
        w_gate=dense_init(ks[1], (e, d, f), dtype),
        w_up=dense_init(ks[2], (e, d, f), dtype),
        w_down=dense_init(ks[3], (e, f, d), dtype, in_axis=1),
    )


def moe_ffn(x: jax.Array, params: Dict, cfg: ModelConfig) -> jax.Array:
    """x: (..., d_model) -> (..., d_model). Flattens leading dims into groups."""
    orig_shape = x.shape
    d = orig_shape[-1]
    tokens = 1
    for s in orig_shape[:-1]:
        tokens *= s
    x2 = x.reshape(tokens, d)

    gs = min(cfg.moe_group_size, tokens)
    ngroups = -(-tokens // gs)
    pad = ngroups * gs - tokens
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    xg = x2.reshape(ngroups, gs, d)

    e, k = cfg.num_experts, cfg.experts_per_token
    cap = cfg.moe_capacity(gs)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), params["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)  # (g, s, k)
    topv = topv / jnp.maximum(jnp.sum(topv, axis=-1, keepdims=True), 1e-9)

    # one-hot expert choice per k: (g, s, k, e)
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)
    # position within expert: cumulate over (k, s) with k-major priority
    # flatten choices in (k, s) order so k=0 choices get earlier slots
    oh_ks = onehot.transpose(0, 2, 1, 3).reshape(ngroups, k * gs, e)
    pos_ks = jnp.cumsum(oh_ks, axis=1) - oh_ks  # position of each choice
    pos = pos_ks.reshape(ngroups, k, gs, e).transpose(0, 2, 1, 3)  # (g,s,k,e)
    within_cap = (pos < cap) & (onehot > 0)

    pos_idx = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)  # (g, s, k)
    cap_oh = jax.nn.one_hot(pos_idx, cap, dtype=jnp.float32)  # (g, s, k, c)
    keep = jnp.any(within_cap, axis=-1)  # (g, s, k)

    # dispatch tensor (g, s, e, c)
    dispatch = jnp.einsum(
        "gske,gskc->gsec", onehot * within_cap.astype(jnp.float32), cap_oh
    )
    combine = jnp.einsum(
        "gske,gskc->gsec",
        onehot * (topv * keep.astype(topv.dtype))[..., None],
        cap_oh,
    )

    xdtype = x.dtype
    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch.astype(xdtype), xg)
    g_act = act_fn(cfg.act)(
        jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"])
    )
    u = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
    expert_out = jnp.einsum("gecf,efd->gecd", g_act * u, params["w_down"])
    yg = jnp.einsum("gsec,gecd->gsd", combine.astype(xdtype), expert_out)

    y = yg.reshape(ngroups * gs, d)
    if pad:
        y = y[:tokens]
    return y.reshape(orig_shape)


def moe_ffn_ref(x: jax.Array, params: Dict, cfg: ModelConfig) -> jax.Array:
    """Oracle: loop over experts densely (no capacity drop). For tests with
    capacity_factor large enough that nothing is dropped, moe_ffn == this."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    logits = x2.astype(jnp.float32) @ params["router"]
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, cfg.experts_per_token)
    topv = topv / jnp.maximum(jnp.sum(topv, axis=-1, keepdims=True), 1e-9)
    y = jnp.zeros_like(x2)
    for e in range(cfg.num_experts):
        g = act_fn(cfg.act)(x2 @ params["w_gate"][e])
        u = x2 @ params["w_up"][e]
        out_e = (g * u) @ params["w_down"][e]
        w_e = jnp.sum(jnp.where(topi == e, topv, 0.0), axis=-1)
        y = y + out_e * w_e[:, None].astype(x2.dtype)
    return y.reshape(shape)
