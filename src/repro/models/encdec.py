"""Encoder-decoder trunk (seamless-m4t-medium backbone).

The modality frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, S_src, d_model). The decoder is a standard
causal transformer with cross-attention; decode shapes use a fixed encoder
memory length (`DECODE_ENC_LEN`).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.act_sharding import constrain_batch
from repro.models.layers import (
    attention,
    dense_init,
    dtype_of,
    gated_mlp,
    rms_norm,
)
from repro.models.transformer import (
    _init_attn,
    _init_mlp,
    _stack_layers,
    _attn_qkv,
    logits_from_hidden,
)

DECODE_ENC_LEN = 4096  # encoder memory length used by decode shape cells


def _init_enc_layer(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return dict(
        attn=_init_attn(k1, cfg, dtype),
        mlp=_init_mlp(k2, cfg, dtype),
        pre_attn_norm=jnp.zeros((cfg.d_model,), dtype),
        pre_mlp_norm=jnp.zeros((cfg.d_model,), dtype),
    )


def _init_dec_layer(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return dict(
        self_attn=_init_attn(k1, cfg, dtype),
        cross_attn=_init_attn(k2, cfg, dtype),
        mlp=_init_mlp(k3, cfg, dtype),
        pre_self_norm=jnp.zeros((cfg.d_model,), dtype),
        pre_cross_norm=jnp.zeros((cfg.d_model,), dtype),
        pre_mlp_norm=jnp.zeros((cfg.d_model,), dtype),
    )


def init_params(cfg: ModelConfig, key) -> Dict:
    dtype = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 4)
    params = dict(
        embed=(jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        enc_layers=_stack_layers(ks[1], cfg.enc_layers, partial(_init_enc_layer, cfg=cfg, dtype=dtype)),
        dec_layers=_stack_layers(ks[2], cfg.num_layers, partial(_init_dec_layer, cfg=cfg, dtype=dtype)),
        enc_final_norm=jnp.zeros((cfg.d_model,), dtype),
        final_norm=jnp.zeros((cfg.d_model,), dtype),
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[3], (cfg.d_model, cfg.vocab_size), dtype)
    return params


def encode(
    params: Dict, src: jax.Array, cfg: ModelConfig, src_valid: Optional[jax.Array] = None
) -> jax.Array:
    """src: (B, S, D) frame embeddings (stub frontend). Bidirectional."""
    x = constrain_batch(src.astype(dtype_of(cfg.dtype)))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(h, layer):
        hn = rms_norm(h, layer["pre_attn_norm"], cfg.norm_eps)
        q, k, v = _attn_qkv(layer["attn"], hn, positions, cfg)
        a = attention(q, k, v, positions, src_valid, causal=False)
        a = a.reshape(b, s, -1)
        h = h + jnp.einsum("bse,ed->bsd", a, layer["attn"]["wo"])
        h = h + gated_mlp(
            rms_norm(h, layer["pre_mlp_norm"], cfg.norm_eps),
            layer["mlp"]["w_gate"], layer["mlp"]["w_up"], layer["mlp"]["w_down"], cfg.act,
        )
        return h, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"])
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def _cross_kv(layer: Dict, enc_out: jax.Array, cfg: ModelConfig):
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = jnp.einsum("bsd,de->bse", enc_out, layer["cross_attn"]["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", enc_out, layer["cross_attn"]["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    return k, v


def _dec_layer_apply(
    layer: Dict,
    h: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    self_kv,  # None (in-chunk) or (ck, cv) cache
    cross_k, cross_v,  # (B, S_enc, Hkv, Dh)
    enc_valid: Optional[jax.Array],
    tgt_valid: Optional[jax.Array],
):
    b, s, _ = h.shape
    hd = cfg.resolved_head_dim
    # self attention
    hn = rms_norm(h, layer["pre_self_norm"], cfg.norm_eps)
    q, k, v = _attn_qkv(layer["self_attn"], hn, positions, cfg)
    if self_kv is None:
        a = attention(q, k, v, positions, tgt_valid, causal=True)
        new_self = (k, v)
    else:
        ck, cv = self_kv
        if s == 1:
            # select-update (see transformer.attn_block): a per-batch scatter
            # into a sharded cache degenerates to a full-cache all-gather
            m = ck.shape[1]
            hit = (
                jax.lax.broadcasted_iota(jnp.int32, (b, m), 1)
                == positions[:, :1]
            )[:, :, None, None]
            ck = jnp.where(hit, k[:, 0][:, None], ck)
            cv = jnp.where(hit, v[:, 0][:, None], cv)
        else:
            start = positions[:, 0]
            ck = jax.vmap(lambda c, kk, st: jax.lax.dynamic_update_slice(c, kk, (st, 0, 0)))(ck, k, start)
            cv = jax.vmap(lambda c, vv, st: jax.lax.dynamic_update_slice(c, vv, (st, 0, 0)))(cv, v, start)
        a = attention(q, ck, cv, positions, tgt_valid, causal=True)
        new_self = (ck, cv)
    h = h + jnp.einsum("bse,ed->bsd", a.reshape(b, s, -1), layer["self_attn"]["wo"])

    # cross attention (non-causal over encoder memory)
    hn = rms_norm(h, layer["pre_cross_norm"], cfg.norm_eps)
    qc = jnp.einsum("bsd,de->bse", hn, layer["cross_attn"]["wq"]).reshape(b, s, cfg.num_heads, hd)
    a = attention(qc, cross_k, cross_v, positions, enc_valid, causal=False)
    h = h + jnp.einsum("bse,ed->bsd", a.reshape(b, s, -1), layer["cross_attn"]["wo"])

    h = h + gated_mlp(
        rms_norm(h, layer["pre_mlp_norm"], cfg.norm_eps),
        layer["mlp"]["w_gate"], layer["mlp"]["w_up"], layer["mlp"]["w_down"], cfg.act,
    )
    return h, new_self


def forward_train(params: Dict, src: jax.Array, tgt: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Teacher-forced: returns decoder logits (B, S_tgt, V)."""
    enc_out = encode(params, src, cfg)
    x = constrain_batch(params["embed"][tgt])
    b, s = tgt.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(h, layer):
        ck, cv = _cross_kv(layer, enc_out, cfg)
        h, _ = _dec_layer_apply(layer, h, positions, cfg, None, ck, cv, None, None)
        return h, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"])
    return logits_from_hidden(params, x, cfg)


def prefill_step(
    params: Dict,
    src: jax.Array,
    tgt: jax.Array,
    cfg: ModelConfig,
    src_valid: Optional[jax.Array] = None,
    tgt_valid: Optional[jax.Array] = None,
):
    """Encode + teacher-forced prefix. Returns (last logits, cache pytree).

    Cache = dict(self_k, self_v (L,B,S_tgt,H,D), cross_k, cross_v (L,B,S_enc,H,D)).
    """
    enc_out = encode(params, src, cfg, src_valid)
    x = constrain_batch(params["embed"][tgt])
    b, s = tgt.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if tgt_valid is None:
        tgt_valid = jnp.full((b,), s, jnp.int32)

    def body(h, layer):
        ck, cv = _cross_kv(layer, enc_out, cfg)
        h, (sk, sv) = _dec_layer_apply(layer, h, positions, cfg, None, ck, cv, src_valid, tgt_valid)
        return h, (sk, sv, ck, cv)

    x, (sk, sv, ck, cv) = jax.lax.scan(body, x, params["dec_layers"])
    last = jnp.take_along_axis(x, (tgt_valid - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return logits_from_hidden(params, last, cfg), dict(self_k=sk, self_v=sv, cross_k=ck, cross_v=cv)


def decode_step(
    params: Dict,
    tokens: jax.Array,  # (B, 1)
    positions: jax.Array,  # (B,)
    cfg: ModelConfig,
    cache: Dict,
    enc_valid: Optional[jax.Array] = None,
):
    x = constrain_batch(params["embed"][tokens])
    b = tokens.shape[0]
    pos2 = positions[:, None]
    kv_valid = positions + 1

    def body(h, xs):
        layer, sk, sv, ck, cv = xs
        h, (sk2, sv2) = _dec_layer_apply(
            layer, h, pos2, cfg, (sk, sv), ck, cv, enc_valid, kv_valid
        )
        return h, (sk2, sv2)

    x, (sk, sv) = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache["self_k"], cache["self_v"], cache["cross_k"], cache["cross_v"]),
    )
    logits = logits_from_hidden(params, x[:, 0], cfg)
    return logits, dict(self_k=sk, self_v=sv, cross_k=cache["cross_k"], cross_v=cache["cross_v"])


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = DECODE_ENC_LEN) -> Dict:
    hd = cfg.resolved_head_dim
    kv_self = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
    kv_cross = (cfg.num_layers, batch, enc_len, cfg.num_kv_heads, hd)
    return dict(
        self_k=(kv_self, cfg.dtype),
        self_v=(kv_self, cfg.dtype),
        cross_k=(kv_cross, cfg.dtype),
        cross_v=(kv_cross, cfg.dtype),
    )
