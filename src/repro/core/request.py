"""Request model: lifecycle state shared by the simulator and the engine."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass(frozen=True)
class SLOSpec:
    """Service-level objectives. Paper defaults: TTFT 8 s, TPOT 50 ms."""

    ttft: float = 8.0
    tpot: float = 0.050


class Phase(enum.Enum):
    QUEUED = "queued"  # waiting for prefill
    PREFILL = "prefill"  # chunked prefill in progress
    TRANSFER = "transfer"  # KV moving prefill -> decode instance
    DECODE = "decode"  # active on the decode instance
    DONE = "done"
    FAILED = "failed"  # shed by admission control (an SLO miss)
    CANCELLED = "cancelled"  # client disconnected / withdrew the request


# Terminal phases: the request will never produce another token. CANCELLED is
# deliberately distinct from FAILED — a shed request is the *server's* SLO
# miss, a cancelled one is the *client* walking away (metrics must not
# conflate them; see sim/metrics.attainment).
TERMINAL_PHASES = frozenset({Phase.DONE, Phase.FAILED, Phase.CANCELLED})


@dataclass
class Request:
    rid: int
    arrival: float
    input_len: int
    # sim: the true output length; engine: max new tokens
    output_len: int
    slo: SLOSpec = field(default_factory=SLOSpec)
    # multi-tenant serving: who submitted this and which SLO tier it bought.
    # `slo` holds the resolved numeric targets; `slo_class` is the named tier
    # (metrics group by it, admission quotas group by `tenant`).
    tenant: str = "default"
    slo_class: str = "standard"
    # prefix sharing: requests carrying the same non-empty `prefix_group`
    # begin with one shared prompt template covering `prefix_frac` of
    # input_len (the "shared system prompt" shape prefix-aware routing
    # exploits); the sim ignores both, the engine harness materializes them
    prefix_group: str = ""
    prefix_frac: float = 0.0

    # --- dynamic state ---------------------------------------------------
    phase: Phase = Phase.QUEUED
    prefilled_tokens: int = 0  # chunked-prefill progress
    prefix_cached_tokens: int = 0  # prefix-cache hits reduce remaining work
    # tokens matched in the session's PrefixCache at admission — pure KV
    # budget/metrics accounting, unlike prefix_cached_tokens it never skips
    # compute (token outputs stay invariant to the cache)
    prefix_hit_tokens: int = 0
    prefill_finish: Optional[float] = None
    first_token_time: Optional[float] = None  # == prefill_finish in PD disagg
    decode_start: Optional[float] = None  # admission to the decode instance
    n_generated: int = 0
    n_decoded: int = 0  # tokens produced by the decode instance (excl. prefill's)
    token_times: List[float] = field(default_factory=list)  # generation times
    delivery_times: List[float] = field(default_factory=list)  # after pacing
    done_time: Optional[float] = None
    restarts: int = 0  # fault-tolerance: times this request was re-prefilled

    # ---------------------------------------------------------------- props
    @property
    def seq_len(self) -> int:
        """Current total sequence length (prompt + generated)."""
        return self.input_len + self.n_generated

    @property
    def remaining_prefill_tokens(self) -> int:
        return max(0, self.input_len - self.prefix_cached_tokens - self.prefilled_tokens)

    @property
    def prefill_done(self) -> bool:
        return self.remaining_prefill_tokens == 0

    @property
    def decode_done(self) -> bool:
        return self.n_generated >= self.output_len

    # --------------------------------------------------------------- events
    def reset_for_restart(self) -> None:
        """Node failure: KV lost; request re-enters the prefill queue.

        Generated tokens already delivered are kept (the client has them);
        prefill must redo the prompt + regenerated context.
        """
        self.phase = Phase.QUEUED
        self.prefilled_tokens = 0
        self.prefill_finish = None
        self.decode_start = None
        self.n_decoded = 0
        self.restarts += 1

    # --------------------------------------------------------------- metrics
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    def mean_tpot(self) -> Optional[float]:
        """Mean inter-token latency over generated tokens (paper metric)."""
        if self.first_token_time is None or self.n_generated <= 1:
            return 0.0 if self.first_token_time is not None else None
        times = self.delivery_times if self.delivery_times else self.token_times
        if len(times) < 2:
            return 0.0
        return (times[-1] - times[0]) / (len(times) - 1)

    def decode_tput(self) -> Optional[float]:
        """Per-request decode speed in tokens/sec (paper Fig. 6 metric)."""
        if self.done_time is None or self.first_token_time is None:
            return None
        dur = self.done_time - self.first_token_time
        if dur <= 0:
            return None
        return self.n_generated / dur

    def meets_ttft(self) -> bool:
        t = self.ttft()
        return t is not None and t <= self.slo.ttft

    def meets_tpot(self) -> bool:
        t = self.mean_tpot()
        return t is not None and t <= self.slo.tpot

    def meets_e2e(self) -> bool:
        return self.meets_ttft() and self.meets_tpot()
