"""Step-time lookup table (paper §3.2): LUT[batch_size, seq_len] -> seconds.

Profiled offline ("mean decode step time over 100 profiling runs per
configuration") and updated online with the historical mean of observed step
times per (batch-bucket, seq-bucket) cell. Unseen cells fall back to an
analytic model (roofline-derived on TPU — see sim/costmodel.py) so lookups
are always defined.
"""
from __future__ import annotations

from bisect import bisect_right
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np


def default_bsz_buckets(max_bsz: int = 256) -> List[int]:
    out = [1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256]
    return [b for b in out if b <= max_bsz] or [1]


def default_seq_buckets(max_seq: int = 1 << 20) -> List[int]:
    out = []
    s = 512
    while s <= max_seq:
        out.append(s)
        s *= 2
    return out


@dataclass
class StepTimeLUT:
    """(batch, seq) -> per-step decode time with online running-mean updates."""

    analytic: Callable[[int, int], float]  # (bsz, seq) -> seconds (fallback/seed)
    bsz_buckets: List[int] = field(default_factory=default_bsz_buckets)
    seq_buckets: List[int] = field(default_factory=default_seq_buckets)
    seed_offline: bool = True  # paper: offline profile pre-populates the LUT

    def __post_init__(self) -> None:
        nb, ns = len(self.bsz_buckets), len(self.seq_buckets)
        self.mean = np.zeros((nb, ns))
        self.count = np.zeros((nb, ns), dtype=np.int64)
        if self.seed_offline:
            for i, b in enumerate(self.bsz_buckets):
                for j, s in enumerate(self.seq_buckets):
                    self.mean[i, j] = self.analytic(b, s)
                    self.count[i, j] = 1  # offline profile counts as one obs

    # ------------------------------------------------------------- bucketing
    def _bidx(self, bsz: int) -> int:
        i = bisect_right(self.bsz_buckets, max(1, bsz)) - 1
        return min(max(i, 0), len(self.bsz_buckets) - 1)

    def _sidx(self, seq: int) -> int:
        i = bisect_right(self.seq_buckets, max(1, seq)) - 1
        return min(max(i, 0), len(self.seq_buckets) - 1)

    # --------------------------------------------------------------- queries
    def lookup(self, bsz: int, seq: int) -> float:
        i, j = self._bidx(bsz), self._sidx(seq)
        if self.count[i, j] > 0:
            return float(self.mean[i, j])
        return float(self.analytic(bsz, seq))

    def lookup_batch(self, bsz: int, seqs: Sequence[int]) -> float:
        """Paper semantics: LUT[bsz, max seq in batch]."""
        return self.lookup(bsz, max(seqs) if len(seqs) else 1)

    # --------------------------------------------------------------- updates
    def update(self, bsz: int, seq: int, observed: float) -> None:
        """Running (historical) mean per cell — paper §3.2."""
        i, j = self._bidx(bsz), self._sidx(seq)
        c = self.count[i, j]
        self.mean[i, j] = (self.mean[i, j] * c + observed) / (c + 1)
        self.count[i, j] = c + 1

    # ------------------------------------------------------------ jax export
    def as_arrays(self):
        """(bsz_edges, seq_edges, table) for the jittable scheduler."""
        return (
            np.asarray(self.bsz_buckets, np.int32),
            np.asarray(self.seq_buckets, np.int32),
            self.mean.astype(np.float32),
        )

    # ---------------------------------------------------------- checkpointing
    def state_dict(self) -> dict:
        return dict(
            bsz_buckets=list(self.bsz_buckets),
            seq_buckets=list(self.seq_buckets),
            mean=self.mean.copy(),
            count=self.count.copy(),
        )

    def load_state_dict(self, st: dict) -> None:
        assert list(st["bsz_buckets"]) == self.bsz_buckets
        assert list(st["seq_buckets"]) == self.seq_buckets
        self.mean = np.array(st["mean"])
        self.count = np.array(st["count"])
