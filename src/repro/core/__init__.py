"""Kairos core: the paper's scheduling contribution.

Host control plane (numpy): request model, Alg.1/2/3, LUT, pacer, baselines.
Device data plane (jax): jittable mirrors in jax_sched (property-tested to
match the host implementations exactly).
"""
from repro.core.lut import StepTimeLUT
from repro.core.pacer import DeliveryPacer
from repro.core.predictor import (
    PrefillThroughputEstimator,
    predict_all_finish_times,
    predict_finish_time_fcfs,
)
from repro.core.request import Phase, Request, SLOSpec
from repro.core.slack import (
    DECODE_SCHEDULERS,
    ContinuousBatchingScheduler,
    SlackDecodeScheduler,
)
from repro.core.urgency import (
    PREFILL_SCHEDULERS,
    EDFPrefillScheduler,
    FCFSPrefillScheduler,
    SJFPrefillScheduler,
    UrgencyPrefillScheduler,
)

__all__ = [
    "StepTimeLUT",
    "DeliveryPacer",
    "PrefillThroughputEstimator",
    "predict_all_finish_times",
    "predict_finish_time_fcfs",
    "Phase",
    "Request",
    "SLOSpec",
    "DECODE_SCHEDULERS",
    "ContinuousBatchingScheduler",
    "SlackDecodeScheduler",
    "PREFILL_SCHEDULERS",
    "EDFPrefillScheduler",
    "FCFSPrefillScheduler",
    "SJFPrefillScheduler",
    "UrgencyPrefillScheduler",
]
