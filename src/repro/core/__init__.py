"""Kairos core: the paper's scheduling substrate.

Host control plane (numpy): request model, finish-time predictor, LUT,
pacer. Device data plane (jax): jittable mirrors in jax_sched
(property-tested to match the host implementations exactly).

The scheduling *policies* themselves (Alg. 1/3 + baselines) live in
``repro.policies`` — a registry both the simulator and the engine construct
from, so there is exactly one place a policy name means something.
"""
from repro.core.lut import StepTimeLUT
from repro.core.pacer import DeliveryPacer
from repro.core.predictor import (
    PrefillThroughputEstimator,
    predict_all_finish_times,
    predict_finish_time_fcfs,
)
from repro.core.request import Phase, Request, SLOSpec

__all__ = [
    "StepTimeLUT",
    "DeliveryPacer",
    "PrefillThroughputEstimator",
    "predict_all_finish_times",
    "predict_finish_time_fcfs",
    "Phase",
    "Request",
    "SLOSpec",
]
