"""Prefill finish-time prediction (paper Algorithm 2) + throughput estimator.

The paper re-estimates every request's FCFS finish time at the start of each
prefill step, using a running estimate of prefill throughput (tokens/sec).
Its Algorithm 2 is O(n) per request => O(n^2) per step; we implement the
faithful form *and* an O(n) max-plus scan that returns all finish times at
once (the recurrence t_i = max(t_{i-1}, a_i) + d_i is a max-plus prefix
product) — results are identical (property-tested).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.core.request import Request


@dataclass
class PrefillThroughputEstimator:
    """Running estimate of prefill tokens/sec (paper: UPDATETHROUGHPUT).

    The paper maintains "a running estimate of the average prefill
    throughput"; we use an EWMA so the estimate tracks drift (prefix-cache
    hit-rate changes, power throttling) with bounded memory.
    """

    mu: float  # tokens per second
    alpha: float = 0.2  # EWMA weight for new observations
    _n: int = 0

    def update(self, tokens: int, elapsed: float) -> None:
        if elapsed <= 0 or tokens <= 0:
            return
        obs = tokens / elapsed
        if self._n == 0:
            self.mu = obs
        else:
            self.mu = (1 - self.alpha) * self.mu + self.alpha * obs
        self._n += 1


def predict_finish_time_fcfs(
    queue: Sequence[Request], target: Request, t_now: float, mu: float
) -> float:
    """Paper Algorithm 2, verbatim: simulated FCFS clock up to `target`."""
    cursor = t_now
    for r in sorted(queue, key=lambda r: (r.arrival, r.rid)):
        if r.arrival > target.arrival or (r.arrival == target.arrival and r.rid > target.rid):
            continue
        d = r.remaining_prefill_tokens / max(mu, 1e-9)
        cursor = max(cursor, r.arrival) + d
    return cursor


def predict_all_finish_times(
    queue: Sequence[Request], t_now: float, mu: float
) -> np.ndarray:
    """All FCFS finish times in one O(n log n) pass (max-plus scan).

    Returns finish times aligned with `queue` order (not arrival order).
    Identical to calling predict_finish_time_fcfs per request.
    """
    n = len(queue)
    if n == 0:
        return np.zeros(0)
    arrivals = np.array([r.arrival for r in queue])
    rids = np.array([r.rid for r in queue])
    durs = np.array([r.remaining_prefill_tokens / max(mu, 1e-9) for r in queue])
    order = np.lexsort((rids, arrivals))
    t = t_now
    finish_sorted = np.empty(n)
    for i, idx in enumerate(order):  # simple scan; O(n)
        t = max(t, arrivals[idx]) + durs[idx]
        finish_sorted[i] = t
    out = np.empty(n)
    out[order] = finish_sorted
    return out
