"""Jittable (jax.lax) implementations of the Kairos scheduling algorithms.

Real deployments keep scheduling on the host, but at multi-pod scale the
scheduler itself becomes a hot loop (thousands of active slots, every ~10 ms).
These versions run the *same math* as core/predictor.py and the registered
policies in repro/policies/{prefill,decode}.py as
fixed-shape JAX programs over padded request-state arrays, so they can be
fused into the device step (beyond-paper optimization) or vmapped for
what-if sweeps. Property tests assert exact agreement with the numpy
control-plane implementations.

Conventions: slot arrays of length N; `active` masks real requests; slot
index is the deterministic tie-breaker (mirrors rid ordering on the host).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

_BIG = jnp.float32(3.0e38)


# ----------------------------------------------------------------------------
# Algorithm 2: FCFS finish-time prediction (max-plus scan)
# ----------------------------------------------------------------------------

def fcfs_finish_times(
    arrivals: jax.Array,  # (N,) f32
    remaining: jax.Array,  # (N,) f32 tokens
    active: jax.Array,  # (N,) bool
    t_now: jax.Array,  # scalar
    mu: jax.Array,  # scalar tokens/sec
) -> jax.Array:
    """Finish times under FCFS (t_i = max(t_{i-1}, a_i) + d_i) per slot."""
    durs = jnp.where(active, remaining / jnp.maximum(mu, 1e-9), 0.0)
    key = jnp.where(active, arrivals, _BIG)  # inactive last
    order = jnp.argsort(key, stable=True)
    a_s = arrivals[order]
    d_s = durs[order]

    def step(t, xs):
        a, d = xs
        t2 = jnp.maximum(t, a) + d
        return t2, t2

    _, fin_sorted = jax.lax.scan(step, jnp.asarray(t_now, jnp.float32), (a_s, d_s))
    out = jnp.zeros_like(fin_sorted).at[order].set(fin_sorted)
    return out


# ----------------------------------------------------------------------------
# Algorithm 1: urgency-based prefill selection
# ----------------------------------------------------------------------------

def urgency_scores(
    arrivals: jax.Array,
    input_lens: jax.Array,  # (N,) f32
    remaining: jax.Array,
    active: jax.Array,
    t_now: jax.Array,
    mu: jax.Array,
    slo_ttft: jax.Array,  # (N,) or scalar
) -> jax.Array:
    finish = fcfs_finish_times(arrivals, remaining, active, t_now, mu)
    slack = slo_ttft - (finish - arrivals)
    u = (slack / slo_ttft) / jnp.maximum(input_lens, 1.0)
    return jnp.where(active & (remaining > 0), u, -_BIG)


def urgency_select(
    arrivals: jax.Array,
    input_lens: jax.Array,
    remaining: jax.Array,  # (N,) f32 remaining prefill tokens
    active: jax.Array,
    t_now: jax.Array,
    mu: jax.Array,
    slo_ttft: jax.Array,
    budget: int,
) -> jax.Array:
    """Tokens of each slot to prefill this step (sum <= budget)."""
    u = urgency_scores(arrivals, input_lens, remaining, active, t_now, mu, slo_ttft)
    order = jnp.argsort(-u, stable=True)
    rem_s = jnp.where(active, remaining, 0.0)[order]
    cum = jnp.cumsum(rem_s)
    take_s = jnp.clip(budget - (cum - rem_s), 0.0, rem_s)
    take = jnp.zeros_like(take_s).at[order].set(take_s)
    return take


# ----------------------------------------------------------------------------
# LUT lookup
# ----------------------------------------------------------------------------

def lut_lookup(
    table: jax.Array,  # (NB, NS) f32 seconds
    bsz_edges: jax.Array,  # (NB,) i32 ascending bucket lower-edges
    seq_edges: jax.Array,  # (NS,) i32
    bsz: jax.Array,  # i32 (any shape)
    seq: jax.Array,  # i32 (same shape)
) -> jax.Array:
    bi = jnp.clip(jnp.searchsorted(bsz_edges, bsz, side="right") - 1, 0, bsz_edges.shape[0] - 1)
    si = jnp.clip(jnp.searchsorted(seq_edges, seq, side="right") - 1, 0, seq_edges.shape[0] - 1)
    return table[bi, si]


# ----------------------------------------------------------------------------
# Algorithm 3: slack-guided decode selection
# ----------------------------------------------------------------------------

class SlackSelection(NamedTuple):
    selected: jax.Array  # (N,) bool — decode these this step
    slack: jax.Array  # (N,) f32 per-request slack (Eq. 2)
    s_min: jax.Array  # scalar
    batch_size: jax.Array  # i32 |B|


@partial(jax.jit, static_argnames=())
def slack_select(
    seq_lens: jax.Array,  # (N,) i32 current seq len
    n_gen: jax.Array,  # (N,) i32 tokens generated so far
    first_token_t: jax.Array,  # (N,) f32
    active: jax.Array,  # (N,) bool
    t_now: jax.Array,
    slo_tpot: jax.Array,  # (N,) or scalar
    table: jax.Array,
    bsz_edges: jax.Array,
    seq_edges: jax.Array,
) -> SlackSelection:
    n = seq_lens.shape[0]
    elapsed = t_now - first_token_t
    t1 = lut_lookup(table, bsz_edges, seq_edges, jnp.ones_like(seq_lens), seq_lens)
    slack = slo_tpot * (n_gen + 1).astype(jnp.float32) - elapsed - t1
    slack = jnp.where(active, slack, _BIG)
    s_min = jnp.min(slack)

    key = jnp.where(active, seq_lens, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(key, stable=True)
    seq_s = seq_lens[order]
    act_s = active[order]

    def step(carry, xs):
        count, t_cur = carry
        seq_i, act_i = xs
        t_step = lut_lookup(table, bsz_edges, seq_edges, count + 1, seq_i)
        improves = (count == 0) | ((count + 1).astype(jnp.float32) * t_cur > count.astype(jnp.float32) * t_step)
        cond = act_i & (t_step <= s_min) & improves
        count2 = jnp.where(cond, count + 1, count)
        t_cur2 = jnp.where(cond, t_step, t_cur)
        return (count2, t_cur2), cond

    (bsz, _), sel_s = jax.lax.scan(
        step, (jnp.int32(0), jnp.float32(0.0)), (seq_s, act_s)
    )
    selected = jnp.zeros((n,), bool).at[order].set(sel_s)
    # fallback: nothing packs -> decode all active (Alg. 3 lines 19-21)
    none = bsz == 0
    selected = jnp.where(none, active, selected)
    bsz = jnp.where(none, jnp.sum(active.astype(jnp.int32)), bsz)
    return SlackSelection(selected, jnp.where(active, slack, jnp.nan), s_min, bsz)


# ----------------------------------------------------------------------------
# Running-mean LUT update (device-side mirror of StepTimeLUT.update)
# ----------------------------------------------------------------------------

def lut_update(
    table: jax.Array,
    counts: jax.Array,
    bsz_edges: jax.Array,
    seq_edges: jax.Array,
    bsz: jax.Array,
    seq: jax.Array,
    observed: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    bi = jnp.clip(jnp.searchsorted(bsz_edges, bsz, side="right") - 1, 0, bsz_edges.shape[0] - 1)
    si = jnp.clip(jnp.searchsorted(seq_edges, seq, side="right") - 1, 0, seq_edges.shape[0] - 1)
    c = counts[bi, si]
    new_mean = (table[bi, si] * c + observed) / (c + 1.0)
    return table.at[bi, si].set(new_mean), counts.at[bi, si].set(c + 1.0)
