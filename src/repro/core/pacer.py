"""Token delivery pacer.

Kairos decodes short requests ahead of their TPOT deadline and banks the
excess tokens ("the excess tokens can be buffered and released gradually,
effectively decoupling generation speed from token delivery", paper §2.3).
The pacer converts generation timestamps into client delivery timestamps.

Modes:
  immediate — deliver as generated (metric-neutral; default for evaluation)
  paced     — release at the TPOT cadence: token n is delivered at
              max(gen_time_n, first_token + n * TPOT_pace) with pace <= SLO.
              Smooth UX; still meets TPOT because pace <= SLO.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence


@dataclass
class DeliveryPacer:
    mode: str = "immediate"  # immediate | paced
    pace_fraction: float = 0.9  # paced: release at 90% of the SLO interval

    def delivery_times(
        self, gen_times: Sequence[float], first_token_time: float, tpot_slo: float
    ) -> List[float]:
        if self.mode == "immediate" or not gen_times:
            return list(gen_times)
        pace = tpot_slo * self.pace_fraction
        out: List[float] = []
        prev = first_token_time
        for n, t in enumerate(gen_times):
            if n == 0:
                d = t  # first token defines the TTFT; never delayed
            else:
                d = max(t, prev + 0.0, first_token_time + n * pace)
                d = max(d, prev)  # monotone
            out.append(d)
            prev = d
        return out

    def banked(self, gen_times: Sequence[float], t_now: float, first_token_time: float, tpot_slo: float) -> int:
        """How many generated-but-undelivered tokens are in the bank at t_now."""
        deliv = self.delivery_times(gen_times, first_token_time, tpot_slo)
        gen_done = sum(1 for t in gen_times if t <= t_now)
        delivered = sum(1 for t in deliv if t <= t_now)
        return gen_done - delivered
