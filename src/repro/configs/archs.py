"""The 10 assigned architectures + the paper's own model (proxy).

Exact values from the assignment table; ``source`` records provenance tier.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig

GROK1_314B = ModelConfig(
    name="grok-1-314b",
    family="moe",
    source="hf:xai-org/grok-1; unverified",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    experts_per_token=2,
    tie_embeddings=True,
    act="gelu",
    attn_logit_softcap=30.0,  # grok uses attn logit softcapping
    notes="MoE 8e top-2",
)

PHI35_MOE_42B = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    experts_per_token=2,
    tie_embeddings=False,
    notes="MoE 16e top-2",
)

GEMMA2_9B = ModelConfig(
    name="gemma2-9b",
    family="dense",
    source="arXiv:2408.00118; hf",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    sliding_window=4096,
    alternate_local_global=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    act="gelu",
    notes="local+global alternating, logit softcap",
)

LLAMA3_8B = ModelConfig(
    name="llama3-8b",
    family="dense",
    source="arXiv:2407.21783; unverified",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    tie_embeddings=False,
    notes="GQA 128k vocab",
)

MINICPM_2B = ModelConfig(
    name="minicpm-2b",
    family="dense",
    source="arXiv:2404.06395; hf",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
    notes="WSD schedule (arch=llama-like); MHA",
)

COMMAND_R_35B = ModelConfig(
    name="command-r-35b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    tie_embeddings=True,
    notes="GQA, no-bias",
)

CHAMELEON_34B = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    source="arXiv:2405.09818; unverified",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    use_qk_norm=True,
    input_mode="embeddings",  # early-fusion VQ tokens; frontend stubbed
    tie_embeddings=False,
    notes="early-fusion, VQ image tokens; modality frontend is a stub "
    "(input_specs provides precomputed patch embeddings)",
)

MAMBA2_130M = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    source="arXiv:2405.21060; unverified",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state_dim=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
    notes="SSD (state-space duality); attn-free",
)

ZAMBA2_27B = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242; hf",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state_dim=64,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_period=6,  # one shared attention block every 6 mamba2 layers
    tie_embeddings=True,
    notes="Mamba2 + shared attn blocks",
)

SEAMLESS_M4T_MEDIUM = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    source="arXiv:2308.11596; hf",
    num_layers=12,
    enc_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    input_mode="embeddings",  # audio frontend stubbed (precomputed frames)
    tie_embeddings=True,
    act="gelu",
    notes="enc-dec, multimodal; modality frontend is a stub",
)

# The paper's own evaluation model (MiniMax-M2.5, 229B MoE). Public config is
# not released; this proxy matches the published headline stats (229B total,
# ~10B active) and is used for the sim cost model + an extra dry-run config.
PAPER_MINIMAX_M25_PROXY = ModelConfig(
    name="minimax-m2.5-proxy",
    family="moe",
    source="hf:MiniMaxAI/MiniMax-M2.5 (proxy; config unreleased)",
    num_layers=62,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=1536,  # per-expert ff (fine-grained experts)
    vocab_size=200064,
    num_experts=128,
    experts_per_token=4,
    tie_embeddings=False,
    notes="proxy config for the paper's eval model (229B-A10B class)",
)

ALL_ARCHS = {
    c.name: c
    for c in [
        GROK1_314B,
        PHI35_MOE_42B,
        GEMMA2_9B,
        LLAMA3_8B,
        MINICPM_2B,
        COMMAND_R_35B,
        CHAMELEON_34B,
        MAMBA2_130M,
        ZAMBA2_27B,
        SEAMLESS_M4T_MEDIUM,
        PAPER_MINIMAX_M25_PROXY,
    ]
}

ASSIGNED = [n for n in ALL_ARCHS if n != "minimax-m2.5-proxy"]
