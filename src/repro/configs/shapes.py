"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Four shape cells per architecture (40 total):
  train_4k     seq_len=4096   global_batch=256  -> lowers train_step
  prefill_32k  seq_len=32768  global_batch=32   -> lowers prefill_step
  decode_32k   seq_len=32768  global_batch=128  -> lowers serve_step (1 new
               token against a KV cache of seq_len)
  long_500k    seq_len=524288 global_batch=1    -> serve_step; SSM/hybrid only

No arrays are allocated here — everything is jax.ShapeDtypeStruct.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES = {s.name: s for s in [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]}


def shape_applicable(cfg: ModelConfig, spec: ShapeSpec) -> Optional[str]:
    """Return None if (cfg, spec) should run; else a skip reason string."""
    if spec.name == "long_500k" and not cfg.supports_long_context:
        return (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} ({cfg.family}) uses full attention — skipped per "
            "assignment (noted in DESIGN.md)"
        )
    return None


# --------------------------------------------------------------------------
# ShapeDtypeStruct builders. These mirror the pytrees the step functions take.
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def token_or_embed_spec(cfg: ModelConfig, batch: int, seq: int):
    if cfg.input_mode == "embeddings":
        return _sds((batch, seq, cfg.d_model), jnp.bfloat16)
    return _sds((batch, seq), jnp.int32)


def train_input_specs(cfg: ModelConfig, spec: ShapeSpec) -> Dict:
    b, s = spec.global_batch, spec.seq_len
    if cfg.is_encdec:
        half = s // 2
        return dict(
            src=token_or_embed_spec(cfg, b, half),
            tgt=_sds((b, half), jnp.int32),
            labels=_sds((b, half), jnp.int32),
        )
    out = dict(
        inputs=token_or_embed_spec(cfg, b, s),
        labels=_sds((b, s), jnp.int32),
    )
    return out


def prefill_input_specs(cfg: ModelConfig, spec: ShapeSpec) -> Dict:
    b, s = spec.global_batch, spec.seq_len
    if cfg.is_encdec:
        half = s // 2
        return dict(
            src=token_or_embed_spec(cfg, b, half),
            tgt=_sds((b, half), jnp.int32),
        )
    return dict(inputs=token_or_embed_spec(cfg, b, s))


def decode_input_specs(cfg: ModelConfig, spec: ShapeSpec) -> Dict:
    """One-token serve_step against a KV cache (or SSM state) of seq_len."""
    b, s = spec.global_batch, spec.seq_len
    out = dict(
        tokens=_sds((b, 1), jnp.int32),
        positions=_sds((b,), jnp.int32),
        cache=cache_specs(cfg, b, s),
    )
    return out


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """ShapeDtypeStruct pytree matching models.model.init_cache."""
    from repro.models.model import cache_struct  # late import (no jax init)

    return cache_struct(cfg, batch, max_len)


def input_specs(cfg: ModelConfig, spec: ShapeSpec) -> Dict:
    if spec.kind == "train":
        return train_input_specs(cfg, spec)
    if spec.kind == "prefill":
        return prefill_input_specs(cfg, spec)
    return decode_input_specs(cfg, spec)
