"""Model configuration schema for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``. Configs are
frozen dataclasses so they can be hashed into jit cache keys and serialized
into dry-run artifacts.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""  # citation tag from the assignment table

    # transformer trunk
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 2
    capacity_factor: float = 1.25
    moe_group_size: int = 2048  # tokens per dispatch group

    # attention variants
    sliding_window: int = 0  # 0 = all-global
    alternate_local_global: bool = False  # gemma2: even layers local
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    rope_theta: float = 10_000.0
    use_qk_norm: bool = False

    # SSM (mamba2 / SSD)
    ssm_state_dim: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    ssm_ngroups: int = 1

    # hybrid (zamba2-style): one shared attention block every `hybrid_period`
    # ssm layers
    hybrid_period: int = 0

    # encoder-decoder
    enc_layers: int = 0  # >0 => encdec; num_layers is the decoder depth

    # io / misc
    attn_impl: str = "auto"  # auto | naive | blockwise | pallas (flash kernels)
    input_mode: str = "tokens"  # tokens | embeddings (stubbed modality frontend)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"  # silu | gelu
    dtype: str = "bfloat16"

    # notes recorded into DESIGN/EXPERIMENTS artifacts
    notes: str = ""

    # ----------------------------------------------------------------- derived
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic (SSM/hybrid) archs run the long_500k shape."""
        return self.family in ("ssm", "hybrid")

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def moe_capacity(self, tokens_per_group: int) -> int:
        import math

        cap = math.ceil(
            tokens_per_group * self.experts_per_token * self.capacity_factor / max(1, self.num_experts)
        )
        # round up to a multiple of 8 for tiling friendliness
        return max(8, ((cap + 7) // 8) * 8)

    # -------------------------------------------------------------- param math
    def count_params(self) -> int:
        """Analytic parameter count (embedding + trunk + head).

        Used for MODEL_FLOPS = 6*N*D roofline bookkeeping; close to exact for
        the simplified blocks we implement.
        """
        d, hd = self.d_model, self.resolved_head_dim
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd

        def attn_params() -> int:
            return d * n_q + 2 * d * n_kv + n_q * d

        def dense_mlp() -> int:
            return 3 * d * self.d_ff  # SwiGLU: gate, up, down

        def moe_mlp() -> int:
            return self.num_experts * 3 * d * self.d_ff + d * self.num_experts

        def ssm_params() -> int:
            di = self.ssm_d_inner
            n = self.ssm_state_dim
            g = self.ssm_ngroups
            conv_ch = di + 2 * g * n
            in_proj = d * (2 * di + 2 * g * n + self.ssm_num_heads)
            conv = conv_ch * self.ssm_conv_width
            out_proj = di * d
            extra = self.ssm_num_heads * 2 + di  # A, D, norm
            return in_proj + conv + out_proj + extra

        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d

        norms = 2 * d  # per layer (pre-attn + pre-mlp), approximated below

        if self.family in ("dense", "vlm"):
            total += self.num_layers * (attn_params() + dense_mlp() + norms)
        elif self.family == "moe":
            total += self.num_layers * (attn_params() + moe_mlp() + norms)
        elif self.family == "ssm":
            total += self.num_layers * (ssm_params() + norms)
        elif self.family == "hybrid":
            total += self.num_layers * (ssm_params() + norms)
            n_shared = 1  # one shared attention+mlp block (zamba2-style)
            total += n_shared * (attn_params() + dense_mlp() + norms)
        elif self.family == "encdec":
            # encoder self-attn + mlp; decoder self + cross + mlp
            total += self.enc_layers * (attn_params() + dense_mlp() + norms)
            total += self.num_layers * (2 * attn_params() + dense_mlp() + 3 * d)
        total += d  # final norm
        return int(total)

    def count_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.family != "moe":
            return self.count_params()
        d = self.d_model
        inactive = (self.num_experts - self.experts_per_token) * 3 * d * self.d_ff
        return int(self.count_params() - self.num_layers * inactive)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 2),
        d_model=64,
        d_ff=128,
        vocab_size=256,
        moe_group_size=32,
    )
    if cfg.num_heads:
        kw.update(num_heads=4, num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads else 4, head_dim=16)
    if cfg.num_experts:
        kw.update(num_experts=4, experts_per_token=2)
    if cfg.ssm_state_dim:
        kw.update(ssm_state_dim=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.hybrid_period:
        kw.update(hybrid_period=2, num_layers=4)
    if cfg.enc_layers:
        kw.update(enc_layers=2, num_layers=2)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    return cfg.replace(**kw)
