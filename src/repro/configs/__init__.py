"""Config registry: ``get_config(name)`` / ``list_configs()``."""
from __future__ import annotations

from repro.configs.archs import ALL_ARCHS, ASSIGNED
from repro.configs.base import ModelConfig, reduced_config
from repro.configs.shapes import (
    ALL_SHAPES,
    ShapeSpec,
    input_specs,
    shape_applicable,
)


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return reduced_config(get_config(name[: -len("-smoke")]))
    try:
        return ALL_ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALL_ARCHS)}") from None


def list_configs():
    return sorted(ALL_ARCHS)


__all__ = [
    "ModelConfig",
    "ShapeSpec",
    "ALL_ARCHS",
    "ALL_SHAPES",
    "ASSIGNED",
    "get_config",
    "list_configs",
    "reduced_config",
    "input_specs",
    "shape_applicable",
]
