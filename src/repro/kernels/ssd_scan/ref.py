"""Pure-jnp oracle for the SSD scan kernel (wraps models.ssm.ssd_chunked)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def ssd_scan_ref(
    x: jax.Array,  # (B, H, NC, Q, P)
    dt: jax.Array,  # (B, H, NC, Q)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, NC, Q, N)
    Cm: jax.Array,  # (B, NC, Q, N)
):
    b, h, nc, q, p = x.shape
    n = Bm.shape[-1]
    l = nc * q
    x_l = x.transpose(0, 2, 3, 1, 4).reshape(b, l, h, p)
    dt_l = dt.transpose(0, 2, 3, 1).reshape(b, l, h)
    B_l = Bm.reshape(b, l, 1, n)
    C_l = Cm.reshape(b, l, 1, n)
    y, fs = ssd_chunked(x_l, dt_l, A, B_l, C_l, chunk=q)
    y = y.reshape(b, nc, q, h, p).transpose(0, 3, 1, 2, 4)
    return y.astype(x.dtype), fs
