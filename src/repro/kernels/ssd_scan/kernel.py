"""Pallas TPU kernel: Mamba2 SSD chunked scan (state-space duality).

Per (batch, head) the sequence is processed in chunks: the quadratic
intra-chunk part is two MXU matmuls (C B^T masked by the decay matrix L,
then against dt-scaled x), and the inter-chunk recurrence carries the
(head_dim, state) SSM state in a VMEM f32 scratch across the sequential
chunk grid dimension — the TPU-native replacement for the paper-adjacent
CUDA scan: no warp shuffles, just block matmuls + a carried accumulator.

Grid: (batch, heads, n_chunks) with chunks innermost (sequential).
ngroups=1 (B/C shared across heads), matching the mamba2 configs used here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1, 1, 1, Q, P)
    dt_ref,  # (1, 1, 1, Q)
    a_ref,  # (1, 1) f32 — A for this head (negative)
    b_ref,  # (1, 1, Q, N)
    c_ref,  # (1, 1, Q, N)
    y_ref,  # (1, 1, 1, Q, P) out
    fs_ref,  # (1, 1, P, N) out — final state, written at the last chunk
    state_ref,  # (P, N) f32 scratch — carried across chunks
    *,
    q: int,
):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    A = a_ref[0, 0]
    Bm = b_ref[0, 0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (Q, N)

    dA = dt * A  # (Q,)
    cums = jnp.cumsum(dA)  # (Q,)

    # intra-chunk: L[i,j] = exp(cums_i - cums_j) for j <= i
    seg = cums[:, None] - cums[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)  # (Q, Q)

    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    xdt = x * dt[:, None]  # (Q, P)
    y = jax.lax.dot_general(
        scores * L, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)

    # contribution of the carried state: y += exp(cums) * (C @ state^T)
    y = y + jnp.exp(cums)[:, None] * jax.lax.dot_general(
        Cm, state_ref[...], (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, N) x (P, N)^T -> (Q, P)

    # state update: state = state * exp(sum dA) + sum_t decay_t * dt_t x_t B_t^T
    decay_states = jnp.exp(cums[-1] - cums)  # (Q,)
    inc = jax.lax.dot_general(
        xdt * decay_states[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (P, N)
    state_ref[...] = state_ref[...] * jnp.exp(cums[-1]) + inc

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _finish():
        fs_ref[0, 0] = state_ref[...].astype(fs_ref.dtype)


def ssd_scan(
    x: jax.Array,  # (B, H, NC, Q, P)
    dt: jax.Array,  # (B, H, NC, Q) — already softplus'd
    A: jax.Array,  # (H,) negative
    Bm: jax.Array,  # (B, NC, Q, N) — ngroups=1, shared across heads
    Cm: jax.Array,  # (B, NC, Q, N)
    *,
    interpret: bool = True,
):
    """Returns (y (B,H,NC,Q,P), final_state (B,H,P,N))."""
    b, h, nc, q, p = x.shape
    n = Bm.shape[-1]
    grid = (b, h, nc)
    kernel = functools.partial(_ssd_kernel, q=q)
    y, fs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1), lambda ib, ih, ic: (0, ih)),
            pl.BlockSpec((1, 1, q, n), lambda ib, ih, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda ib, ih, ic: (ib, ic, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, q, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32)[None, :], Bm, Cm)
    return y, fs
