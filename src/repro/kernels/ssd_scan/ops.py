"""Jit'd wrapper: (B, L, H, P) sequence layout -> chunked kernel layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan


def ssd(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) — post-softplus
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, L, N) — ngroups=1
    Cm: jax.Array,  # (B, L, N)
    *,
    chunk: int = 128,
    interpret: bool = True,
):
    """Returns (y (B, L, H, P), final_state (B, H, P, N))."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 => identity update
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    lp = l + pad
    nc = lp // q
    xk = x.reshape(b, nc, q, h, p).transpose(0, 3, 1, 2, 4)
    dtk = dt.reshape(b, nc, q, h).transpose(0, 3, 1, 2)
    Bk = Bm.reshape(b, nc, q, n)
    Ck = Cm.reshape(b, nc, q, n)
    y, fs = ssd_scan(xk, dtk, A, Bk, Ck, interpret=interpret)
    y = y.transpose(0, 2, 3, 1, 4).reshape(b, lp, h, p)
    return y[:, :l], fs
