"""Pure-jnp oracle for GQA flash decode."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,  # (B, Hq, Dh) — one token per sequence
    k: jax.Array,  # (B, S, Hkv, Dh)
    v: jax.Array,
    kv_len: jax.Array,  # (B,)
    *,
    scale: float | None = None,
    logit_cap: float = 0.0,
) -> jax.Array:
    b, hq, dh = q.shape
    hkv = k.shape[2]
    rep = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q, k, preferred_element_type=jnp.float32) * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    kvp = jnp.arange(k.shape[1], dtype=jnp.int32)
    mask = kvp[None, None, :] < kv_len[:, None, None]
    s = jnp.where(mask, s, -0.7 * jnp.finfo(jnp.float32).max)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p.astype(v.dtype), v).astype(q.dtype)
