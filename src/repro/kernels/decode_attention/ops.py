"""Jit'd wrapper: (B, Hq, Dh) query layout -> grouped kernel layout + padding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import flash_decode_attention


def decode_attention(
    q: jax.Array,  # (B, Hq, Dh)
    k: jax.Array,  # (B, S, Hkv, Dh)
    v: jax.Array,
    kv_len: jax.Array,  # (B,)
    *,
    scale=None,
    logit_cap: float = 0.0,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    b, hq, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    qpk = hq // hkv
    qg = q.reshape(b, hkv, qpk, dh)
    bk = min(block_k, max(8, s))
    pad = (-s) % bk
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    out = flash_decode_attention(
        qg, k, v, kv_len, scale=scale, logit_cap=logit_cap, block_k=bk, interpret=interpret
    )
    return out.reshape(b, hq, dh)
