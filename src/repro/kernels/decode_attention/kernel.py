"""Pallas TPU kernel: GQA flash decode (single-token attention over a KV cache).

The decode instance's hot loop and the quantity the paper's LUT models: one
query token per sequence reads its whole KV prefix. Memory-bound — the
kernel streams KV blocks HBM->VMEM once, computing the online softmax for
the q_per_kv query-head group of each KV head (an MXU-friendly (qpk, dh) x
(dh, bk) matmul per block).

Grid: (batch, kv_heads, kv_blocks), kv innermost with VMEM carry.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_kernel(
    kvlen_ref,  # (1, 1) i32
    q_ref,  # (1, 1, qpk, dh)
    k_ref,  # (1, bk, 1, dh)
    v_ref,  # (1, bk, 1, dh)
    o_ref,  # (1, 1, qpk, dh)
    acc_ref,  # (qpk, dh) f32
    m_ref,  # (qpk, 1) f32
    l_ref,  # (qpk, 1) f32
    *,
    scale: float,
    bk: int,
    logit_cap: float,
):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]  # (qpk, dh)
    k = k_ref[0, :, 0, :]  # (bk, dh)
    v = v_ref[0, :, 0, :]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (qpk, bk)
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)

    kvp = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)[0]
    mask = kvp[None, :] < kvlen_ref[0, 0]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_attention(
    q: jax.Array,  # (B, Hkv, qpk, Dh) — grouped by KV head
    k: jax.Array,  # (B, S, Hkv, Dh)
    v: jax.Array,
    kv_len: jax.Array,  # (B,)
    *,
    scale: float | None = None,
    logit_cap: float = 0.0,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    b, hkv, qpk, dh = q.shape
    s = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    bk = min(block_k, s)
    assert s % bk == 0, (s, bk)
    grid = (b, hkv, s // bk)

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk, logit_cap=logit_cap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda ib, ih, ik: (ib, 0)),
            pl.BlockSpec((1, 1, qpk, dh), lambda ib, ih, ik: (ib, ih, 0, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda ib, ih, ik: (ib, ik, ih, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda ib, ih, ik: (ib, ik, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qpk, dh), lambda ib, ih, ik: (ib, ih, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, qpk, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qpk, dh), jnp.float32),
            pltpu.VMEM((qpk, 1), jnp.float32),
            pltpu.VMEM((qpk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32)[:, None], q, k, v)
