"""Pallas TPU kernel: chunked-prefill causal flash attention with GQA.

The prefill instance's hot loop: a chunk of queries (at context offset
`q_pos`) attends to the KV cache prefix `[0, kv_len)`. Online softmax over
KV blocks keeps VMEM at O(block) — never materializing (Sq, Skv).

Grid: (batch, q_heads, q_blocks, kv_blocks); kv innermost so the f32
accumulator scratch carries across KV steps. GQA maps query head h to KV
head h // (Hq // Hkv) in the K/V BlockSpec index maps. MXU alignment: block
sizes are multiples of 128 on the contracting/lane dims (head_dim is padded
by ops.py when needed).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(
    qpos_ref,  # (1, bq) i32 — absolute positions of this q block
    kvlen_ref,  # (1, 1) i32 — valid KV prefix length for this batch row
    q_ref,  # (1, bq, 1, dh)
    k_ref,  # (1, bk, 1, dh)
    v_ref,  # (1, bk, 1, dh)
    o_ref,  # (1, bq, 1, dh)
    acc_ref,  # (bq, dh) f32 scratch
    m_ref,  # (bq, 1) f32 scratch
    l_ref,  # (bq, 1) f32 scratch
    *,
    scale: float,
    bk: int,
    logit_cap: float,
):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :]  # (bq, dh)
    k = k_ref[0, :, 0, :]  # (bk, dh)
    v = v_ref[0, :, 0, :]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bk)
    s = s * scale
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)

    qp = qpos_ref[0, :]  # (bq,)
    kvp = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)[0]
    mask = (kvp[None, :] <= qp[:, None]) & (kvp[None, :] < kvlen_ref[0, 0])
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)  # (bq, bk)
    corr = jnp.exp(m_prev - m_new)  # (bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_prefill_attention(
    q: jax.Array,  # (B, Sq, Hq, Dh)
    k: jax.Array,  # (B, Skv, Hkv, Dh)
    v: jax.Array,
    q_pos: jax.Array,  # (B, Sq) i32 absolute positions
    kv_len: jax.Array,  # (B,) i32 valid prefix
    *,
    scale: float | None = None,
    logit_cap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    qpk = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    grid = (b, hq, sq // bq, skv // bk)

    kernel = functools.partial(_flash_kernel, scale=scale, bk=bk, logit_cap=logit_cap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq), lambda ib, ih, iq, ik: (ib, iq)),  # qpos
            pl.BlockSpec((1, 1), lambda ib, ih, iq, ik: (ib, 0)),  # kvlen
            pl.BlockSpec((1, bq, 1, dh), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec(
                (1, bk, 1, dh),
                lambda ib, ih, iq, ik, qpk=qpk: (ib, ik, ih // qpk, 0),
            ),
            pl.BlockSpec(
                (1, bk, 1, dh),
                lambda ib, ih, iq, ik, qpk=qpk: (ib, ik, ih // qpk, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dh), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, hq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos.astype(jnp.int32), kv_len.astype(jnp.int32)[:, None], q, k, v)
