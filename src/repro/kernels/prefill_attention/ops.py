"""Jit'd public wrapper: pads head_dim/seq to hardware-aligned blocks and
dispatches to the Pallas kernel (interpret on CPU, compiled on TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.prefill_attention.kernel import flash_prefill_attention


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def prefill_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_len: jax.Array,
    *,
    scale=None,
    logit_cap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Drop-in attention: (B,Sq,Hq,Dh) x (B,Skv,Hkv,Dh) -> (B,Sq,Hq,Dh)."""
    b, sq, hq, dh = q.shape
    skv = k.shape[1]
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(8, skv))
    # align seq dims to blocks; padded kv is masked via kv_len, padded q rows
    # are sliced off
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    # padded q rows need positions that keep them masked-safe (attend to pos 0)
    pos_pad = _pad_to(q_pos.astype(jnp.int32), 1, bq)
    out = flash_prefill_attention(
        qp, kp, vp, pos_pad, kv_len,
        scale=scale, logit_cap=logit_cap,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    return out[:, :sq]
