"""RPA003 — async safety.

The asyncio stepper (`serving/frontend.py`) and the fleet router
(`serving/router.py`) share one event loop with every client coroutine. A
synchronous blocking call inside any of their ``async def`` bodies —
``time.sleep``, a `Clock.sleep` on a wall clock, a synchronous
`ServeSession.run`, file IO — stalls the whole loop: every stream, every
admission, every replica. Worse, on a ManualClock the same call often
*works* (virtual sleeps return instantly), so the bug only manifests in
production wall-clock runs that tests never exercise.

Flagged inside ``async def`` bodies (nested synchronous ``def``s are skipped;
they define code, they don't run it here):

  * ``time.sleep(...)`` — use ``asyncio.sleep``;
  * ``<...>.clock.sleep(...)`` / ``clock.sleep(...)`` — blocking on a wall
    clock; route through an awaitable idle helper and pragma the
    virtual-clock fast path if it is genuinely non-blocking;
  * ``<...>session.run(...)`` — the synchronous replay loop; drive the
    engine via ``session.step()`` from the stepper instead;
  * builtin ``open(...)`` — file IO on the event loop.
"""
from __future__ import annotations

from typing import Iterator, List

import ast

from repro.analysis.core import Finding, Project, dotted, import_aliases, resolve_call
from repro.analysis.scopes import ASYNC_SCOPE


def _async_body_calls(fn: ast.AsyncFunctionDef) -> Iterator[ast.Call]:
    """Call nodes executed in the coroutine itself: descend the body but not
    into nested synchronous function definitions."""
    stack: List[ast.AST] = list(fn.body)
    while stack:
        node = stack.pop()
        if isinstance(node, ast.FunctionDef):
            continue  # defined here, runs elsewhere
        if isinstance(node, ast.Call):
            yield node
        stack.extend(ast.iter_child_nodes(node))


class AsyncSafetyChecker:
    code = "RPA003"
    description = (
        "no blocking calls (time.sleep, clock.sleep, session.run, open) "
        "inside async def bodies of the asyncio-facing serving modules"
    )

    def run(self, project: Project) -> Iterator[Finding]:
        for sf in project.iter_files(ASYNC_SCOPE.include, ASYNC_SCOPE.exclude):
            if sf.tree is None:
                continue
            aliases = import_aliases(sf.tree)
            for fn in ast.walk(sf.tree):
                if not isinstance(fn, ast.AsyncFunctionDef):
                    continue
                for call in _async_body_calls(fn):
                    msg = self._blocking(call, aliases)
                    if msg:
                        yield Finding(
                            sf.rel,
                            call.lineno,
                            self.code,
                            f"{msg} inside `async def {fn.name}` blocks the "
                            "event loop (every stream and replica stalls)",
                        )

    @staticmethod
    def _blocking(call: ast.Call, aliases) -> str:
        target = resolve_call(call, aliases)
        if target == "time.sleep":
            return "`time.sleep(...)`"
        if target == "open" and isinstance(call.func, ast.Name):
            return "synchronous file IO `open(...)`"
        chain = dotted(call.func)
        if chain is None:
            return ""
        parts = chain.split(".")
        if parts[-1] == "sleep" and "clock" in parts[:-1]:
            return f"blocking `{chain}(...)`"
        if parts[-1] == "run" and any("session" in p for p in parts[:-1]):
            return f"synchronous `{chain}(...)`"
        return ""
