"""RPA004 — registry coverage.

Every policy and scenario ships through a string-keyed registry
(`@register_prefill("kairos-urgency")`, `@register_scenario("bursty")`, …),
which is exactly what makes an *untested* or *undocumented* one invisible:
nothing imports it by symbol, so dead or broken registrants stay green
forever. This checker cross-references every registered name against the
test suite and the docs layer (DESIGN.md plus, when present, the
operator-facing docs/OPERATORS.md — a name appearing in either counts) — a
policy you can ship but nobody exercises, or exercise but nobody documents,
fails the build at its registration site.

Both registration forms count: the decorator form and the direct
factory-call form (``register_decode("x", flag=True)(Cls)``).

Matching is word-ish (name delimited by non-``[A-Za-z0-9_-]``), so
"kairos-slack" inside "kairos-slack-greedy" does **not** count as coverage
of "kairos-slack".
"""
from __future__ import annotations

import re
from typing import Dict, Iterator, List, Tuple

import ast

from repro.analysis.core import Finding, Project, dotted
from repro.analysis.scopes import SRC_SCOPE

REGISTER_FUNCS = (
    "register_prefill",
    "register_decode",
    "register_router",
    "register_deflection",
    "register_autoscaler",
    "register_scenario",
)


def _registrations(project: Project) -> List[Tuple[str, str, str, int]]:
    """(kind, name, file, line) for every registry call under src."""
    out: List[Tuple[str, str, str, int]] = []
    for sf in project.iter_files(SRC_SCOPE.include, SRC_SCOPE.exclude):
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = dotted(node.func)
            if chain is None:
                continue
            kind = chain.split(".")[-1]
            if kind not in REGISTER_FUNCS:
                continue
            if not node.args or not isinstance(node.args[0], ast.Constant):
                continue
            name = node.args[0].value
            if isinstance(name, str):
                out.append((kind, name, sf.rel, node.lineno))
    return out


def _word_pattern(name: str) -> re.Pattern:
    return re.compile(rf"(?<![\w-]){re.escape(name)}(?![\w-])")


class RegistryCoverageChecker:
    code = "RPA004"
    description = (
        "every registered policy/scenario name must be referenced by at "
        "least one tests/ file and documented in DESIGN.md or docs/OPERATORS.md"
    )

    # overridable for fixture tests; files that don't exist are skipped, but
    # at least one doc file must exist for the doc side of the check to pass
    tests_dir = "tests"
    doc_files = ("DESIGN.md", "docs/OPERATORS.md")

    def run(self, project: Project) -> Iterator[Finding]:
        regs = _registrations(project)
        if not regs:
            return
        tests_root = project.root / self.tests_dir
        test_texts: Dict[str, str] = {}
        if tests_root.is_dir():
            for p in sorted(tests_root.rglob("*.py")):
                test_texts[p.name] = p.read_text(encoding="utf-8")
        doc_texts = [
            (project.root / rel).read_text(encoding="utf-8")
            for rel in self.doc_files
            if (project.root / rel).exists()
        ]
        doc_label = " or ".join(self.doc_files)

        for kind, name, rel, line in regs:
            pat = _word_pattern(name)
            if not any(pat.search(t) for t in test_texts.values()):
                yield Finding(
                    rel, line, self.code,
                    f"{kind}('{name}') has no reference in {self.tests_dir}/ — "
                    "a registered-but-untested policy can rot silently; add a "
                    "test that exercises it by name",
                )
            if not any(pat.search(t) for t in doc_texts):
                yield Finding(
                    rel, line, self.code,
                    f"{kind}('{name}') is not documented in {doc_label} — "
                    "add it to the registry table",
                )
