"""The concrete checkers (one module per invariant; codes RPA001–RPA005)."""
from __future__ import annotations

from typing import Tuple

from repro.analysis.checkers.asyncsafe import AsyncSafetyChecker
from repro.analysis.checkers.clock import ClockHygieneChecker
from repro.analysis.checkers.registry import RegistryCoverageChecker
from repro.analysis.checkers.rng import RngDisciplineChecker
from repro.analysis.checkers.schema import MetricsSchemaChecker

ALL_CHECKERS: Tuple[type, ...] = (
    ClockHygieneChecker,
    RngDisciplineChecker,
    AsyncSafetyChecker,
    RegistryCoverageChecker,
    MetricsSchemaChecker,
)

__all__ = [
    "ALL_CHECKERS",
    "AsyncSafetyChecker",
    "ClockHygieneChecker",
    "MetricsSchemaChecker",
    "RegistryCoverageChecker",
    "RngDisciplineChecker",
]
