"""RPA005 — metrics-schema drift.

CI's workloads-smoke and bench-gate jobs parse the JSON that
`ServeSession.summary()`, `RouterSession.summary()`, and the harness cell
builders emit; `benchmarks/check_regression.py` diffs committed records of
it. Those consumers live in other files, other jobs, other commits — so a
renamed or dropped key is a contract break that no unit in the producing
module will catch. This checker extracts the *key fingerprint* of each
producer from its AST and diffs it against the committed
`src/repro/analysis/schema/metrics_schema.json`: the contract can only
change together with an explicit schema update
(``python -m repro.analysis --write-schema``), which makes the change
visible in review.

A key fingerprint is the union, over the producer's body, of: keyword names
of ``dict(...)`` calls, string keys of dict literals, string keys assigned
via subscript (``out["k"] = ...``), and keyword names of ``.update(...)``
calls. It is a drift detector, not a precise schema — nested and top-level
keys are pooled deliberately, so *any* key change anywhere in the producer
trips the diff.
"""
from __future__ import annotations

import json
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

import ast

from repro.analysis.core import Finding, Project

SCHEMA_REL = "src/repro/analysis/schema/metrics_schema.json"

# (entry key, repo-relative file, symbol path, extraction mode)
SPECS: Tuple[Tuple[str, str, Tuple[str, ...], str], ...] = (
    ("serving.SessionMetrics", "src/repro/serving/session.py", ("SessionMetrics",), "fields"),
    ("serving.ServeSession.summary", "src/repro/serving/session.py", ("ServeSession", "summary"), "keys"),
    ("serving.RouterSession.summary", "src/repro/serving/router.py", ("RouterSession", "summary"), "keys"),
    ("serving.RouterSession.prefix_summary", "src/repro/serving/router.py", ("RouterSession", "prefix_summary"), "keys"),
    ("serving.HandoffMetrics", "src/repro/serving/disagg.py", ("HandoffMetrics",), "fields"),
    ("serving.DisaggSession.summary", "src/repro/serving/disagg.py", ("DisaggSession", "summary"), "keys"),
    ("serving.DisaggSession.handoff_summary", "src/repro/serving/disagg.py", ("DisaggSession", "handoff_summary"), "keys"),
    ("sim.Attainment", "src/repro/sim/metrics.py", ("Attainment",), "fields"),
    ("sim.summarize", "src/repro/sim/metrics.py", ("summarize",), "keys"),
    ("workloads.cell_report", "src/repro/workloads/harness.py", ("_cell_report",), "keys"),
    ("workloads.evaluate_cell", "src/repro/workloads/harness.py", ("evaluate_cell",), "keys"),
    ("workloads.router_cell_block", "src/repro/workloads/harness.py", ("router_cell_block",), "keys"),
    ("workloads.disagg_cell_block", "src/repro/workloads/harness.py", ("disagg_cell_block",), "keys"),
    ("workloads.churn_cell_block", "src/repro/workloads/harness.py", ("churn_cell_block",), "keys"),
    ("serving.FleetSession.summary", "src/repro/serving/fleetctl.py", ("FleetSession", "summary"), "keys"),
    ("obs.counters_from_events", "src/repro/obs/events.py", ("counters_from_events",), "keys"),
    ("obs.attainment_from_events", "src/repro/obs/slo.py", ("attainment_from_events",), "keys"),
    ("obs.windowed_slo", "src/repro/obs/slo.py", ("windowed_slo",), "keys"),
    ("obs.trace_cell_block", "src/repro/obs/slo.py", ("trace_cell_block",), "keys"),
)


def _find_symbol(tree: ast.Module, path: Sequence[str]) -> Optional[ast.AST]:
    node: ast.AST = tree
    for name in path:
        body = getattr(node, "body", [])
        node = next(
            (
                n
                for n in body
                if isinstance(n, (ast.ClassDef, ast.FunctionDef, ast.AsyncFunctionDef))
                and n.name == name
            ),
            None,
        )
        if node is None:
            return None
    return node


def _dataclass_fields(cls: ast.ClassDef) -> Set[str]:
    return {
        s.target.id
        for s in cls.body
        if isinstance(s, ast.AnnAssign) and isinstance(s.target, ast.Name)
    }


def _key_fingerprint(fn: ast.AST) -> Set[str]:
    keys: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            func = node.func
            is_dict = isinstance(func, ast.Name) and func.id == "dict"
            is_update = isinstance(func, ast.Attribute) and func.attr == "update"
            if is_dict or is_update:
                keys.update(kw.arg for kw in node.keywords if kw.arg is not None)
        elif isinstance(node, ast.Dict):
            keys.update(
                k.value for k in node.keys
                if isinstance(k, ast.Constant) and isinstance(k.value, str)
            )
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for t in targets:
                if (
                    isinstance(t, ast.Subscript)
                    and isinstance(t.slice, ast.Constant)
                    and isinstance(t.slice.value, str)
                ):
                    keys.add(t.slice.value)
    return keys


def extract_schema(project: Project, specs=SPECS) -> Dict[str, object]:
    """The current tree's schema: entry key -> sorted key list (or an
    ``{"error": ...}`` marker when the producer cannot be located)."""
    entries: Dict[str, object] = {}
    for key, rel, path, mode in specs:
        sf = project.get(rel)
        if sf is None or sf.tree is None:
            entries[key] = {"error": f"{rel} not found or unparseable"}
            continue
        sym = _find_symbol(sf.tree, path)
        if sym is None:
            entries[key] = {"error": f"{'.'.join(path)} not found in {rel}"}
            continue
        got = _dataclass_fields(sym) if mode == "fields" else _key_fingerprint(sym)
        entries[key] = sorted(got)
    return {"version": 1, "entries": entries}


class MetricsSchemaChecker:
    code = "RPA005"
    description = (
        "summary()/cell-builder key sets must match the committed "
        "metrics_schema.json (update via `python -m repro.analysis --write-schema`)"
    )

    # overridable for fixture tests
    schema_rel = SCHEMA_REL
    specs = SPECS

    def run(self, project: Project) -> Iterator[Finding]:
        schema_path = project.root / self.schema_rel
        if not schema_path.exists():
            yield Finding(
                self.schema_rel, 1, self.code,
                "committed metrics schema is missing; generate it with "
                "`python -m repro.analysis --write-schema`",
            )
            return
        committed = json.loads(schema_path.read_text(encoding="utf-8")).get("entries", {})
        current = extract_schema(project, self.specs)["entries"]

        for key, rel, path, _mode in self.specs:
            got = current.get(key)
            sf = project.get(rel)
            sym = _find_symbol(sf.tree, path) if sf is not None and sf.tree is not None else None
            line = getattr(sym, "lineno", 1)
            if isinstance(got, dict):  # locate error
                yield Finding(rel, 1, self.code, f"schema entry '{key}': {got['error']}")
                continue
            want = committed.get(key)
            if want is None:
                yield Finding(
                    rel, line, self.code,
                    f"producer '{key}' has no entry in {self.schema_rel}; "
                    "re-run --write-schema to record it",
                )
                continue
            added = sorted(set(got) - set(want))
            removed = sorted(set(want) - set(got))
            for k in added:
                yield Finding(
                    rel, line, self.code,
                    f"'{key}' now emits key '{k}' not in the committed schema — "
                    "downstream CI consumers parse this JSON; update "
                    f"{self.schema_rel} deliberately (--write-schema)",
                )
            for k in removed:
                yield Finding(
                    rel, line, self.code,
                    f"'{key}' no longer emits key '{k}' that the committed "
                    "schema promises — this breaks the bench-gate/workloads "
                    f"JSON contract; update {self.schema_rel} deliberately",
                )
        for key in sorted(set(committed) - {s[0] for s in self.specs}):
            yield Finding(
                self.schema_rel, 1, self.code,
                f"schema entry '{key}' has no extraction spec; remove it or "
                "add a spec in repro.analysis.checkers.schema",
            )
