"""RPA001 — clock hygiene.

The engine, sim, policies, and workloads compute TTFT/TPOT/slack from an
injectable `Clock` (serving/clock.py). A direct wall-clock read anywhere in
those packages makes scheduling decisions time-dependent and voids the
ManualClock parity contracts (sync session == async frontend == 1-replica
router, bit for bit) without failing a single test — the parity tests all
run on ManualClock and never see the stray read. This checker makes the
injection boundary a machine-checked fact.
"""
from __future__ import annotations

from typing import Iterator

import ast

from repro.analysis.core import Finding, Project, import_aliases, resolve_call
from repro.analysis.scopes import CLOCK_SCOPE

BANNED = {
    "time.time",
    "time.time_ns",
    "time.monotonic",
    "time.monotonic_ns",
    "time.perf_counter",
    "time.perf_counter_ns",
    "time.process_time",
    "time.process_time_ns",
    "datetime.datetime.now",
    "datetime.datetime.utcnow",
    "datetime.datetime.today",
    "datetime.date.today",
}


class ClockHygieneChecker:
    code = "RPA001"
    description = (
        "no wall-clock reads outside serving/clock.py — all timing flows "
        "through the injectable Clock"
    )

    def run(self, project: Project) -> Iterator[Finding]:
        for sf in project.iter_files(CLOCK_SCOPE.include, CLOCK_SCOPE.exclude):
            if sf.tree is None:
                continue
            aliases = import_aliases(sf.tree)
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.Call):
                    continue
                target = resolve_call(node, aliases)
                if target in BANNED:
                    yield Finding(
                        sf.rel,
                        node.lineno,
                        self.code,
                        f"wall-clock read `{target}()` in a deterministic-core "
                        "package; read time through the injectable Clock "
                        "(repro.serving.clock) instead",
                    )
