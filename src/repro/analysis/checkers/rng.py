"""RPA002 — RNG discipline.

Scenario generation, the simulator, and engine sampling are deterministic
functions of (config, seed): every random draw in a decision path must come
from an explicitly-seeded `np.random.Generator` (or a `jax.random` key, which
is seeded by construction). Three patterns break that and are banned here:

  * ``np.random.default_rng()`` with no seed argument — seeds from OS entropy,
    so two runs of the "same" scenario diverge;
  * module-level ``np.random.<fn>(...)`` — draws from numpy's hidden global
    state, which any import can perturb;
  * stdlib ``random.*`` — global state again, plus Python's per-process hash
    salt leaks into common idioms around it.
"""
from __future__ import annotations

from typing import Iterator

import ast

from repro.analysis.core import Finding, Project, import_aliases, resolve_call
from repro.analysis.scopes import RNG_SCOPE

# numpy.random attributes that are constructors/types, not global-state draws
_NUMPY_OK = {
    "default_rng",
    "Generator",
    "SeedSequence",
    "BitGenerator",
    "PCG64",
    "Philox",
}


class RngDisciplineChecker:
    code = "RPA002"
    description = (
        "decision-path randomness must be an explicitly-seeded Generator: "
        "no seedless default_rng(), no np.random global state, no stdlib random"
    )

    def run(self, project: Project) -> Iterator[Finding]:
        for sf in project.iter_files(RNG_SCOPE.include, RNG_SCOPE.exclude):
            if sf.tree is None:
                continue
            aliases = import_aliases(sf.tree)
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.Call):
                    continue
                target = resolve_call(node, aliases)
                if target is None:
                    continue
                if target == "numpy.random.default_rng":
                    if not node.args and not node.keywords:
                        yield Finding(
                            sf.rel,
                            node.lineno,
                            self.code,
                            "`default_rng()` without a seed draws from OS "
                            "entropy; pass an explicit seed expression",
                        )
                elif target.startswith("numpy.random."):
                    attr = target.split(".", 2)[2]
                    if attr not in _NUMPY_OK and "." not in attr:
                        yield Finding(
                            sf.rel,
                            node.lineno,
                            self.code,
                            f"module-level `np.random.{attr}()` uses numpy's "
                            "hidden global RNG state; thread a seeded "
                            "Generator through instead",
                        )
                elif target.startswith("random."):
                    yield Finding(
                        sf.rel,
                        node.lineno,
                        self.code,
                        f"stdlib `{target}()` uses process-global RNG state; "
                        "decision paths must use a seeded np.random.Generator",
                    )
