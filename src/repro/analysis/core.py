"""Checker framework for the repro static-analysis suite.

The determinism contracts this repo sells — bit-identical ManualClock parity
between `ServeSession`, the async frontend, and a 1-replica router; replayable
`SlotAllocator` snapshots; a stable bench-gate JSON schema — all rest on
invariants that no unit test can see being violated *by omission* (a stray
`time.monotonic()` in a policy keeps every parity test green while silently
voiding what they prove). This package makes those invariants machine-checked:
each `Checker` walks the project's `ast` trees and reports `Finding`s; the CLI
(`python -m repro.analysis`) exits non-zero on any unsuppressed finding and CI
gates on it.

Suppression: a finding is suppressed by an inline pragma on the finding line
or the line directly above it::

    t0 = time.perf_counter()  # repro: allow[RPA001] wall-time is the point here

The justification text after the bracket is MANDATORY — a bare pragma does not
suppress and instead raises RPA900, so every exception in the tree documents
itself. Multiple codes: ``allow[RPA001,RPA002]``.

Scoping lives in `repro.analysis.scopes`: each checker declares the package
prefixes it patrols, so e.g. `launch/` CLIs may legitimately read wall time
while `repro.policies` may not.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Protocol, Sequence, Set

# `# repro: allow[RPA001] why this is fine` — justification text required.
PRAGMA_RE = re.compile(
    r"#\s*repro:\s*allow\[(?P<codes>[A-Z]{3}\d{3}(?:\s*,\s*[A-Z]{3}\d{3})*)\]"
    r"[ \t]*[-—:]*[ \t]*(?P<why>.*)$"
)

# Framework-level codes (checkers own RPA001..RPA005).
SYNTAX_ERROR = "RPA000"  # file does not parse; reported, never fatal
BAD_PRAGMA = "RPA900"  # suppression pragma without a justification


@dataclass(frozen=True)
class Finding:
    """One invariant violation, anchored to a repo-relative location."""

    file: str  # posix path relative to the repo root
    line: int
    code: str
    message: str

    def as_dict(self) -> Dict[str, object]:
        return dict(file=self.file, line=self.line, code=self.code, message=self.message)

    def __str__(self) -> str:
        return f"{self.file}:{self.line}: {self.code} {self.message}"


@dataclass
class SourceFile:
    """A parsed project file plus its suppression-pragma map."""

    path: Path  # absolute
    rel: str  # posix, repo-root-relative — the identity findings carry
    text: str
    tree: Optional[ast.Module]  # None when the file does not parse
    error: Optional[SyntaxError] = None
    # line -> codes suppressed on that line (honored for line and line+1)
    pragmas: Dict[int, Set[str]] = field(default_factory=dict)
    # pragma lines whose justification text is empty (RPA900)
    bad_pragma_lines: List[int] = field(default_factory=list)

    @property
    def lines(self) -> List[str]:
        return self.text.splitlines()

    def allows(self, code: str, line: int) -> bool:
        """Is `code` suppressed at `line` (same-line or line-above pragma)?"""
        return any(code in self.pragmas.get(at, ()) for at in (line, line - 1))


def _parse_pragmas(sf: SourceFile) -> None:
    for i, raw in enumerate(sf.text.splitlines(), start=1):
        m = PRAGMA_RE.search(raw)
        if not m:
            continue
        codes = {c.strip() for c in m.group("codes").split(",")}
        if not m.group("why").strip():
            # an unjustified pragma suppresses nothing — and is itself a finding
            sf.bad_pragma_lines.append(i)
            continue
        sf.pragmas.setdefault(i, set()).update(codes)


def load_source_file(path: Path, root: Path) -> SourceFile:
    text = path.read_text(encoding="utf-8")
    rel = path.resolve().relative_to(root.resolve()).as_posix()
    tree: Optional[ast.Module] = None
    error: Optional[SyntaxError] = None
    try:
        tree = ast.parse(text, filename=rel)
    except SyntaxError as e:  # degrade gracefully: one finding, run continues
        error = e
    sf = SourceFile(path=path, rel=rel, text=text, tree=tree, error=error)
    _parse_pragmas(sf)
    return sf


def find_repo_root(start: Path) -> Path:
    """Walk up to the directory holding pyproject.toml (or .git); the repo
    root anchors `rel` paths, scope prefixes, and the tests/DESIGN.md
    cross-references."""
    cur = start.resolve()
    if cur.is_file():
        cur = cur.parent
    for cand in (cur, *cur.parents):
        if (cand / "pyproject.toml").exists() or (cand / ".git").exists():
            return cand
    return cur


@dataclass
class Project:
    """Everything a checker may look at: parsed python files under the scan
    roots, plus the repo root for non-python cross-references (DESIGN.md,
    tests/) that repo-wide checkers consult directly."""

    root: Path
    files: List[SourceFile]

    def iter_files(self, prefixes: Sequence[str] = (), exclude: Sequence[str] = ()) -> Iterator[SourceFile]:
        """Parsed files whose repo-relative path starts with any prefix
        (empty = all), minus exact-or-prefix excludes."""
        for sf in self.files:
            if prefixes and not any(sf.rel.startswith(p) for p in prefixes):
                continue
            if any(sf.rel == e or sf.rel.startswith(e.rstrip("/") + "/") for e in exclude):
                continue
            yield sf

    def get(self, rel: str) -> Optional[SourceFile]:
        for sf in self.files:
            if sf.rel == rel:
                return sf
        return None


def load_project(paths: Sequence[Path], root: Optional[Path] = None) -> Project:
    root = root or find_repo_root(Path(paths[0]) if paths else Path.cwd())
    seen: Set[Path] = set()
    files: List[SourceFile] = []
    for p in paths:
        p = Path(p)
        candidates = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for c in candidates:
            c = c.resolve()
            if c in seen:
                continue
            seen.add(c)
            files.append(load_source_file(c, root))
    return Project(root=root, files=files)


class Checker(Protocol):
    """One invariant. `run` yields raw findings; the runner applies pragmas."""

    code: str
    description: str

    def run(self, project: Project) -> Iterator[Finding]: ...


def framework_findings(project: Project) -> Iterator[Finding]:
    """Findings the framework itself owns: unparseable files (RPA000) and
    justification-less pragmas (RPA900)."""
    for sf in project.files:
        if sf.error is not None:
            line = sf.error.lineno or 1
            yield Finding(
                sf.rel, line, SYNTAX_ERROR,
                f"file does not parse: {sf.error.msg} (checkers skipped this file)",
            )
        for line in sf.bad_pragma_lines:
            yield Finding(
                sf.rel, line, BAD_PRAGMA,
                "suppression pragma has no justification text; "
                "write `# repro: allow[CODE] <why this exception is sound>`",
            )


def run_checkers(
    project: Project,
    checkers: Iterable[Checker],
    select: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """Run the selected checkers plus the framework checks, apply suppression
    pragmas, and return findings sorted by (file, line, code)."""
    selected = None if select is None else set(select)
    raw: List[Finding] = []
    for chk in checkers:
        if selected is not None and chk.code not in selected:
            continue
        raw.extend(chk.run(project))
    if selected is None or {SYNTAX_ERROR, BAD_PRAGMA} & selected:
        raw.extend(
            f for f in framework_findings(project)
            if selected is None or f.code in selected
        )
    kept: List[Finding] = []
    for f in raw:
        sf = project.get(f.file)
        # RPA900 is not self-suppressible: a pragma cannot vouch for itself
        if sf is not None and f.code != BAD_PRAGMA and sf.allows(f.code, f.line):
            continue
        kept.append(f)
    kept.sort(key=lambda f: (f.file, f.line, f.code))
    return kept


# --------------------------------------------------------------------------
# Shared AST utilities


def import_aliases(tree: ast.Module) -> Dict[str, str]:
    """Map local names to their dotted import origin.

    `import numpy as np` -> {"np": "numpy"};
    `from time import monotonic as m` -> {"m": "time.monotonic"}.
    Only module-level and function-level imports are walked — enough to
    resolve the call sites the checkers care about.
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0]
                )
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def dotted(node: ast.AST) -> Optional[str]:
    """Render a Name/Attribute chain as "a.b.c" (None for anything else,
    e.g. a call result or subscript in the chain)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def resolve_call(node: ast.Call, aliases: Dict[str, str]) -> Optional[str]:
    """Fully-qualified dotted name of a call target, import-aliases applied:
    `np.random.default_rng(0)` -> "numpy.random.default_rng"."""
    name = dotted(node.func)
    if name is None:
        return None
    head, _, rest = name.partition(".")
    origin = aliases.get(head)
    if origin is None:
        return name
    return f"{origin}.{rest}" if rest else origin
