"""Per-checker scoping: which packages each invariant patrols.

The whitelist is as load-bearing as the ban. `launch/` CLIs (dryrun, train,
elastic, loadgen, evaluate) legitimately read host wall time — compile-time
reporting and operator progress lines are *about* wall time — so RPA001
deliberately excludes them (audited 2026-08: every `time.time`/`perf_counter`
there feeds a human-facing progress print or a `wall_time_s`-style report
field, never a scheduling decision). Likewise `serving/clock.py` is the one
place wall clocks are *supposed* to live: it is the injection boundary.

`repro.models`, `repro.kernels`, `repro.training` use `jax.random` keys (a
functional, explicitly-seeded API) and are outside RPA002's decision-path
scope; the ban is on *hidden global state* feeding scheduling decisions.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class Scope:
    include: Tuple[str, ...]  # repo-relative path prefixes
    exclude: Tuple[str, ...] = field(default_factory=tuple)


# RPA001 clock hygiene: all timing in the deterministic core must flow
# through the injectable Clock (serving/clock.py), or ManualClock parity
# between sim / session / async frontend / router silently breaks.
CLOCK_SCOPE = Scope(
    include=(
        "src/repro/sim/",
        "src/repro/serving/",
        "src/repro/policies/",
        "src/repro/workloads/",
        "src/repro/core/",
        # observability must obey the same discipline: TraceRecorder never
        # reads a clock — every timestamp is handed in by an emitting
        # session that already read it from its injected Clock
        "src/repro/obs/",
    ),
    exclude=("src/repro/serving/clock.py",),  # the injection boundary itself
)

# RPA002 RNG discipline: decision paths may only draw randomness from an
# explicitly-seeded Generator that the caller threads through.
RNG_SCOPE = Scope(
    include=(
        "src/repro/sim/",
        "src/repro/serving/",
        "src/repro/policies/",
        "src/repro/workloads/",
        "src/repro/core/",
        "src/repro/obs/",
    ),
)

# RPA003 async safety: only the asyncio-facing modules; everything else is
# deliberately synchronous.
ASYNC_SCOPE = Scope(
    include=(
        "src/repro/serving/frontend.py",
        "src/repro/serving/router.py",
        "src/repro/serving/fleetctl.py",
    ),
)

# RPA004 registry coverage / RPA005 metrics schema: repo-wide over src.
SRC_SCOPE = Scope(include=("src/repro/",))
