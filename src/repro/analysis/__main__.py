"""CLI: ``python -m repro.analysis [paths ...]``.

Exit status is the CI contract: 0 = clean tree, 1 = at least one
unsuppressed finding, 2 = usage error. ``--format json`` emits a findings
artifact the `static-analysis` CI job uploads.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import ALL_CHECKERS, analyze, find_repo_root, load_project
from repro.analysis.checkers.schema import SCHEMA_REL, extract_schema


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro static-analysis suite (RPA001-RPA005)",
    )
    ap.add_argument("paths", nargs="*", default=None, help="files/dirs to scan (default: src)")
    ap.add_argument(
        "--select",
        default=None,
        help="comma-separated checker codes to run (e.g. RPA001,RPA004); default all",
    )
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument(
        "--list", action="store_true", help="list checker codes and exit"
    )
    ap.add_argument(
        "--write-schema",
        action="store_true",
        help=f"regenerate {SCHEMA_REL} from the current tree and exit "
        "(the deliberate metrics-contract update step)",
    )
    args = ap.parse_args(argv)

    if args.list:
        for cls in ALL_CHECKERS:
            print(f"{cls.code}  {cls.description}")
        return 0

    paths = args.paths or ["src"]
    for p in paths:
        if not Path(p).exists():
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    if args.write_schema:
        root = find_repo_root(Path(paths[0]))
        project = load_project([Path(p) for p in paths], root=root)
        out = root / SCHEMA_REL
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(extract_schema(project), indent=2) + "\n", encoding="utf-8")
        print(f"wrote {out}")
        return 0

    select = None if args.select is None else [c.strip() for c in args.select.split(",") if c.strip()]
    findings = analyze(paths, select=select)

    if args.format == "json":
        print(json.dumps(dict(count=len(findings), findings=[f.as_dict() for f in findings]), indent=2))
    else:
        for f in findings:
            print(f)
        n = len(findings)
        print(f"repro.analysis: {n} finding{'s' if n != 1 else ''}" if n else "repro.analysis: clean")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
