"""`repro.analysis` — project-specific static invariants, machine-checked.

    python -m repro.analysis src/              # text findings, exit 1 if any
    python -m repro.analysis --select RPA001 --format json src/
    python -m repro.analysis --write-schema    # record a deliberate schema change

Checkers (see DESIGN.md §analysis for the full contract of each):

    RPA001  clock hygiene    no wall-clock reads outside serving/clock.py
    RPA002  rng discipline   only explicitly-seeded Generators in decision paths
    RPA003  async safety     no blocking calls in the asyncio serving modules
    RPA004  registry coverage  every registered name tested + documented
    RPA005  metrics schema   summary()/cell key sets match the committed schema
    RPA000  (framework)      file does not parse — reported, never fatal
    RPA900  (framework)      suppression pragma without a justification

Suppress a finding with an inline pragma carrying a justification::

    t0 = time.perf_counter()  # repro: allow[RPA001] intentional wall time
"""
from __future__ import annotations

from pathlib import Path
from typing import List, Optional, Sequence

from repro.analysis.checkers import ALL_CHECKERS
from repro.analysis.core import (
    Checker,
    Finding,
    Project,
    find_repo_root,
    load_project,
    run_checkers,
)

__all__ = [
    "ALL_CHECKERS",
    "Checker",
    "Finding",
    "Project",
    "analyze",
    "find_repo_root",
    "load_project",
    "run_checkers",
]


def analyze(
    paths: Sequence[str],
    select: Optional[Sequence[str]] = None,
    root: Optional[Path] = None,
) -> List[Finding]:
    """Run the full suite over `paths`; the library entry point the CLI and
    the repo-smoke test share."""
    project = load_project([Path(p) for p in paths], root=root)
    return run_checkers(project, [cls() for cls in ALL_CHECKERS], select=select)
