"""Workload scenarios + SLO-attainment evaluation harness.

Importing this package registers every built-in scenario. Public surface:

    Scenario / TenantSpec / LengthDist   the scenario spec (SLO tiers are
                                         plain core SLOSpec values)
    register_scenario     decorator, @register_scenario("my-scenario")
    make_scenario         name + kwargs -> Scenario-like
    generate_scenario     name -> List[Request] (one-shot)
    available_scenarios   every registered name
    ArrivalProcess + Poisson/MarkovModulated/Sinusoidal arrivals
    HarnessConfig / evaluate_cell / run_grid   the evaluation harness

See DESIGN.md §workloads.
"""
from repro.workloads.arrivals import (
    ArrivalProcess,
    MarkovModulatedArrivals,
    PoissonArrivals,
    SinusoidalArrivals,
)
from repro.workloads.harness import (
    BACKENDS,
    HarnessConfig,
    evaluate_cell,
    run_grid,
    to_engine_requests,
)
from repro.workloads.scenarios import (
    DEFAULT_SLO_CLASSES,
    LengthDist,
    ReplayScenario,
    Scenario,
    TenantSpec,
    TraceConfigScenario,
    available_scenarios,
    generate_scenario,
    make_scenario,
    register_scenario,
)

__all__ = [
    "ArrivalProcess",
    "MarkovModulatedArrivals",
    "PoissonArrivals",
    "SinusoidalArrivals",
    "BACKENDS",
    "HarnessConfig",
    "evaluate_cell",
    "run_grid",
    "to_engine_requests",
    "DEFAULT_SLO_CLASSES",
    "LengthDist",
    "ReplayScenario",
    "Scenario",
    "TenantSpec",
    "TraceConfigScenario",
    "available_scenarios",
    "generate_scenario",
    "make_scenario",
    "register_scenario",
]
