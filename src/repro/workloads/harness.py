"""Evaluation harness: (scenario × prefill × decode × backend) grids.

One report schema over five backends:

    sim          `DisaggSimulator` via `run_policy` — paper-scale lengths
                 and SLOs, discrete-event time
    engine       the live `DisaggServer` driven through `ServeSession.run`
                 on a deterministic `ManualClock` — real JAX compute at
                 demo scale
    async-engine the same server behind the `AsyncServeSession` frontend:
                 requests are submitted open-loop at their arrival times on
                 an asyncio event loop and their token streams drained by
                 ``async_clients`` concurrent consumers — true concurrent
                 admission/delivery rather than a replayed loop. On the
                 shared `ManualClock` its per-request TTFT/TPOT match the
                 `engine` backend bit-for-bit (the async/sync parity
                 contract), so any divergence between those two columns is
                 a frontend bug, not noise.
    router       ``router_replicas`` such servers behind a `RouterSession`
                 (repro.serving.router): placement by ``router_policy``
                 from the routing registry, per-replica prefix caches doing
                 admission-time hit accounting. The cell carries a
                 ``router`` block (per-replica assigned/completed counts +
                 prefix hit rates). With 1 replica it reproduces the
                 async-engine cell bit-for-bit — the routing layer adds no
                 clock reads of its own.
    disagg       a P/D-split fleet (`repro.serving.disagg`): a prefill pool
                 and a decode pool of servers on ONE shared ManualClock,
                 with an explicit KV handoff (priced by
                 ``CostModel.transfer_time``, bounded in-flight window) and
                 registered prefill-deflection policies. The cell carries a
                 ``disagg`` block: handoff/deflection records plus per-pool
                 attainment. A 1P:1D fleet under ``never`` deflection
                 reproduces the 1-replica router cell bit-for-bit.

Scenario traces are paper-scale (prompts up to 128K tokens); the engine
backend maps each request onto an engine-scale twin (prompt/output lengths
rescaled into the engine's slot budget, arrivals compressed, tenant /
SLO-class labels preserved) so per-tenant admission quotas, shedding, and
the registry policies are exercised on real compute. Numbers from the two
backends are therefore *not* comparable to each other — the grid is for
attainment-vs-policy structure per backend, not cross-backend deltas.

Every cell reports total and per-tenant / per-SLO-class attainment, goodput
(SLO-met tokens/sec), and shed counts, all derived uniformly from terminal
request phases (`repro.sim.metrics`). `launch/evaluate.py` is the CLI;
`benchmarks/paper_figs.py` plots the emitted JSON.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.request import Phase, Request, SLOSpec
from repro.obs import TraceRecorder, trace_cell_block, write_trace
from repro.sim.metrics import attainment, attainment_by, goodput
from repro.sim.simulator import SimConfig, run_policy
from repro.workloads.scenarios import make_scenario

BACKENDS: Tuple[str, ...] = ("sim", "engine", "async-engine", "router", "disagg", "churn")


def parse_kills(specs: Sequence[str]) -> Tuple[Tuple[float, int], ...]:
    """Parse repeated ``"T:IDX"`` kill specs into a ``(t, replica_index)``
    schedule (virtual seconds on the engine timeline)."""
    out = []
    for spec in specs:
        try:
            t_str, i_str = spec.split(":")
            t, i = float(t_str), int(i_str)
        except ValueError:
            raise ValueError(
                f"kill spec must be 'T:IDX' (virtual seconds : replica index, "
                f"e.g. 0.05:1), got {spec!r}"
            ) from None
        if t < 0 or i < 0:
            raise ValueError(f"kill spec fields must be >= 0, got {spec!r}")
        out.append((t, i))
    return tuple(sorted(out))


def parse_pools(spec: str) -> Tuple[int, int]:
    """Parse a ``"P:D"`` pool-size spec into (prefill, decode) counts."""
    try:
        p_str, d_str = spec.split(":")
        p, d = int(p_str), int(d_str)
    except ValueError:
        raise ValueError(
            f"pool spec must be 'P:D' with integer pool sizes (e.g. 2:2), got {spec!r}"
        ) from None
    if p < 1 or d < 1:
        raise ValueError(f"pool sizes must be >= 1, got {spec!r}")
    return p, d


@dataclass(frozen=True)
class HarnessConfig:
    """Knobs shared by every cell of one grid run."""

    n_requests: Optional[int] = None  # override the scenario's default size
    seed: int = 0
    sim: SimConfig = field(default_factory=SimConfig)

    # engine backend: model + how paper-scale traces map onto it
    engine_arch: str = "llama3-8b-smoke"
    engine_max_prompt: int = 24  # paper-scale inputs rescaled into [2, this]
    engine_max_output: int = 6  # outputs rescaled into [1, this]
    engine_arrival_scale: float = 0.01  # arrivals × this -> engine virtual seconds
    # SLO targets must map into engine virtual time too, or attainment
    # degenerates to the completion rate (every paper-scale target is
    # trivially met under compressed arrivals). TTFT compresses with the
    # arrivals (None = follow engine_arrival_scale, so changing one knob
    # can't silently decouple them); TPOT tracks service time, which does
    # NOT compress, so it gets its own factor.
    engine_slo_ttft_scale: Optional[float] = None
    engine_slo_tpot_scale: float = 0.05

    @property
    def slo_ttft_scale(self) -> float:
        return (
            self.engine_slo_ttft_scale
            if self.engine_slo_ttft_scale is not None
            else self.engine_arrival_scale
        )
    engine_chunk_size: int = 16
    engine_max_slots: int = 8
    engine_max_len: int = 64
    # paged KV (DESIGN.md §kvcache): page_size switches every engine-family
    # backend from contiguous per-slot KV to refcounted pages with radix
    # prefix reuse; None keeps the slot substrate (bit-identical on
    # prefix-free traces — pinned in tests). cache_pages bounds the pool
    # (None = max_slots * max_len / page_size, i.e. slot-equivalent).
    page_size: Optional[int] = None
    cache_pages: Optional[int] = None
    queue_depth: Optional[int] = None  # global admission bound (engine)
    tenant_quota: Optional[int] = None  # per-tenant queued bound (engine)
    # async-engine backend: concurrent stream consumers, per-stream token
    # buffer, and the slow-consumer policy ("block" stalls the engine,
    # "shed" cancels the laggard — see repro.serving.frontend)
    async_clients: int = 4
    stream_buffer: int = 16
    backpressure: str = "block"
    # router backend: N AsyncServeSession replicas behind a RouterSession
    # (repro.serving.router), placement by a registered routing policy;
    # prefix_block is the prefix-trie block size for both the per-replica
    # session caches and the router's routing indexes
    router_replicas: int = 2
    router_policy: str = "least-queued"
    prefix_block: int = 4
    prefix_cache_blocks: Optional[int] = None
    # churn backend: the router fleet under churn — a FleetSession
    # (repro.serving.fleetctl) with injected replica kills and an
    # autoscaler moving the live-replica count within
    # [fleet_min_replicas, fleet_max_replicas] every autoscale_interval
    # virtual seconds, driven by windowed-SLO telemetry over slo_window
    # (falls back to autoscale_interval when slo_window is None)
    churn_kills: Tuple[Tuple[float, int], ...] = ()
    autoscaler_policy: str = "static"
    autoscale_interval: float = 0.05
    fleet_min_replicas: int = 1
    fleet_max_replicas: int = 6
    # disagg backend: prefill/decode pool sizes, the registered deflection
    # policy, KV-transfer pricing (shared by every engine backend's
    # admission handoff via EngineConfig), and the in-flight transfer bound
    disagg_prefill: int = 2
    disagg_decode: int = 2
    deflect_policy: str = "never"
    transfer_lat: float = 0.002
    transfer_bw: float = 900e9
    max_inflight_transfers: int = 8
    # observability (repro.obs): event-trace output. None = tracing off (the
    # default recorder is absent, so no per-event cost at all); "" = record
    # in memory and attach the cell's ``trace`` block but write no file; a
    # path = also export per cell (".jsonl" -> event JSONL, anything else ->
    # Chrome trace-event / Perfetto JSON), with a per-cell suffix so grid
    # cells never clobber each other. ``slo_window`` is the sliding-window
    # width in backend virtual seconds for the trace block's windowed SLO
    # series (None = omit the series).
    trace: Optional[str] = None
    slo_window: Optional[float] = None

    def as_dict(self) -> Dict:
        # the report's run-identity block: every knob (asdict recurses into
        # SimConfig), so two perf records with different settings never
        # diff as if only the numbers moved
        return dataclasses.asdict(self)


@dataclass
class _EngineBundle:
    """Lazily-built model shared by every engine cell of a grid."""

    arch: str
    cfg: object = None
    model: object = None
    params: object = None
    built: bool = field(default=False)

    def build(self):
        if not self.built:
            import jax

            from repro.configs import get_config
            from repro.models import build_model

            self.cfg = get_config(self.arch).replace(dtype="float32")
            self.model = build_model(self.cfg)
            self.params = self.model.init(jax.random.key(0))
            self.built = True
        return self


def _group_prefix_tokens(group: str, n: int, vocab_size: int) -> List[int]:
    """The shared prompt template for a prefix group: deterministic in the
    group name alone (CRC32 seed, not Python's salted hash), so every twin
    of the same group starts with literally identical tokens across runs."""
    rng = np.random.default_rng(zlib.crc32(group.encode("utf-8")))
    return list(map(int, rng.integers(2, vocab_size, n)))


def to_engine_requests(
    reqs: Sequence[Request], hcfg: HarnessConfig, vocab_size: int, rng: np.random.Generator
) -> List[Tuple[Request, List[int]]]:
    """Map paper-scale requests onto engine-scale (Request, prompt) twins.

    Lengths are rescaled relative to the trace maximum (preserving relative
    ordering, so long-tail structure survives), arrivals are compressed by
    ``engine_arrival_scale``, tenant/SLO-class labels carry over unchanged,
    and the numeric SLO targets are compressed into engine virtual time
    (``engine_slo_ttft_scale`` / ``engine_slo_tpot_scale``) so relative
    tier tightness — premium vs batch — survives and attainment stays
    policy-sensitive rather than trivially 1.0.

    Requests carrying a ``prefix_group`` (shared-system-prompt scenarios)
    get prompts that literally begin with the group's template for
    ``prefix_frac`` of their length — the token-level structure the prefix
    cache and prefix-affinity routing act on.
    """
    if not reqs:
        return []
    max_in = max(r.input_len for r in reqs)
    max_out = max(r.output_len for r in reqs)
    pairs = []
    for r in reqs:
        n_in = 2 + round((hcfg.engine_max_prompt - 2) * r.input_len / max_in)
        n_out = max(1, round(hcfg.engine_max_output * r.output_len / max_out))
        if r.prefix_group:
            # template head + unique tail; at least one unique token so no
            # two prompts are fully identical
            k = min(n_in - 1, max(0, round(n_in * r.prefix_frac)))
            prompt = _group_prefix_tokens(r.prefix_group, k, vocab_size) + list(
                map(int, rng.integers(2, vocab_size, n_in - k))
            )
        else:
            prompt = list(map(int, rng.integers(2, vocab_size, n_in)))
        pairs.append(
            (
                Request(
                    rid=r.rid,
                    arrival=r.arrival * hcfg.engine_arrival_scale,
                    input_len=n_in,
                    output_len=n_out,
                    slo=SLOSpec(
                        ttft=r.slo.ttft * hcfg.slo_ttft_scale,
                        tpot=r.slo.tpot * hcfg.engine_slo_tpot_scale,
                    ),
                    tenant=r.tenant,
                    slo_class=r.slo_class,
                    prefix_group=r.prefix_group,
                    prefix_frac=r.prefix_frac,
                ),
                prompt,
            )
        )
    return pairs


def _cell_report(reqs: Sequence[Request]) -> Dict:
    """The backend-independent part of a cell: everything is derived from
    terminal request phases, so every backend emits an identical schema."""
    att = attainment(reqs).as_dict()
    per_tenant = {k: v.as_dict() for k, v in attainment_by(reqs, "tenant").items()}
    return dict(
        n_requests=len(reqs),
        n_completed=sum(r.phase == Phase.DONE for r in reqs),
        attainment=att,
        per_tenant=per_tenant,
        per_class={k: v.as_dict() for k, v in attainment_by(reqs, "slo_class").items()},
        goodput=goodput(reqs),
        # shed counts are the same n_shed the attainment rows carry — one
        # source of truth, surfaced where the CLI/CI consumers look for it
        shed=dict(
            total=att["n_shed"],
            by_tenant={k: v["n_shed"] for k, v in per_tenant.items() if v["n_shed"]},
        ),
        # client-withdrawn requests (async frontend disconnect / slow-consumer
        # shed); structurally parallel to `shed` but a different fate —
        # cancelled ≠ shed ≠ failed (sim.metrics module docstring)
        cancelled=dict(
            total=att["n_cancelled"],
            by_tenant={
                k: v["n_cancelled"] for k, v in per_tenant.items() if v["n_cancelled"]
            },
        ),
    )


def _run_sim(
    reqs, prefill: str, decode: str, hcfg: HarnessConfig,
    trace: Optional[TraceRecorder] = None,
) -> List[Request]:
    res = run_policy(reqs, prefill, decode, sim_cfg=hcfg.sim, trace=trace)
    return res.requests


def _engine_cfg(prefill: str, decode: str, hcfg: HarnessConfig):
    """The one `EngineConfig` every engine-family backend (and the churn
    backend's `server_factory` for scale-up replicas) builds from — keeping
    a cold-started replica's knobs identical to the seed fleet's."""
    from repro.serving.engine import EngineConfig

    return EngineConfig(
        max_slots=hcfg.engine_max_slots,
        max_len=hcfg.engine_max_len,
        chunk_size=hcfg.engine_chunk_size,
        prefill_policy=prefill,
        decode_policy=decode,
        admission_queue_depth=hcfg.queue_depth,
        tenant_queue_depth=hcfg.tenant_quota,
        transfer_lat=hcfg.transfer_lat,
        transfer_bw=hcfg.transfer_bw,
        page_size=hcfg.page_size,
        cache_pages=hcfg.cache_pages,
    )


def _engine_setup(
    reqs,
    prefill: str,
    decode: str,
    hcfg: HarnessConfig,
    bundle: _EngineBundle,
    n_servers: int = 1,
    shared_clock: bool = False,
    trace: Optional[TraceRecorder] = None,
):
    """Shared (engine | async-engine | router | disagg | churn) setup:
    request twins plus ``n_servers`` fresh servers, each on its own
    deterministic ManualClock — or all on ONE shared clock
    (``shared_clock``, the disagg fleet's single-timeline requirement).
    Identical construction is what makes the engine backends directly
    comparable (and the 1-replica router cell bit-identical to
    async-engine).
    Returns ``(servers, pairs)``; single-server callers unpack ``servers[0]``.
    """
    from repro.serving.clock import ManualClock
    from repro.serving.engine import DisaggServer

    bundle.build()
    rng = np.random.default_rng(hcfg.seed)
    pairs = to_engine_requests(reqs, hcfg, bundle.cfg.vocab_size, rng)
    ecfg = _engine_cfg(prefill, decode, hcfg)
    fleet_clock = ManualClock(auto_step=1e-4) if shared_clock else None
    servers = [
        DisaggServer(
            bundle.model,
            bundle.params,
            ecfg,
            clock=fleet_clock if shared_clock else ManualClock(auto_step=1e-4),
            # server-level default sink, picked up by the single-server
            # sessions; the fleet backends instead hand the recorder to
            # their session layer (which stamps per-replica / per-pool
            # labels), so they build servers without one
            trace=trace,
        )
        for _ in range(n_servers)
    ]
    return servers, pairs


def _run_engine(
    reqs, prefill: str, decode: str, hcfg: HarnessConfig, bundle: _EngineBundle,
    trace: Optional[TraceRecorder] = None,
) -> Tuple[List[Request], Optional[Dict]]:
    from repro.serving.session import ServeSession

    (server,), pairs = _engine_setup(reqs, prefill, decode, hcfg, bundle, trace=trace)
    session = ServeSession(server)
    session.run(pairs)
    return [r for r, _ in pairs], kv_cell_block(session.summary())


def _run_async_engine(
    reqs, prefill: str, decode: str, hcfg: HarnessConfig, bundle: _EngineBundle,
    trace: Optional[TraceRecorder] = None,
) -> Tuple[List[Request], Optional[Dict]]:
    """The live-concurrency cell: open-loop submission through the
    `AsyncServeSession` frontend, streams drained by concurrent clients."""
    import asyncio

    from repro.serving.frontend import AsyncServeSession

    (server,), pairs = _engine_setup(reqs, prefill, decode, hcfg, bundle, trace=trace)

    async def _serve() -> Dict:
        frontend = AsyncServeSession(
            server,
            stream_buffer=hcfg.stream_buffer,
            backpressure=hcfg.backpressure,
        )
        async with frontend:
            await frontend.replay(pairs, clients=hcfg.async_clients)
        return frontend.summary()

    summary = asyncio.run(_serve())
    return [r for r, _ in pairs], kv_cell_block(summary)


def kv_cell_block(s: Dict) -> Optional[Dict]:
    """Project a session/fleet ``summary()`` into the report cell's ``kv``
    block: page-pool occupancy + sharing telemetry and the two sides of the
    reuse-is-real invariant (``prefill_computed_tokens`` must equal total
    prompt tokens minus ``prefix_cached_tokens`` — pinned in tests). None
    when the cell ran on the slot substrate (no ``pages`` in the summary),
    so slot cells keep their exact pre-paging schema."""
    if s.get("pages") is None:
        return None
    return dict(
        pages=s["pages"],
        prefix_cached_tokens=s["prefix_cached_tokens"],
        prefill_computed_tokens=s["prefill_computed_tokens"],
    )


def router_cell_block(s: Dict) -> Dict:
    """Project a `RouterSession.summary()` into the report cell's ``router``
    block: routing identity, fleet-wide prefix accounting, and per-replica
    counters (with the global/tenant shed split, so a per-tenant shed
    report can tell "fleet full" from "quota hit" per replica)."""
    return dict(
        policy=s["routing"]["policy"],
        replicas=s["routing"]["replicas"],
        assigned=s["routing"]["assigned"],
        prefix=s["prefix"],
        per_replica=[
            dict(
                replica=ps["replica"],
                assigned=ps["assigned"],
                submitted=ps["submitted"],
                completed=ps["completed"],
                rejected=ps["rejected"],
                rejected_global=ps["rejected_global"],
                rejected_tenant=ps["rejected_tenant"],
                cancelled=ps["cancelled"],
                prefix=ps["prefix"],
            )
            for ps in s["per_replica"]
        ],
    )


def _run_router(
    reqs, prefill: str, decode: str, hcfg: HarnessConfig, bundle: _EngineBundle,
    trace: Optional[TraceRecorder] = None,
) -> Tuple[List[Request], Dict]:
    """The fleet cell: ``router_replicas`` servers behind a `RouterSession`,
    placement by ``router_policy``. Returns the terminal requests plus the
    per-replica breakdown block for the report."""
    import asyncio

    from repro.serving.router import RouterSession

    servers, pairs = _engine_setup(
        reqs, prefill, decode, hcfg, bundle, n_servers=hcfg.router_replicas
    )

    async def _serve() -> RouterSession:
        router = RouterSession(
            servers,
            policy=hcfg.router_policy,
            stream_buffer=hcfg.stream_buffer,
            backpressure=hcfg.backpressure,
            prefix_block=hcfg.prefix_block,
            prefix_cache_blocks=hcfg.prefix_cache_blocks,
            trace=trace,
        )
        async with router:
            await router.replay(pairs, clients=hcfg.async_clients)
        return router

    router = asyncio.run(_serve())
    return [r for r, _ in pairs], router_cell_block(router.summary())


def churn_cell_block(s: Dict) -> Dict:
    """Project a `FleetSession.summary()` into the report cell: the router
    block (the fleet IS a router) plus the ``fleet`` control-plane record —
    kills, restores, autoscale decisions, and the per-kill recovery plans."""
    return dict(router_cell_block(s), fleet=s["fleet"])


def _run_churn(
    reqs, prefill: str, decode: str, hcfg: HarnessConfig, bundle: _EngineBundle,
    trace: Optional[TraceRecorder] = None,
) -> Tuple[List[Request], Dict]:
    """The churn cell: ``router_replicas`` servers behind a `FleetSession`
    with the kill schedule from ``churn_kills`` injected mid-run and the
    registered ``autoscaler_policy`` moving the live-replica count on
    windowed-SLO telemetry. ``server_factory`` hands the controller
    identically-configured cold replicas for scale-up."""
    import asyncio

    from repro.serving.clock import ManualClock
    from repro.serving.engine import DisaggServer
    from repro.serving.fleetctl import FleetSession

    servers, pairs = _engine_setup(
        reqs, prefill, decode, hcfg, bundle, n_servers=hcfg.router_replicas
    )

    def _factory() -> DisaggServer:
        return DisaggServer(
            bundle.model,
            bundle.params,
            _engine_cfg(prefill, decode, hcfg),
            clock=ManualClock(auto_step=1e-4),
        )

    async def _serve() -> FleetSession:
        fleet = FleetSession(
            servers,
            policy=hcfg.router_policy,
            autoscaler=hcfg.autoscaler_policy,
            n_min=hcfg.fleet_min_replicas,
            n_max=hcfg.fleet_max_replicas,
            autoscale_interval=hcfg.autoscale_interval,
            slo_window=hcfg.slo_window or hcfg.autoscale_interval or 0.5,
            kill_schedule=hcfg.churn_kills,
            server_factory=_factory,
            stream_buffer=hcfg.stream_buffer,
            backpressure=hcfg.backpressure,
            prefix_block=hcfg.prefix_block,
            prefix_cache_blocks=hcfg.prefix_cache_blocks,
            trace=trace,
        )
        async with fleet:
            await fleet.replay(pairs, clients=hcfg.async_clients)
        return fleet

    fleet = asyncio.run(_serve())
    return [r for r, _ in pairs], churn_cell_block(fleet.summary())


def disagg_cell_block(core, reqs: Sequence[Request]) -> Dict:
    """Project a `DisaggSession` into the report cell's ``disagg`` block:
    pool topology, the KV-handoff record, the deflection record, and the
    per-pool attainment split (which prefill worker's TTFT / which decode
    worker's TPOT story each pool tells)."""
    from repro.sim.metrics import attainment_by_pool

    labels = core.pool_labels()
    return dict(
        pools=dict(prefill=len(core.prefill_pool), decode=len(core.decode_pool)),
        deflect=core.deflect.name,
        handoff=core.handoff_summary(),
        deflection=core.deflection_summary(),
        attainment_by_prefill_pool={
            k: v.as_dict()
            for k, v in attainment_by_pool(reqs, labels["prefill"]).items()
        },
        attainment_by_decode_pool={
            k: v.as_dict()
            for k, v in attainment_by_pool(reqs, labels["decode"]).items()
        },
    )


def _run_disagg(
    reqs, prefill: str, decode: str, hcfg: HarnessConfig, bundle: _EngineBundle,
    trace: Optional[TraceRecorder] = None,
) -> Tuple[List[Request], Dict, Optional[Dict]]:
    """The P/D-split cell: ``disagg_prefill``:``disagg_decode`` servers on
    ONE shared ManualClock behind a `DisaggFleetSession`, prefill deflection
    by ``deflect_policy``. Returns the terminal requests plus the report's
    ``disagg`` block."""
    import asyncio

    from repro.serving.disagg import DisaggFleetSession

    servers, pairs = _engine_setup(
        reqs,
        prefill,
        decode,
        hcfg,
        bundle,
        n_servers=hcfg.disagg_prefill + hcfg.disagg_decode,
        shared_clock=True,
    )

    async def _serve() -> DisaggFleetSession:
        fleet = DisaggFleetSession(
            servers[: hcfg.disagg_prefill],
            servers[hcfg.disagg_prefill :],
            deflection=hcfg.deflect_policy,
            stream_buffer=hcfg.stream_buffer,
            backpressure=hcfg.backpressure,
            max_inflight_transfers=hcfg.max_inflight_transfers,
            trace=trace,
        )
        async with fleet:
            await fleet.replay(pairs, clients=hcfg.async_clients)
        return fleet

    fleet = asyncio.run(_serve())
    terminal = [r for r, _ in pairs]
    return terminal, disagg_cell_block(fleet.core, terminal), kv_cell_block(fleet.summary())


def _trace_path(base: str, scenario: str, prefill: str, decode: str, backend: str) -> str:
    """Per-cell trace path: the cell's coordinates spliced in before the
    extension, so one ``--trace out.json`` grid run never clobbers itself.
    The suffix is deterministic — consumers can reconstruct it, but the
    robust way is to read ``cell["trace"]["path"]`` from the report."""
    stem, dot, ext = base.rpartition(".")
    if not dot:
        stem, ext = base, "json"
    return f"{stem}.{backend}.{scenario}.{prefill}.{decode}.{ext}"


def evaluate_cell(
    scenario: str,
    prefill: str,
    decode: str,
    backend: str,
    hcfg: Optional[HarnessConfig] = None,
    scenario_kwargs: Optional[Dict] = None,
    _bundle: Optional[_EngineBundle] = None,
) -> Dict:
    """Run one (scenario, prefill, decode, backend) cell and report it."""
    if hcfg is None:
        hcfg = HarnessConfig()
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    kwargs = dict(scenario_kwargs or {})
    if hcfg.n_requests is not None:
        kwargs.setdefault("n_requests", hcfg.n_requests)
    # regenerate per cell so every cell is self-contained whatever the
    # backend does to the objects (the sim deepcopies and the engine builds
    # twins today, but a cell must not depend on its neighbors' backends)
    reqs = make_scenario(scenario, **kwargs).generate(hcfg.seed)
    if backend == "sim":
        bundle = None
    else:
        # build the model outside the timer; note the engine's jitted
        # prefill/decode steps still compile on first use, so the first
        # engine cell's wall_time_s carries that one-time cost
        bundle = (_bundle or _EngineBundle(hcfg.engine_arch)).build()
    # wall_time_s is intentionally host wall-clock, not sim/engine virtual
    # time: it reports what the cell cost the machine (compile + compute),
    # never anything a scheduling decision reads
    t0 = time.perf_counter()  # repro: allow[RPA001] intentional host wall time
    router_block = None
    disagg_block = None
    churn_block = None
    kv_block = None
    # trace=None keeps every emission site on its `if recorder is None`
    # fast path — the traced and untraced runs are bit-identical either way
    # (pinned in tests), this just skips even the no-op checks
    recorder = TraceRecorder() if hcfg.trace is not None else None
    if backend == "sim":
        terminal = _run_sim(reqs, prefill, decode, hcfg, trace=recorder)
    elif backend == "engine":
        terminal, kv_block = _run_engine(reqs, prefill, decode, hcfg, bundle, trace=recorder)
    elif backend == "async-engine":
        terminal, kv_block = _run_async_engine(
            reqs, prefill, decode, hcfg, bundle, trace=recorder
        )
    elif backend == "disagg":
        terminal, disagg_block, kv_block = _run_disagg(
            reqs, prefill, decode, hcfg, bundle, trace=recorder
        )
    elif backend == "churn":
        terminal, churn_block = _run_churn(
            reqs, prefill, decode, hcfg, bundle, trace=recorder
        )
    else:
        terminal, router_block = _run_router(
            reqs, prefill, decode, hcfg, bundle, trace=recorder
        )
    cell = dict(
        scenario=scenario,
        prefill=prefill,
        decode=decode,
        backend=backend,
        wall_time_s=time.perf_counter() - t0,  # repro: allow[RPA001] see t0 above
    )
    cell.update(_cell_report(terminal))
    if hcfg.page_size is not None and backend != "sim":
        # bench cells carrying paged runs key separately from slot cells
        # (benchmarks/check_regression.py folds this into the cell key).
        # The sim backend never builds an engine, so page_size is inert
        # there and the cell keeps its slot identity.
        cell["variant"] = "paged"
    if kv_block is not None:
        cell["kv"] = kv_block
    if router_block is not None:
        cell["router"] = router_block
    if disagg_block is not None:
        cell["disagg"] = disagg_block
    if churn_block is not None:
        cell["churn"] = churn_block
    if recorder is not None:
        trace_block = trace_cell_block(recorder.events, slo_window=hcfg.slo_window)
        if hcfg.trace:  # "" = in-memory block only, no file
            path = _trace_path(hcfg.trace, scenario, prefill, decode, backend)
            trace_block["path"] = path
            trace_block["format"] = write_trace(recorder.events, path)
        cell["trace"] = trace_block
    return cell


def run_grid(
    scenarios: Sequence[str],
    prefills: Sequence[str],
    decodes: Sequence[str],
    backends: Sequence[str] = ("sim",),
    hcfg: Optional[HarnessConfig] = None,
    scenario_kwargs: Optional[Dict[str, Dict]] = None,
) -> Dict:
    """Sweep the full cartesian grid; returns the single JSON-able report.

    ``scenario_kwargs`` maps scenario name -> factory kwargs (e.g. the
    ``replay`` scenario's ``path``).
    """
    if hcfg is None:
        hcfg = HarnessConfig()
    bundle = _EngineBundle(hcfg.engine_arch)  # built lazily, shared by cells
    cells = []
    for backend in backends:
        for scenario in scenarios:
            for prefill in prefills:
                for decode in decodes:
                    cells.append(
                        evaluate_cell(
                            scenario,
                            prefill,
                            decode,
                            backend,
                            hcfg=hcfg,
                            scenario_kwargs=(scenario_kwargs or {}).get(scenario),
                            _bundle=bundle,
                        )
                    )
    return dict(
        grid=dict(
            scenarios=list(scenarios),
            prefills=list(prefills),
            decodes=list(decodes),
            backends=list(backends),
        ),
        config=hcfg.as_dict(),
        cells=cells,
    )
