"""Workload scenarios: named, seeded generators of multi-tenant traces.

A `Scenario` bundles what the paper's evaluation varies implicitly — the
arrival process, the request-length distribution, the tenant mix, and the
SLO class of each tenant — behind one call: ``scenario.generate(seed) ->
List[Request]``. Scenarios register by name (mirroring `repro.policies`):

    @register_scenario("my-scenario")
    def my_scenario(n_requests=1000, **kw) -> Scenario: ...

    make_scenario("bursty", n_requests=200).generate(seed=0)
    available_scenarios()  # every registered name

Built-ins:

    paper-longtail  the paper's trace (wraps `TraceConfig`/`generate_trace`
                    bit-for-bit, for backward compatibility)
    bursty          Markov-modulated on/off arrivals, paper lengths
    diurnal         sinusoidal arrival rate (compressed daily cycle)
    multi-tenant    3 tenants with distinct length distributions and
                    TTFT/TPOT SLO classes (premium / standard / batch)
    heavy-head      long_frac cranked up to stress HOL blocking
    prefix-heavy    shared-system-prompt tenants (prefix-cache-friendly:
                    each group's prompts start with one template)
    flash-crowd     steady Poisson base load + one dense synchronized burst
                    (the request-imbalance spike the fleet controller's
                    autoscaler and failover paths are measured against)
    replay          JSONL trace via `load_trace` (requires path=...)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.request import Request, SLOSpec
# LengthDist lives beside generate_trace: one source of truth for the
# paper's length mixture, shared by TraceConfig and per-tenant scenarios.
from repro.sim.trace import (
    LengthDist,
    TraceConfig,
    generate_trace,
    load_trace,
    rescale_qps,
)
from repro.workloads.arrivals import (
    ArrivalProcess,
    MarkovModulatedArrivals,
    PoissonArrivals,
    SinusoidalArrivals,
)


# The default SLO tier table (name -> numeric targets); scenarios may
# override per-name. Tiers are plain `SLOSpec`s — the same type every
# Request carries — so there is exactly one SLO-target type in the repo.
DEFAULT_SLO_CLASSES: Dict[str, SLOSpec] = {
    "premium": SLOSpec(ttft=4.0, tpot=0.040),
    "standard": SLOSpec(ttft=8.0, tpot=0.050),
    "batch": SLOSpec(ttft=30.0, tpot=0.200),
}


@dataclass(frozen=True)
class TenantSpec:
    """One tenant's share of the trace, lengths, and SLO tier."""

    name: str
    weight: float = 1.0
    lengths: LengthDist = field(default_factory=LengthDist)
    slo_class: str = "standard"
    # fraction of each prompt that is the tenant's shared template (system
    # prompt / few-shot header). 0 = fully unique prompts; > 0 stamps
    # Request.prefix_group/prefix_frac so the engine harness materializes
    # literally shared prefix tokens — what prefix-cache-aware admission
    # and prefix-affinity routing exploit.
    shared_prefix_frac: float = 0.0


@dataclass(frozen=True)
class Scenario:
    """A named multi-tenant workload: everything needed to draw a trace."""

    name: str
    n_requests: int = 1000
    arrivals: ArrivalProcess = field(default_factory=PoissonArrivals)
    tenants: Tuple[TenantSpec, ...] = (TenantSpec("default"),)
    slo_classes: Mapping[str, SLOSpec] = field(
        default_factory=lambda: dict(DEFAULT_SLO_CLASSES)
    )

    def __post_init__(self):
        if self.n_requests <= 0:
            raise ValueError(f"n_requests must be positive, got {self.n_requests}")
        if not self.tenants:
            raise ValueError("scenario needs at least one tenant")
        for t in self.tenants:
            if t.weight <= 0:
                raise ValueError(f"tenant {t.name!r} has non-positive weight {t.weight}")
            if not 0.0 <= t.shared_prefix_frac < 1.0:
                raise ValueError(
                    f"tenant {t.name!r} shared_prefix_frac must be in [0, 1), "
                    f"got {t.shared_prefix_frac}"
                )
            if t.slo_class not in self.slo_classes:
                known = ", ".join(sorted(self.slo_classes))
                raise ValueError(
                    f"tenant {t.name!r} references unknown SLO class "
                    f"{t.slo_class!r}; known: {known}"
                )

    def generate(self, seed: int = 0) -> List[Request]:
        rng = np.random.default_rng(seed)
        n = self.n_requests
        arrivals = self.arrivals.times(n, rng)

        w = np.array([t.weight for t in self.tenants], float)
        tenant_idx = rng.choice(len(self.tenants), size=n, p=w / w.sum())

        input_lens = np.empty(n, int)
        output_lens = np.empty(n, int)
        for ti, tenant in enumerate(self.tenants):
            mask = tenant_idx == ti
            if mask.any():
                ins, outs = tenant.lengths.sample(int(mask.sum()), rng)
                input_lens[mask] = ins
                output_lens[mask] = outs

        reqs = []
        for i in range(n):
            tenant = self.tenants[tenant_idx[i]]
            slo = self.slo_classes[tenant.slo_class]
            reqs.append(
                Request(
                    rid=i,
                    arrival=float(arrivals[i]),
                    input_len=int(input_lens[i]),
                    output_len=int(output_lens[i]),
                    slo=slo,
                    tenant=tenant.name,
                    slo_class=tenant.slo_class,
                    prefix_group=tenant.name if tenant.shared_prefix_frac > 0 else "",
                    prefix_frac=tenant.shared_prefix_frac,
                )
            )
        return reqs


@dataclass(frozen=True)
class TraceConfigScenario:
    """Backward-compat wrapper: generates exactly `generate_trace(cfg)`.

    Keeps the paper trace bit-for-bit identical to the pre-workloads code
    path (same rng stream ordering), so existing sweeps don't shift.
    """

    name: str
    cfg: TraceConfig

    @property
    def n_requests(self) -> int:
        return self.cfg.n_requests

    def generate(self, seed: int = 0) -> List[Request]:
        return generate_trace(replace(self.cfg, seed=seed))


@dataclass(frozen=True)
class ReplayScenario:
    """Replays a JSONL trace (see `sim.trace.load_trace` for the format)."""

    name: str
    path: str
    n_requests: Optional[int] = None  # truncate; None = whole file
    qps: Optional[float] = None  # rescale arrivals to this rate

    def generate(self, seed: int = 0) -> List[Request]:
        # seed is accepted for interface uniformity; a replay is already
        # deterministic (the trace file *is* the randomness).
        reqs = load_trace(self.path)
        if self.n_requests is not None:
            reqs = reqs[: self.n_requests]
        # rescale AFTER truncating so the requested rate holds for the
        # prefix actually replayed (a bursty file front would otherwise
        # make the effective rate arbitrary)
        if self.qps is not None:
            rescale_qps(reqs, self.qps)
        return reqs


@dataclass(frozen=True)
class FlashCrowdScenario:
    """A steady base load with one dense, synchronized burst riding on top.

    ``crowd_frac`` of the requests arrive as a "crowd": a burst starting at
    ``t_crowd`` with exponential inter-arrivals at ``crowd_qps`` — an order
    of magnitude above the base rate — of short interactive requests. The
    burst is the canonical request-imbalance spike (PAPER §1): a fixed fleet
    queues it (standing queue depth, TTFT misses), which is exactly the
    windowed-telemetry signature reactive autoscalers key on. Rids are
    assigned in arrival order across both components, so replay drives are
    stable.
    """

    name: str
    n_requests: int = 200
    qps_base: float = 2.0
    crowd_frac: float = 0.5
    t_crowd: float = 10.0
    crowd_qps: float = 40.0
    slo_classes: Mapping[str, SLOSpec] = field(
        default_factory=lambda: dict(DEFAULT_SLO_CLASSES)
    )

    def __post_init__(self):
        if self.n_requests <= 0:
            raise ValueError(f"n_requests must be positive, got {self.n_requests}")
        if not 0.0 < self.crowd_frac < 1.0:
            raise ValueError(
                f"crowd_frac must be in (0, 1), got {self.crowd_frac}"
            )
        if self.qps_base <= 0 or self.crowd_qps <= 0:
            raise ValueError("qps_base and crowd_qps must be positive")

    def generate(self, seed: int = 0) -> List[Request]:
        rng = np.random.default_rng(seed)
        n_crowd = max(1, int(round(self.n_requests * self.crowd_frac)))
        n_base = self.n_requests - n_crowd
        reqs: List[Request] = []
        base_t = PoissonArrivals(qps=self.qps_base).times(n_base, rng)
        base_in, base_out = LengthDist().sample(n_base, rng)
        for t, i, o in zip(base_t, base_in, base_out):
            reqs.append(
                Request(
                    rid=0, arrival=float(t), input_len=int(i), output_len=int(o),
                    slo=self.slo_classes["standard"],
                    tenant="steady", slo_class="standard",
                )
            )
        crowd_t = self.t_crowd + np.cumsum(
            rng.exponential(1.0 / self.crowd_qps, n_crowd)
        )
        crowd_in, crowd_out = _INTERACTIVE_LENGTHS.sample(n_crowd, rng)
        for t, i, o in zip(crowd_t, crowd_in, crowd_out):
            reqs.append(
                Request(
                    rid=0, arrival=float(t), input_len=int(i), output_len=int(o),
                    slo=self.slo_classes["premium"],
                    tenant="crowd", slo_class="premium",
                )
            )
        reqs.sort(key=lambda r: r.arrival)
        for rid, r in enumerate(reqs):
            r.rid = rid
        return reqs


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_SCENARIOS: Dict[str, Callable[..., object]] = {}


def register_scenario(name: str):
    """Decorator: register a scenario factory (kwargs -> Scenario-like)."""

    def deco(fn):
        _SCENARIOS[name] = fn
        return fn

    return deco


def available_scenarios() -> Tuple[str, ...]:
    return tuple(sorted(_SCENARIOS))


def make_scenario(name: str, **kwargs):
    """Build a registered scenario; kwargs are forwarded to its factory
    (every built-in accepts ``n_requests``)."""
    try:
        factory = _SCENARIOS[name]
    except KeyError:
        known = ", ".join(sorted(_SCENARIOS))
        raise ValueError(
            f"unknown scenario {name!r}; registered scenarios: {known}"
        ) from None
    return factory(**kwargs)


def generate_scenario(name: str, seed: int = 0, **kwargs) -> List[Request]:
    """One-shot: `make_scenario(name, **kwargs).generate(seed)`."""
    return make_scenario(name, **kwargs).generate(seed)


# --------------------------------------------------------------------------
# built-ins
# --------------------------------------------------------------------------

# Shorter-bodied distribution for interactive tenants; long-tail-free.
_INTERACTIVE_LENGTHS = LengthDist(
    long_frac=0.0,
    short_median=600.0,
    short_sigma=0.6,
    max_input=8192,
    out_median_short=150.0,
    max_output=1000,
)

# Batch/analytics tenant: mostly long documents, long answers.
_BATCH_LENGTHS = LengthDist(
    long_frac=0.5,
    short_median=6000.0,
    long_median=40000.0,
    out_median_short=300.0,
    out_median_long=400.0,
)


@register_scenario("paper-longtail")
def paper_longtail(n_requests: int = 1000, qps: float = 3.0, **cfg_over):
    """The paper's production-like trace (Fig. 1a), via `TraceConfig`."""
    return TraceConfigScenario(
        name="paper-longtail",
        cfg=TraceConfig(n_requests=n_requests, qps=qps, **cfg_over),
    )


@register_scenario("bursty")
def bursty(
    n_requests: int = 1000,
    qps_on: float = 9.0,
    qps_off: float = 0.6,
    mean_on: float = 15.0,
    mean_off: float = 30.0,
):
    """Markov-modulated on/off arrivals over the paper length mix."""
    return Scenario(
        name="bursty",
        n_requests=n_requests,
        arrivals=MarkovModulatedArrivals(
            qps_on=qps_on, qps_off=qps_off, mean_on=mean_on, mean_off=mean_off
        ),
    )


@register_scenario("diurnal")
def diurnal(
    n_requests: int = 1000,
    qps_mean: float = 3.0,
    amplitude: float = 0.8,
    period: float = 240.0,
):
    """Sinusoidal arrival rate — a compressed daily cycle."""
    return Scenario(
        name="diurnal",
        n_requests=n_requests,
        arrivals=SinusoidalArrivals(qps_mean=qps_mean, amplitude=amplitude, period=period),
    )


@register_scenario("multi-tenant")
def multi_tenant(n_requests: int = 1000, qps: float = 3.0):
    """Three tenants with distinct length distributions and SLO tiers:

    interactive  50%  short prompts, tight premium SLOs
    standard     30%  the paper mix, standard SLOs
    batch        20%  long documents, loose batch SLOs
    """
    return Scenario(
        name="multi-tenant",
        n_requests=n_requests,
        arrivals=PoissonArrivals(qps=qps),
        tenants=(
            TenantSpec("interactive", weight=0.5, lengths=_INTERACTIVE_LENGTHS,
                       slo_class="premium"),
            TenantSpec("standard", weight=0.3, lengths=LengthDist(),
                       slo_class="standard"),
            TenantSpec("batch", weight=0.2, lengths=_BATCH_LENGTHS,
                       slo_class="batch"),
        ),
    )


@register_scenario("heavy-head")
def heavy_head(n_requests: int = 1000, qps: float = 3.0, long_frac: float = 0.35):
    """Long requests dominate (high long_frac): maximal HOL-blocking stress."""
    return Scenario(
        name="heavy-head",
        n_requests=n_requests,
        arrivals=PoissonArrivals(qps=qps),
        tenants=(TenantSpec("default", lengths=LengthDist(long_frac=long_frac)),),
    )


@register_scenario("prefix-heavy")
def prefix_heavy(
    n_requests: int = 1000,
    qps: float = 4.0,
    n_groups: int = 4,
    prefix_frac: float = 0.7,
):
    """Shared-system-prompt tenants: ``n_groups`` apps, each stamping every
    request with one template covering ``prefix_frac`` of the prompt (RAG /
    agent / few-shot traffic). The ROADMAP's prefix-cache-friendly workload:
    per-replica hit rate — and therefore TTFT under load — depends on
    whether routing keeps a group's requests together (prefix-affinity) or
    scatters them (round-robin)."""
    tenants = tuple(
        TenantSpec(
            f"app-{g}",
            lengths=_INTERACTIVE_LENGTHS,
            slo_class=("premium", "standard")[g % 2],
            shared_prefix_frac=prefix_frac,
        )
        for g in range(n_groups)
    )
    return Scenario(
        name="prefix-heavy",
        n_requests=n_requests,
        arrivals=PoissonArrivals(qps=qps),
        tenants=tenants,
    )


@register_scenario("flash-crowd")
def flash_crowd(
    n_requests: int = 200,
    qps_base: float = 2.0,
    crowd_frac: float = 0.5,
    t_crowd: float = 10.0,
    crowd_qps: float = 40.0,
):
    """Steady base + one dense burst: the churn backend's native workload."""
    return FlashCrowdScenario(
        name="flash-crowd",
        n_requests=n_requests,
        qps_base=qps_base,
        crowd_frac=crowd_frac,
        t_crowd=t_crowd,
        crowd_qps=crowd_qps,
    )


@register_scenario("replay")
def replay(path: Optional[str] = None, n_requests: Optional[int] = None,
           qps: Optional[float] = None):
    """Replay a JSONL trace: `make_scenario("replay", path="trace.jsonl")`."""
    if path is None:
        raise ValueError(
            'the "replay" scenario requires a trace file: '
            'make_scenario("replay", path="trace.jsonl") '
            "(see sim.trace.load_trace for the JSONL format)"
        )
    return ReplayScenario(name="replay", path=path, n_requests=n_requests, qps=qps)
