"""Arrival processes for workload scenarios.

The paper evaluates under Poisson arrivals only; real traffic is bursty
(correlated on/off phases) and diurnal (rate follows a daily cycle). Each
process here maps ``(n, rng) -> n sorted arrival times`` and is a frozen
dataclass so scenarios embedding one stay hashable/serializable.

    PoissonArrivals           memoryless, constant rate (paper baseline)
    MarkovModulatedArrivals   2-state MMPP: exponential on/off phases with
                              distinct rates — long-range burstiness
    SinusoidalArrivals        non-homogeneous Poisson with a sinusoidal
                              rate (diurnal cycle), sampled by thinning
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class ArrivalProcess(Protocol):
    def times(self, n: int, rng: np.random.Generator) -> np.ndarray: ...


@dataclass(frozen=True)
class PoissonArrivals:
    """Homogeneous Poisson arrivals at a constant QPS."""

    qps: float = 3.0

    def __post_init__(self):
        if self.qps <= 0:
            raise ValueError(f"qps must be positive, got {self.qps}")

    def times(self, n: int, rng: np.random.Generator) -> np.ndarray:
        return np.cumsum(rng.exponential(1.0 / self.qps, size=n))


@dataclass(frozen=True)
class MarkovModulatedArrivals:
    """2-state Markov-modulated Poisson process (on/off bursts).

    The system alternates between an *on* phase (rate ``qps_on``) and an
    *off* phase (rate ``qps_off``), with exponentially distributed phase
    durations. Because both the phase process and the within-phase arrivals
    are memoryless, a gap that crosses a phase boundary is simply redrawn
    at the new rate from the boundary.
    """

    qps_on: float = 9.0
    qps_off: float = 0.6
    mean_on: float = 15.0  # expected seconds per on phase
    mean_off: float = 30.0

    def __post_init__(self):
        if self.qps_on <= 0:
            raise ValueError(f"qps_on must be positive, got {self.qps_on}")
        if self.qps_off < 0:
            raise ValueError(f"qps_off must be >= 0, got {self.qps_off}")
        if self.mean_on <= 0 or self.mean_off <= 0:
            raise ValueError("phase durations must be positive")

    def times(self, n: int, rng: np.random.Generator) -> np.ndarray:
        out = np.empty(n)
        # start in the stationary phase distribution
        on = bool(rng.random() < self.mean_on / (self.mean_on + self.mean_off))
        t = 0.0
        t_switch = rng.exponential(self.mean_on if on else self.mean_off)
        i = 0
        while i < n:
            rate = self.qps_on if on else self.qps_off
            gap = rng.exponential(1.0 / rate) if rate > 0 else np.inf
            if t + gap >= t_switch:
                t = t_switch
                on = not on
                t_switch = t + rng.exponential(self.mean_on if on else self.mean_off)
                continue
            t += gap
            out[i] = t
            i += 1
        return out


@dataclass(frozen=True)
class SinusoidalArrivals:
    """Diurnal arrivals: rate(t) = qps_mean * (1 + amplitude*sin(2πt/period)).

    Sampled exactly by thinning (Lewis & Shedler): candidates at the peak
    rate, accepted with probability rate(t)/peak.
    """

    qps_mean: float = 3.0
    amplitude: float = 0.8  # relative swing, in [0, 1)
    period: float = 240.0  # seconds per cycle

    def __post_init__(self):
        if self.qps_mean <= 0:
            raise ValueError(f"qps_mean must be positive, got {self.qps_mean}")
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError(f"amplitude must be in [0, 1), got {self.amplitude}")
        if self.period <= 0:
            raise ValueError(f"period must be positive, got {self.period}")

    def rate(self, t: float) -> float:
        return self.qps_mean * (1.0 + self.amplitude * np.sin(2.0 * np.pi * t / self.period))

    def times(self, n: int, rng: np.random.Generator) -> np.ndarray:
        peak = self.qps_mean * (1.0 + self.amplitude)
        out = np.empty(n)
        t = 0.0
        i = 0
        while i < n:
            t += rng.exponential(1.0 / peak)
            if rng.random() * peak <= self.rate(t):
                out[i] = t
                i += 1
        return out
