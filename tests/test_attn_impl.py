"""attn_impl config plumbing: the Pallas flash kernels are a first-class
model option and agree with the jnp paths end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model


@pytest.fixture(scope="module")
def pair():
    base = get_config("llama3-8b-smoke").replace(dtype="float32")
    pal = base.replace(attn_impl="pallas")
    model = build_model(base)
    return base, pal, model.init(jax.random.key(0))


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return dict(inputs=t, labels=t)


def test_pallas_forward_matches_auto(pair):
    base, pal, params = pair
    batch = _batch(base)
    l0 = build_model(base).forward_train(params, batch, remat=False)
    l1 = build_model(pal).forward_train(params, batch, remat=False)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=2e-4, atol=2e-4)


def test_pallas_prefill_matches_auto(pair):
    base, pal, params = pair
    batch = _batch(base, seed=1)
    lg0, _ = build_model(base).prefill(params, batch)
    lg1, _ = build_model(pal).prefill(params, batch)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1), rtol=2e-4, atol=2e-4)


def test_pallas_falls_back_for_windowed(pair):
    """Sliding-window layers aren't kernel-supported; the dispatcher must
    fall through to jnp paths rather than mis-masking."""
    base, _, _ = pair
    win = base.replace(sliding_window=8, alternate_local_global=True, attn_impl="pallas")
    model = build_model(win)
    params = model.init(jax.random.key(0))
    logits = model.forward_train(params, _batch(win), remat=False)
    assert bool(jnp.isfinite(logits).all())
