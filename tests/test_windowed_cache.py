"""Windowed ring KV cache (gemma2-style local layers) — §Perf iteration D6.

The ring cache must be *exactly* equivalent to the plain full-length cache
with sliding-window masking, including far beyond the window boundary."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gemma2-9b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_windowed_cache_selected_only_when_profitable(setup):
    cfg, model, _ = setup
    assert "k_local" in model.init_cache(1, 64)  # max_len 64 > window 32
    assert "k_local" not in model.init_cache(1, 16)  # fits in the window


def test_ring_equals_plain_windowed_beyond_window(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    prompt = list(map(int, rng.integers(2, cfg.vocab_size, 60)))  # 60 >> window 32

    # plain reference: full-length cache, window enforced by masking
    hd = cfg.resolved_head_dim
    plain = dict(
        k=jnp.zeros((cfg.num_layers, 1, 64, cfg.num_kv_heads, hd), jnp.float32),
        v=jnp.zeros((cfg.num_layers, 1, 64, cfg.num_kv_heads, hd), jnp.float32),
    )
    ring = model.init_cache(1, 64)
    assert "k_local" in ring

    for t, tok in enumerate(prompt):
        a = jnp.asarray([[tok]], jnp.int32)
        p = jnp.asarray([t], jnp.int32)
        lg_ring, ring = model.decode(params, a, p, ring)
        lg_plain, plain = model.decode(params, a, p, plain)
        np.testing.assert_allclose(
            np.asarray(lg_ring), np.asarray(lg_plain), rtol=3e-4, atol=3e-4,
            err_msg=f"divergence at position {t}",
        )


def test_ring_cache_shrinks_memory(setup):
    cfg, model, _ = setup
    ring = model.init_cache(2, 256)
    plain_bytes = 2 * cfg.num_layers * 2 * 256 * cfg.num_kv_heads * cfg.resolved_head_dim * 4
    ring_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(ring))
    assert ring_bytes < 0.75 * plain_bytes  # local half stores only the window
