"""Hypothesis property tests for the trace generator and delivery pacer."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.pacer import DeliveryPacer
from repro.sim.trace import TraceConfig, generate_trace


@given(st.integers(0, 10_000), st.floats(0.5, 10.0))
@settings(max_examples=20, deadline=None)
def test_trace_invariants(seed, qps):
    cfg = TraceConfig(n_requests=50, qps=qps, seed=seed)
    reqs = generate_trace(cfg)
    assert len(reqs) == 50
    arr = [r.arrival for r in reqs]
    assert all(b >= a for a, b in zip(arr, arr[1:], strict=False))  # sorted arrivals
    for r in reqs:
        assert cfg.min_input <= r.input_len <= cfg.max_input
        assert cfg.min_output <= r.output_len <= cfg.max_output
    # mean inter-arrival ~ 1/qps (loose: 3x band)
    gaps = np.diff(arr)
    assert 1 / (3 * qps) < gaps.mean() < 3 / qps


def test_trace_deterministic_per_seed():
    a = generate_trace(TraceConfig(n_requests=20, seed=7))
    b = generate_trace(TraceConfig(n_requests=20, seed=7))
    assert [(r.arrival, r.input_len, r.output_len) for r in a] == [
        (r.arrival, r.input_len, r.output_len) for r in b
    ]


gen_times = st.lists(
    st.floats(0.0, 10.0).map(lambda x: round(x, 4)), min_size=1, max_size=30
).map(sorted)


@given(gen_times, st.floats(0.01, 0.2))
@settings(max_examples=40, deadline=None)
def test_pacer_properties(times, tpot):
    p = DeliveryPacer(mode="paced", pace_fraction=0.9)
    out = p.delivery_times(times, times[0], tpot)
    assert len(out) == len(times)
    # delivery never precedes generation and is monotone
    assert all(d >= g for d, g in zip(out, times, strict=True))
    assert all(b >= a for a, b in zip(out, out[1:], strict=False))
    # immediate mode is the identity
    assert DeliveryPacer(mode="immediate").delivery_times(times, times[0], tpot) == times


@given(gen_times, st.floats(0.01, 0.2))
@settings(max_examples=20, deadline=None)
def test_pacer_bank_non_negative(times, tpot):
    p = DeliveryPacer(mode="paced")
    for t_now in (times[0], times[len(times) // 2], times[-1] + 1.0):
        assert p.banked(times, t_now, times[0], tpot) >= 0
