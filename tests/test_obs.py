"""repro.obs: recorder/exporter/SLO units, the event-stream vs
SessionMetrics cross-check, and the overhead guard (a trace-enabled run is
bit-identical to a recorder-free run — tracing observes, never perturbs)."""
import copy
import json

import pytest

from repro.core.request import Phase, Request, SLOSpec
from repro.obs import (
    Event,
    EventType,
    TERMINAL_EVENTS,
    TraceRecorder,
    attainment_from_events,
    check_terminal_invariant,
    chrome_trace,
    counters_from_events,
    read_jsonl,
    trace_cell_block,
    windowed_slo,
    write_jsonl,
    write_trace,
)


def _stream() -> TraceRecorder:
    """One hand-built request lifecycle across a prefill and a decode pool."""
    tr = TraceRecorder()
    tr.emit(EventType.SUBMIT, 0.0, rid=0, tenant="a", pool="p0", arrival=0.0,
            input_len=4, output_len=2, slo_ttft=1.0, slo_tpot=0.5,
            slo_class="standard")
    tr.emit(EventType.ADMIT, 0.0, rid=0, tenant="a", pool="p0", queue_depth=1)
    tr.emit(EventType.PREFILL_START, 0.1, rid=0, pool="p0", take=4)
    tr.emit(EventType.PREFILL_END, 0.2, rid=0, pool="p0", queue_depth=0)
    tr.emit(EventType.HANDOFF_QUEUED, 0.2, rid=0, pool="p0")
    tr.emit(EventType.HANDOFF_START, 0.2, rid=0, pool="p0", ready_at=0.25)
    tr.emit(EventType.TOKEN, 0.2, rid=0, pool="p0")
    tr.emit(EventType.HANDOFF_ATTACH, 0.25, rid=0, pool="p1", slot=0)
    tr.emit(EventType.DECODE_STEP, 0.3, pool="p1", batch=1, step_time=0.05,
            tpot_budget=0.5)
    tr.emit(EventType.TOKEN, 0.3, rid=0, tenant="a", pool="p1", slot=0)
    tr.emit(EventType.DONE, 0.3, rid=0, tenant="a", pool="p1", slot=0,
            n_generated=2)
    return tr


# ------------------------------------------------------------------ events
def test_event_dict_roundtrip():
    ev = Event(type=EventType.TOKEN, t=1.5, rid=3, tenant="t", pool="p",
               slot=2, data={"k": 1})
    assert Event.from_dict(ev.as_dict()) == ev


def test_recorder_basics():
    tr = _stream()
    assert len(tr) == 11
    assert tr.by_type()["token"] == 2
    assert [e.type for e in tr.for_rid(0)][0] is EventType.SUBMIT
    # the scheduler-track DECODE_STEP carries rid=-1, not any request's rid
    assert all(e.rid == 0 for e in tr.for_rid(0))
    tr.clear()
    assert len(tr) == 0


def test_terminal_invariant_sees_exactly_one_terminal():
    tr = _stream()
    assert check_terminal_invariant(tr.events) == {0: ["done"]}
    tr.emit(EventType.CANCEL, 0.4, rid=0, stage="decode")  # double terminal
    assert check_terminal_invariant(tr.events)[0] == ["done", "cancel"]
    assert EventType.CANCEL in TERMINAL_EVENTS


def test_counters_from_synthetic_stream():
    tr = _stream()
    tr.emit(EventType.SUBMIT, 0.1, rid=1, tenant="b", arrival=0.1,
            input_len=4, output_len=2, slo_ttft=1.0, slo_tpot=0.5,
            slo_class="standard")
    tr.emit(EventType.SHED, 0.1, rid=1, tenant="b", scope="tenant",
            queue_depth=3)
    c = counters_from_events(tr.events)
    assert c["submitted"] == 2 and c["accepted"] == 1
    assert c["completed"] == 1 and c["rejected"] == 1
    assert c["rejected_tenant"] == 1 and c["rejected_global"] == 0
    assert c["rejected_rids"] == [1]
    assert c["completed_by_tenant"] == {"a": 1}


# ---------------------------------------------------------------- exporters
def test_jsonl_roundtrip(tmp_path):
    tr = _stream()
    path = str(tmp_path / "ev.jsonl")
    write_jsonl(tr.events, path)
    assert read_jsonl(path) == tr.events


def test_jsonl_malformed_line_reports_location(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text('{"type": "token", "t": 0.0}\nnot json\n')
    with pytest.raises(ValueError, match=r"bad\.jsonl:2"):
        read_jsonl(str(path))


def test_chrome_trace_shape():
    doc = chrome_trace(_stream().events)
    assert set(doc) >= {"traceEvents", "displayTimeUnit", "otherData"}
    evs = doc["traceEvents"]
    # process metadata for both pools, named after the pool labels
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"p0", "p1"} <= names
    # slices exist for prefill / handoff / decode, flows for TTFT
    assert {"X", "s", "f"} <= {e["ph"] for e in evs}
    flow_ids = [e["id"] for e in evs if e["ph"] in ("s", "f")]
    assert flow_ids and all(i != 0 for i in flow_ids)
    # per-track timestamps are monotone (the body is globally ts-sorted)
    tracks = {}
    for e in evs:
        if e["ph"] == "M":
            continue
        tracks.setdefault((e.get("pid"), e.get("tid")), []).append(e["ts"])
    for ts in tracks.values():
        assert all(a <= b for a, b in zip(ts, ts[1:]))


def test_write_trace_dispatches_on_suffix(tmp_path):
    tr = _stream()
    assert write_trace(tr.events, str(tmp_path / "t.jsonl")) == "jsonl"
    assert write_trace(tr.events, str(tmp_path / "t.json")) == "chrome"
    doc = json.loads((tmp_path / "t.json").read_text())
    assert "traceEvents" in doc


# ---------------------------------------------------------------------- slo
def test_windowed_slo_rejects_bad_window():
    with pytest.raises(ValueError):
        windowed_slo(_stream().events, 0.0)


def test_windowed_slo_buckets_terminal_events():
    out = windowed_slo(_stream().events, 0.25)
    assert out["window"] == 0.25 and out["n_windows"] == 2
    assert sum(w["done"] for w in out["windows"]) == 1
    assert sum(w["submitted"] for w in out["windows"]) == 1
    assert sum(w["tokens"] for w in out["windows"]) == 2
    # the handoff started and attached -> the gauge returns to zero
    assert out["windows"][-1]["inflight_last"] == 0


def test_trace_cell_block_summary():
    block = trace_cell_block(_stream().events, slo_window=0.25)
    assert block["events"] == 11 and block["requests"] == 1
    assert block["multi_terminal"] == 0
    assert block["attainment"]["n"] == 1
    assert block["slo"]["n_windows"] == 2
    # no slo_window -> no slo key (the block stays schema-stable otherwise)
    assert "slo" not in trace_cell_block(_stream().events)


# ----------------------------------------------------- sim cross-checks
def test_sim_events_reproduce_metrics_and_do_not_perturb():
    from repro.sim.metrics import attainment
    from repro.sim.simulator import run_policy
    from repro.workloads import generate_scenario

    reqs = generate_scenario("multi-tenant", seed=3, n_requests=24)
    base = run_policy(reqs, "kairos-urgency", "kairos-slack")
    tr = TraceRecorder()
    traced = run_policy(reqs, "kairos-urgency", "kairos-slack", trace=tr)
    # overhead guard: the recorder observes the identical schedule
    for a, b in zip(base.requests, traced.requests, strict=True):
        assert a.token_times == b.token_times
        assert a.prefill_finish == b.prefill_finish
    # events-derived attainment IS sim.metrics.attainment, float-for-float
    assert attainment_from_events(tr.events) == attainment(traced.requests).as_dict()
    assert all(len(v) == 1 for v in check_terminal_invariant(tr.events).values())
    c = counters_from_events(tr.events)
    assert c["submitted"] == 24 and c["completed"] == 24


# -------------------------------------------------- engine cross-checks
@pytest.fixture(scope="module")
def tiny_model():
    import jax

    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _server(tiny_model, trace=None, **ecfg_kw):
    from repro.serving.clock import ManualClock
    from repro.serving.engine import DisaggServer, EngineConfig

    cfg, model, params = tiny_model
    kw = dict(max_slots=4, max_len=64, chunk_size=16)
    kw.update(ecfg_kw)
    return DisaggServer(model, params, EngineConfig(**kw),
                        clock=ManualClock(auto_step=1e-4), trace=trace)


def _requests(cfg, n=4, max_out=4, seed=0, arrival_gap=0.0, tenant=""):
    import numpy as np

    rng = np.random.default_rng(seed)
    pairs = []
    for i in range(n):
        length = int(rng.integers(4, 14))
        prompt = list(map(int, rng.integers(2, cfg.vocab_size, length)))
        pairs.append((
            Request(rid=i, arrival=i * arrival_gap, input_len=length,
                    output_len=max_out, slo=SLOSpec(ttft=120.0, tpot=10.0),
                    tenant=tenant),
            prompt,
        ))
    return pairs


def test_engine_trace_on_is_bit_identical_to_trace_off(tiny_model):
    """The overhead guard on the live engine: an enabled recorder must not
    move a single clock read — identical outputs, timings, and summary."""
    from repro.serving.session import ServeSession

    cfg = tiny_model[0]
    sess0 = ServeSession(_server(tiny_model))
    out0 = sess0.run(_requests(cfg, n=5))
    tr = TraceRecorder()
    sess1 = ServeSession(_server(tiny_model, trace=tr))
    out1 = sess1.run(_requests(cfg, n=5))
    assert out0 == out1
    for a, b in zip(sess0.requests, sess1.requests, strict=True):
        assert a.token_times == b.token_times
        assert a.ttft() == b.ttft()
        assert a.mean_tpot() == b.mean_tpot()
    # the recorder adds no metric and changes no value
    assert sess0.summary() == sess1.summary()
    assert len(tr) > 0


def test_engine_counters_match_session_metrics(tiny_model):
    """Satellite cross-check: fold the event stream back into
    SessionMetrics-equivalent counters and demand equality — sheds (global
    quota), a queue-stage cancel, and prefix-cache hit accounting included."""
    from repro.serving.prefixcache import PrefixCache
    from repro.serving.session import ServeSession

    cfg = tiny_model[0]
    sess = ServeSession(_server(tiny_model, trace=TraceRecorder(),
                                admission_queue_depth=3),
                        prefix_cache=PrefixCache(block=4))
    pairs = _requests(cfg, n=6)
    # a literal shared head so the cache has something to hit
    head = pairs[0][1][:4]
    for _, p in pairs:
        p[:4] = head
    for r, p in pairs:
        sess.submit(r, p)  # arrivals all at t=0: the 4th+ queued are shed
    cancelled = next(r for r, _ in pairs if r.phase not in
                     (Phase.FAILED, Phase.CANCELLED))
    assert sess.cancel(cancelled.rid)
    while sess.has_work:
        sess.step()
    tr = sess.trace
    m = sess.metrics
    c = counters_from_events(tr.events)
    assert c["submitted"] == m.submitted == 6
    assert c["accepted"] == m.accepted
    assert c["rejected"] == m.rejected > 0
    assert c["rejected_global"] == m.rejected_global
    assert c["rejected_tenant"] == m.rejected_tenant
    assert c["completed"] == m.completed
    assert c["cancelled"] == m.cancelled == 1
    assert sorted(c["rejected_rids"]) == sorted(m.rejected_rids)
    assert sorted(c["cancelled_rids"]) == sorted(m.cancelled_rids)
    assert c["prefix_lookups"] == m.prefix_lookups == m.accepted
    assert c["prefix_hits"] == m.prefix_hits > 0
    assert c["prefix_hit_tokens"] == m.prefix_hit_tokens
    assert c["prefix_lookup_tokens"] == m.prefix_lookup_tokens
    assert all(len(v) == 1 for v in check_terminal_invariant(tr.events).values())


def test_cancel_mid_handoff_emits_exactly_one_terminal(tiny_model):
    """The satellite bugfix contract: a cancel landing while the KV is on
    the wire funnels through one path and emits exactly one terminal event,
    stamped with the transfer stage."""
    from repro.serving.clock import ManualClock
    from repro.serving.disagg import DisaggSession
    from repro.serving.engine import DisaggServer, EngineConfig

    cfg, model, params = tiny_model
    clock = ManualClock(auto_step=1e-4)
    ecfg = EngineConfig(max_slots=4, max_len=64, chunk_size=16,
                        transfer_lat=0.5)
    mk = lambda: DisaggServer(model, params, ecfg, clock=clock)
    tr = TraceRecorder()
    sess = DisaggSession([mk()], [mk()], trace=tr)
    (r, p), = _requests(cfg, n=1)
    sess.submit(r, p)
    sess.step()  # prefill completes; the 0.5s transfer is now in flight
    assert r.phase == Phase.TRANSFER
    assert sess.cancel(r.rid)
    terminals = [e for e in tr.events if e.type in TERMINAL_EVENTS]
    assert len(terminals) == 1
    assert terminals[0].type is EventType.CANCEL
    assert terminals[0].data["stage"] == "inflight"
    assert check_terminal_invariant(tr.events)[r.rid] == ["cancel"]


# ------------------------------------------------------------ harness block
def test_harness_trace_block_adds_no_metric_drift():
    from repro.workloads.harness import HarnessConfig, evaluate_cell

    args = ("multi-tenant", "kairos-urgency", "kairos-slack", "sim")
    plain = evaluate_cell(*args, hcfg=HarnessConfig(n_requests=20, seed=2))
    traced = evaluate_cell(
        *args, hcfg=HarnessConfig(n_requests=20, seed=2, trace="", slo_window=5.0)
    )
    strip = lambda c: {k: v for k, v in c.items()
                       if k not in ("wall_time_s", "trace")}
    assert strip(plain) == strip(traced)  # tracing only ADDS the block
    assert "trace" not in plain
    block = traced["trace"]
    assert block["requests"] == 20 and block["multi_terminal"] == 0
    assert block["slo"]["window"] == 5.0
