"""ServeSession tests: streaming submit/step, admission control, token
callbacks, input_len validation, virtual-clock determinism, and serve()'s
reimplementation on top of the session."""
import copy

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.request import Phase, Request, SLOSpec
from repro.models import build_model
from repro.serving.clock import ManualClock, MonotonicClock
from repro.serving.engine import DisaggServer, EngineConfig
from repro.serving.session import ServeSession


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _requests(cfg, n=4, max_out=4, seed=0, arrival_gap=0.0):
    rng = np.random.default_rng(seed)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, int(rng.integers(4, 14)))))
               for _ in range(n)]
    return [
        (
            Request(rid=i, arrival=arrival_gap * i, input_len=len(p), output_len=max_out,
                    slo=SLOSpec(ttft=120.0, tpot=10.0)),
            p,
        )
        for i, p in enumerate(prompts)
    ]


def _server(tiny_model, clock=None, **ecfg_kw):
    cfg, model, params = tiny_model
    kw = dict(max_slots=4, max_len=64, chunk_size=16)
    kw.update(ecfg_kw)
    return DisaggServer(model, params, EngineConfig(**kw), clock=clock)


def test_submit_rejects_input_len_mismatch(tiny_model):
    server = _server(tiny_model)
    session = ServeSession(server)
    req = Request(rid=0, arrival=0.0, input_len=7, output_len=2)
    with pytest.raises(ValueError, match="input_len=7"):
        session.submit(req, [3, 4, 5])
    # serve() validates too (it used to silently reassign input_len)
    with pytest.raises(ValueError, match="input_len=7"):
        server.serve([(req, [3, 4, 5])])


def test_online_arrivals_with_admission_shedding(tiny_model):
    """The acceptance scenario: an online-arrival burst through submit()/
    step(), with at least one request shed and recorded in metrics."""
    server = _server(tiny_model, clock=ManualClock(auto_step=1e-4))
    session = ServeSession(server, max_queue_depth=2)
    reqs = _requests(tiny_model[0], n=5, max_out=3)
    accepted = [session.submit(req, prompt) for req, prompt in reqs]

    assert accepted.count(False) >= 1  # burst exceeded the queue depth
    while session.has_work:
        session.step()

    s = session.summary()
    assert s["submitted"] == 5
    assert s["rejected"] == accepted.count(False)
    assert s["rejected_rids"] == [r.rid for (r, _), ok in zip(reqs, accepted, strict=True) if not ok]
    assert s["completed"] == s["accepted"]
    for (r, _), ok in zip(reqs, accepted, strict=True):
        assert r.phase == (Phase.DONE if ok else Phase.FAILED)
    # shed requests are visible in per-request metrics with null latencies
    per = {d["rid"]: d for d in s["requests"]}
    for (r, _), ok in zip(reqs, accepted, strict=True):
        if not ok:
            assert per[r.rid]["phase"] == "failed"
            assert per[r.rid]["ttft"] is None
        else:
            assert per[r.rid]["ttft"] is not None
            assert per[r.rid]["mean_tpot"] is not None


def test_on_token_callbacks_stream_every_token(tiny_model):
    server = _server(tiny_model, clock=ManualClock(auto_step=1e-4))
    per_req = []
    session_wide = []
    session = ServeSession(server, on_token=lambda r, tok, t: session_wide.append((r.rid, tok)))
    reqs = _requests(tiny_model[0], n=3, max_out=3)
    for req, prompt in reqs:
        session.submit(req, prompt, on_token=lambda r, tok, t: per_req.append((r.rid, tok)))
    done_rids = []
    while session.has_work:
        done_rids += session.step()

    assert sorted(done_rids) == [r.rid for r, _ in reqs]
    assert per_req == session_wide  # both hooks observe the same stream
    # the streamed tokens, grouped by rid, reconstruct the outputs exactly
    streamed = {}
    for rid, tok in session_wide:
        streamed.setdefault(rid, []).append(tok)
    assert streamed == session.outputs
    # token timestamps are monotone per request
    for r, _ in reqs:
        assert all(b >= a for a, b in zip(r.token_times, r.token_times[1:], strict=False))


def test_serve_is_a_thin_loop_over_the_session(tiny_model):
    """serve() (the legacy blocking API) must produce exactly the outputs of
    a manual submit/step loop over ServeSession."""
    reqs_a = _requests(tiny_model[0], n=3, max_out=4, seed=2)
    reqs_b = copy.deepcopy(reqs_a)

    server_a = _server(tiny_model, clock=ManualClock(auto_step=1e-4))
    outs_a = server_a.serve(reqs_a)

    server_b = _server(tiny_model, clock=ManualClock(auto_step=1e-4))
    session = ServeSession(server_b)
    for req, prompt in reqs_b:
        session.submit(req, prompt)
    while session.has_work:
        session.step()

    assert outs_a == session.outputs
    for (ra, _), (rb, _) in zip(reqs_a, reqs_b, strict=True):
        assert ra.phase == rb.phase == Phase.DONE
        assert ra.n_generated == rb.n_generated


def test_manual_clock_makes_engine_runs_deterministic(tiny_model):
    """With the injected ManualClock, two identical runs agree on every
    timestamp bit-for-bit — the wall-clock flake the clock seam removes."""

    def run_once():
        reqs = _requests(tiny_model[0], n=3, max_out=4, seed=1, arrival_gap=0.01)
        server = _server(tiny_model, clock=ManualClock(auto_step=2e-4))
        outs = server.serve(reqs)
        return outs, [(r.ttft(), r.mean_tpot(), tuple(r.token_times)) for r, _ in reqs]

    outs1, t1 = run_once()
    outs2, t2 = run_once()
    assert outs1 == outs2
    assert t1 == t2  # exact equality, not approx: virtual time is injected


def test_default_clock_is_wall_clock(tiny_model):
    server = _server(tiny_model)
    assert isinstance(server.clock, MonotonicClock)


def test_queue_depth_none_overrides_configured_depth(tiny_model):
    """FROM_CONFIG (default) inherits the EngineConfig depth; an explicit
    None always means unbounded, even over a depth-configured server."""
    server = _server(
        tiny_model, clock=ManualClock(auto_step=1e-4), admission_queue_depth=1
    )
    inherited = ServeSession(server)
    assert inherited.max_queue_depth == 1
    unbounded = ServeSession(server, max_queue_depth=None)
    assert unbounded.max_queue_depth is None
    reqs = _requests(tiny_model[0], n=3, max_out=2, seed=3)
    assert all(unbounded.submit(req, prompt) for req, prompt in reqs)


def test_serve_records_shedding_in_last_session(tiny_model):
    """serve() over a depth-configured engine sheds; the session (and its
    rejection metrics) stays reachable via server.last_session."""
    server = _server(
        tiny_model, clock=ManualClock(auto_step=1e-4), admission_queue_depth=1
    )
    reqs = _requests(tiny_model[0], n=4, max_out=2, seed=5)
    outs = server.serve(reqs)
    s = server.last_session.summary()
    assert s["rejected"] >= 1
    assert set(outs) == {r.rid for r, _ in reqs if r.phase == Phase.DONE}
    assert s["rejected"] + s["completed"] == len(reqs)
