"""End-to-end disaggregated engine tests on real JAX compute (CPU, tiny
model). The key property: scheduling policy changes TIMING, never TOKENS."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.request import Phase, Request, SLOSpec
from repro.models import build_model
from repro.serving.engine import DisaggServer, EngineConfig, reference_generate
from repro.serving.kvcache import SlotAllocator


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _requests(cfg, n=5, max_out=10, seed=0):
    rng = np.random.default_rng(seed)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, rng.integers(4, 28))))
               for _ in range(n)]
    reqs = [
        (
            Request(rid=i, arrival=0.002 * i, input_len=len(p), output_len=max_out,
                    slo=SLOSpec(ttft=120.0, tpot=10.0)),
            p,
        )
        for i, p in enumerate(prompts)
    ]
    return reqs, prompts


@pytest.mark.parametrize("policy", ["kairos-urgency", "fcfs"])
@pytest.mark.parametrize("decode_policy", ["kairos-slack", "continuous"])
def test_scheduling_invariance(tiny_model, policy, decode_policy):
    cfg, model, params = tiny_model
    reqs, prompts = _requests(cfg, n=4, max_out=8)
    ecfg = EngineConfig(
        max_slots=8, max_len=96, chunk_size=16,
        prefill_policy=policy, decode_policy=decode_policy,
    )
    server = DisaggServer(model, params, ecfg)
    outs = server.serve(reqs)
    for i, p in enumerate(prompts):
        ref = reference_generate(model, params, p, 8, 96)
        assert outs[i][: len(ref)] == ref, f"rid={i} policy={policy}/{decode_policy}"
    for r, _ in reqs:
        assert r.phase == Phase.DONE
        assert r.ttft() is not None and r.mean_tpot() is not None


def test_engine_chunked_prefill_spans_chunks(tiny_model):
    """A prompt longer than chunk_size must take multiple prefill steps and
    still produce reference tokens."""
    cfg, model, params = tiny_model
    rng = np.random.default_rng(3)
    prompt = list(map(int, rng.integers(2, cfg.vocab_size, 45)))  # 45 > 16*2
    req = Request(rid=0, arrival=0.0, input_len=45, output_len=6,
                  slo=SLOSpec(ttft=120.0, tpot=10.0))
    ecfg = EngineConfig(max_slots=4, max_len=96, chunk_size=16)
    server = DisaggServer(model, params, ecfg)
    outs = server.serve([(req, prompt)])
    ref = reference_generate(model, params, prompt, 6, 96)
    assert outs[0][: len(ref)] == ref


def test_admission_respects_kv_budget(tiny_model):
    cfg, model, params = tiny_model
    alloc = SlotAllocator(max_slots=4, kv_cap_tokens=100)
    s1 = alloc.alloc(60)
    s2 = alloc.alloc(50)  # over budget
    assert s1 is not None and s2 is None
    s3 = alloc.alloc(40)
    assert s3 is not None and alloc.used_tokens == 100
    alloc.release(s1)
    assert alloc.used_tokens == 40
    snap = alloc.snapshot()
    alloc2 = SlotAllocator(max_slots=4, kv_cap_tokens=100)
    alloc2.restore(snap)
    assert alloc2.used_tokens == 40 and len(alloc2.free) == 3


def test_engine_lut_learns_real_step_times(tiny_model):
    """Online LUT updates (paper Alg.3 l.23-24) must ingest measured times."""
    cfg, model, params = tiny_model
    reqs, _ = _requests(cfg, n=3, max_out=6, seed=1)
    ecfg = EngineConfig(max_slots=8, max_len=96, chunk_size=32)
    server = DisaggServer(model, params, ecfg)
    before = server.lut.count.sum()
    server.serve(reqs)
    assert server.lut.count.sum() > before  # observations recorded
    assert server.mu._n > 0  # prefill throughput estimator updated
