"""AsyncServeSession tests: async/sync parity on a ManualClock, streaming
order + TTFT timestamps, backpressure (block and shed), mid-stream client
cancellation with slot/queue reclamation, admission shedding through the
async path, and the cancelled-vs-shed metrics contract.

The tests drive the event loop with ``asyncio.run`` from plain sync test
functions, so they need no pytest-asyncio plugin at runtime (the ``[test]``
extra still ships it for CI environments that want native async tests).
"""
import asyncio
import copy

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.request import Phase, Request, SLOSpec
from repro.models import build_model
from repro.serving.clock import ManualClock
from repro.serving.engine import DisaggServer, EngineConfig
from repro.serving.frontend import AsyncServeSession
from repro.serving.session import ServeSession
from repro.sim.metrics import attainment


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _requests(cfg, n=4, max_out=4, seed=0, arrival_gap=0.0):
    rng = np.random.default_rng(seed)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, int(rng.integers(4, 14)))))
               for _ in range(n)]
    return [
        (
            Request(rid=i, arrival=arrival_gap * i, input_len=len(p), output_len=max_out,
                    slo=SLOSpec(ttft=120.0, tpot=10.0)),
            p,
        )
        for i, p in enumerate(prompts)
    ]


def _server(tiny_model, clock=None, **ecfg_kw):
    cfg, model, params = tiny_model
    kw = dict(max_slots=4, max_len=64, chunk_size=16)
    kw.update(ecfg_kw)
    return DisaggServer(
        model, params, EngineConfig(**kw),
        clock=clock if clock is not None else ManualClock(auto_step=1e-4),
    )


# --------------------------------------------------------------- parity
def test_async_sync_parity_on_manual_clock(tiny_model):
    """The acceptance criterion: on the same trace and ManualClock, the
    async frontend reproduces ServeSession.run()'s outputs AND per-request
    TTFT/TPOT/token timestamps bit-for-bit."""
    reqs_sync = _requests(tiny_model[0], n=5, max_out=4, seed=2, arrival_gap=0.01)
    reqs_async = copy.deepcopy(reqs_sync)

    server_a = _server(tiny_model)
    outs_sync = server_a.serve(reqs_sync)

    server_b = _server(tiny_model)

    async def run_async():
        frontend = AsyncServeSession(server_b)
        async with frontend:
            return await frontend.replay(reqs_async, clients=3)

    outs_async = asyncio.run(run_async())

    assert outs_sync == outs_async
    for (rs, _), (ra, _) in zip(reqs_sync, reqs_async, strict=True):
        assert rs.phase == ra.phase == Phase.DONE
        # exact equality, not approx: both sides read the same virtual clock
        # in the same order, so any drift is a frontend scheduling bug
        assert rs.ttft() == ra.ttft()
        assert rs.mean_tpot() == ra.mean_tpot()
        assert rs.token_times == ra.token_times


def test_streaming_token_order_and_ttft_timestamps(tiny_model):
    """Tokens arrive through handle.stream() in generation order, and the
    first streamed token's timestamp is the request's TTFT anchor."""
    server = _server(tiny_model)
    pairs = _requests(tiny_model[0], n=3, max_out=3, seed=1)

    async def run():
        streamed = {}

        async def consume(h):
            async for tok in h.stream():
                streamed.setdefault(h.rid, []).append(tok)

        frontend = AsyncServeSession(server)
        async with frontend:
            handles = [await frontend.submit(r, p) for r, p in pairs]
            assert all([await h.admitted() for h in handles])
            await asyncio.gather(*(consume(h) for h in handles))
        return streamed, frontend

    streamed, frontend = asyncio.run(run())
    assert streamed == frontend.session.outputs  # order and content both
    for r, _ in pairs:
        assert r.phase == Phase.DONE
        assert r.first_token_time == r.token_times[0]
        assert r.ttft() == r.first_token_time - r.arrival
        assert all(b >= a for a, b in zip(r.token_times, r.token_times[1:], strict=False))


# --------------------------------------------------------- backpressure
def test_backpressure_shed_cancels_slow_consumer(tiny_model):
    """A consumer that never drains its 1-token buffer gets shed: the
    request is cancelled, counted in backpressure_shed, and its decode slot
    is reclaimed."""
    server = _server(tiny_model)
    (req, prompt), = _requests(tiny_model[0], n=1, max_out=5, seed=3)

    async def run():
        frontend = AsyncServeSession(server, stream_buffer=1, backpressure="shed")
        async with frontend:
            handle = await frontend.submit(req, prompt)
            assert await handle.admitted()
            # no one consumes: the second token overflows the buffer
        return frontend, handle

    frontend, handle = asyncio.run(run())
    assert req.phase == Phase.CANCELLED
    assert handle.cancel_reason == "backpressure"
    m = frontend.metrics
    assert m.backpressure_shed == 1
    assert m.cancelled == 1 and m.cancelled_rids == [req.rid]
    assert m.rejected == 0  # shed-by-backpressure is NOT admission shedding
    # engine resources reclaimed
    assert frontend.session.active == [] and frontend.session.queue == []
    assert server.decode.alloc.live_tokens == {}


def test_backpressure_block_delivers_everything(tiny_model):
    """With the "block" policy and a tiny buffer, a slow-but-alive consumer
    stalls the engine instead of losing tokens: every token is delivered."""
    server = _server(tiny_model)
    (req, prompt), = _requests(tiny_model[0], n=1, max_out=5, seed=4)

    async def run():
        got = []
        frontend = AsyncServeSession(server, stream_buffer=1, backpressure="block")
        async with frontend:
            handle = await frontend.submit(req, prompt)

            async def slow_consume():
                async for tok in handle.stream():
                    await asyncio.sleep(0)  # yield repeatedly: consumer lags
                    await asyncio.sleep(0)
                    got.append(tok)

            await slow_consume()
        return got, frontend

    got, frontend = asyncio.run(run())
    assert req.phase == Phase.DONE
    assert got == frontend.session.outputs[req.rid]
    assert frontend.metrics.backpressure_shed == 0
    assert frontend.metrics.cancelled == 0


def test_shed_policy_never_drops_a_completed_requests_tokens(tiny_model):
    """A request whose final token lands while its buffer is full is DONE,
    not a laggard: the shed policy must deliver into the reserved slots
    rather than cancel it (the reviewer-found final-token edge)."""
    server = _server(tiny_model)
    (req, prompt), = _requests(tiny_model[0], n=1, max_out=2, seed=7)

    async def run():
        frontend = AsyncServeSession(server, stream_buffer=1, backpressure="shed")
        async with frontend:
            handle = await frontend.submit(req, prompt)
            assert await handle.admitted()
            # consume nothing until the request has fully finished
        got = []
        async for tok in handle.stream():
            got.append(tok)
        return frontend, got

    frontend, got = asyncio.run(run())
    assert req.phase == Phase.DONE  # output_len=2 fits buffer+reserve
    assert got == frontend.session.outputs[req.rid] and len(got) == 2
    assert frontend.metrics.backpressure_shed == 0
    assert frontend.metrics.cancelled == 0


# --------------------------------------------------------- cancellation
def test_midstream_cancel_reclaims_slot_and_queue(tiny_model):
    """Breaking out of handle.stream() mid-generation == client disconnect:
    the request terminates CANCELLED, its slot/queue entry is reclaimed, and
    the other stream runs to completion undisturbed."""
    server = _server(tiny_model)
    pairs = _requests(tiny_model[0], n=2, max_out=6, seed=5)
    (r0, p0), (r1, p1) = pairs

    async def run():
        frontend = AsyncServeSession(server)
        async with frontend:
            h0 = await frontend.submit(r0, p0)
            h1 = await frontend.submit(r1, p1)

            async def disconnect_after_first(h):
                async for _ in h.stream():
                    break  # client walks away mid-stream

            async def drain(h):
                async for _ in h.stream():
                    pass

            await asyncio.gather(disconnect_after_first(h0), drain(h1))
        return frontend

    frontend = asyncio.run(run())
    assert r0.phase == Phase.CANCELLED
    assert r1.phase == Phase.DONE
    assert r0.n_generated >= 1  # it really was mid-stream
    assert len(frontend.session.outputs[r1.rid]) == r1.n_generated
    # reclamation: nothing left in any stage, no leaked decode slot
    assert frontend.session.queue == []
    assert frontend.session.waiting_adm == []
    assert frontend.session.active == []
    assert server.decode.alloc.live_tokens == {}
    s = frontend.summary()
    assert s["cancelled"] == 1 and s["cancelled_rids"] == [r0.rid]
    assert s["completed"] == 1
    per = {d["rid"]: d for d in s["requests"]}
    assert per[r0.rid]["phase"] == "cancelled"


def test_pre_admission_cancel_is_recorded_not_lost(tiny_model):
    """Cancelling before the scheduled arrival (client gave up while the
    request was still queued for submission) must still terminate the
    request in CANCELLED and count in the metrics — not leave it QUEUED
    and invisible to every report."""
    server = _server(tiny_model)
    (req, prompt), = _requests(tiny_model[0], n=1, max_out=2, seed=8)

    async def run():
        frontend = AsyncServeSession(server)
        async with frontend:
            handle = await frontend.submit(req, prompt, at=1e9)  # far future
            handle.cancel()
            assert (await handle.admitted()) is False
            out = [tok async for tok in handle.stream()]
        return frontend, handle, out

    frontend, handle, out = asyncio.run(run())
    assert out == []
    assert req.phase == Phase.CANCELLED
    assert handle.cancel_reason == "client"
    m = frontend.metrics
    assert m.cancelled == 1 and m.cancelled_rids == [req.rid]
    # submitted-but-neither-accepted-nor-rejected: the counters add up and
    # summary() carries a per-request row like any other terminal fate
    assert m.submitted == 1 and m.accepted == 0 and m.rejected == 0
    s = frontend.summary()
    per = {d["rid"]: d for d in s["requests"]}
    assert per[req.rid]["phase"] == "cancelled"
    handle.cancel()  # idempotent: terminal phase short-circuits


def test_aclose_resolves_unprocessed_submits(tiny_model):
    """aclose() on exception must resolve handles whose submit intents the
    stepper never ingested, or their awaiters would hang forever."""
    server = _server(tiny_model)
    (req, prompt), = _requests(tiny_model[0], n=1, max_out=2, seed=9)

    async def run():
        frontend = AsyncServeSession(server)
        handle = None
        try:
            async with frontend:
                handle = await frontend.submit(req, prompt)
                raise RuntimeError("client blew up before the stepper ran")
        except RuntimeError:
            pass
        # must resolve promptly instead of deadlocking
        verdict = await asyncio.wait_for(handle.admitted(), timeout=5)
        out = [tok async for tok in handle.stream()]
        return verdict, out

    verdict, out = asyncio.run(run())
    assert verdict is False and out == []
    assert req.phase == Phase.CANCELLED


def test_async_admission_shed_is_failed_not_cancelled(tiny_model):
    """Admission control still sheds through the async path — and a shed
    request is FAILED (server's miss), never CANCELLED (client's exit)."""
    server = _server(tiny_model)
    pairs = _requests(tiny_model[0], n=4, max_out=2, seed=6)

    async def run():
        frontend = AsyncServeSession(server, max_queue_depth=1)
        async with frontend:
            handles = [await frontend.submit(r, p) for r, p in pairs]
            verdicts = [await h.admitted() for h in handles]
            outs = await asyncio.gather(*(h.result() for h in handles))
        return frontend, verdicts, outs

    frontend, verdicts, outs = asyncio.run(run())
    assert verdicts.count(False) >= 1
    for (r, _), ok, out in zip(pairs, verdicts, outs, strict=True):
        if ok:
            assert r.phase == Phase.DONE and out == frontend.session.outputs[r.rid]
        else:
            assert r.phase == Phase.FAILED and out == []
    m = frontend.metrics
    assert m.rejected == verdicts.count(False)
    assert m.cancelled == 0 and m.backpressure_shed == 0


def test_stepper_crash_surfaces_instead_of_hanging(tiny_model):
    """An engine exception mid-run must unblock consumers (EOS) and
    re-raise out of drain()/async-with — never deadlock the frontend."""
    server = _server(tiny_model)
    (req, prompt), = _requests(tiny_model[0], n=1, max_out=4, seed=10)

    def boom(*a, **kw):
        raise RuntimeError("engine exploded")

    async def run():
        frontend = AsyncServeSession(server)
        frontend.session.step = boom  # the next step() call blows up
        handle = None
        with pytest.raises(RuntimeError, match="engine exploded"):
            async with frontend:
                handle = await frontend.submit(req, prompt)
                # consuming must terminate (EOS on crash), not hang
                return [tok async for tok in handle.stream()], handle
        return [], handle

    out, handle = asyncio.run(asyncio.wait_for(run(), timeout=30))
    assert out == []
    assert handle.cancel_reason in ("client", "error")


def test_restart_after_drain(tiny_model):
    """start() after a completed drain() serves a second batch — the drain
    state must not leak into the new stepper."""
    server = _server(tiny_model)
    pairs = _requests(tiny_model[0], n=2, max_out=2, seed=11)

    async def run():
        frontend = AsyncServeSession(server)
        frontend.start()
        h0 = await frontend.submit(*pairs[0])
        out0 = await h0.result()
        await frontend.drain()

        frontend.start()
        h1 = await frontend.submit(*pairs[1])
        out1 = await asyncio.wait_for(h1.result(), timeout=30)
        await frontend.drain()
        return out0, out1

    out0, out1 = asyncio.run(run())
    assert out0 and out1
    assert all(r.phase == Phase.DONE for r, _ in pairs)


# --------------------------------------------------------------- metrics
def test_attainment_keeps_cancelled_out_of_the_denominator():
    """cancelled ≠ shed ≠ failed: CANCELLED requests are reported via
    n_cancelled but neither help nor hurt any attainment fraction."""
    def req(rid, phase):
        r = Request(rid=rid, arrival=0.0, input_len=4, output_len=2,
                    slo=SLOSpec(ttft=100.0, tpot=100.0))
        r.phase = phase
        if phase == Phase.DONE:
            r.first_token_time = 1.0
            r.token_times = [1.0, 1.5]
            r.n_generated = 2
            r.done_time = 1.5
        return r

    reqs = [req(0, Phase.DONE), req(1, Phase.FAILED), req(2, Phase.CANCELLED)]
    att = attainment(reqs)
    assert att.n == 2  # DONE + shed; the cancellation is not an SLO event
    assert att.n_shed == 1
    assert att.n_cancelled == 1
    assert att.ttft == 0.5  # one hit over {DONE, FAILED}, unchanged by rid 2
    done_only = attainment(reqs, done_only=True)
    assert done_only.n == 1 and done_only.ttft == 1.0
    assert done_only.n_cancelled == 1  # still visible, still not counted


# --------------------------------------------------------------- harness
def test_harness_async_engine_backend_matches_engine_backend():
    """The grid's async-engine cell is the engine cell served online: same
    twins, same ManualClock, so the attainment block must agree exactly."""
    from repro.workloads.harness import HarnessConfig, evaluate_cell

    hcfg = HarnessConfig(n_requests=10)
    kw = dict(hcfg=hcfg)
    sync_cell = evaluate_cell("multi-tenant", "kairos-urgency", "kairos-slack",
                              "engine", **kw)
    async_cell = evaluate_cell("multi-tenant", "kairos-urgency", "kairos-slack",
                               "async-engine", **kw)
    assert async_cell["backend"] == "async-engine"
    assert sync_cell["attainment"] == async_cell["attainment"]
    assert sync_cell["per_tenant"] == async_cell["per_tenant"]
    assert sync_cell["goodput"] == async_cell["goodput"]
    assert async_cell["cancelled"]["total"] == 0


def test_loadgen_cli_emits_evaluate_schema(tmp_path):
    from repro.launch import loadgen

    out = tmp_path / "loadgen-report.json"
    report = loadgen.main([
        "--scenario", "multi-tenant", "--n", "10", "--clients", "3",
        "--out", str(out),
    ])
    assert out.exists()
    cell, = report["cells"]
    # the evaluate.py cell schema, plus the loadgen block
    for key in ("attainment", "per_tenant", "per_class", "goodput", "shed", "cancelled"):
        assert key in cell
    assert cell["backend"] == "async-engine"
    lg = cell["loadgen"]
    assert lg["clients"] == 3 and len(lg["tokens_by_client"]) == 3
    # every completed request streamed at least one token to some client
    assert sum(lg["tokens_by_client"]) >= cell["n_completed"] >= 1
    assert lg["backpressure"] == "block" and lg["realtime"] is False
