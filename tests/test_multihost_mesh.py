"""Real multi-device mesh test (ROADMAP item): `ShardingPlan` + `param_pspecs`
divisibility fallbacks exercised on an actual 8-device mesh, not a (1, 1)
host mesh.

JAX fixes its device count at first initialization, so the 8-device run
happens in a subprocess launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the parent asserts
on the JSON the worker prints. Run the worker directly with
``python tests/test_multihost_mesh.py --worker``.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

_SRC = str(Path(__file__).resolve().parents[1] / "src")


def _worker() -> None:
    import math

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.dist.sharding import ShardingPlan, param_pspecs
    from repro.launch.mesh import make_mesh
    from repro.models import build_model

    assert len(jax.devices()) == 8, f"expected 8 forced host devices, got {len(jax.devices())}"
    mesh = make_mesh((2, 4), ("data", "model"))

    # smoke config: vocab 256, d_ff 128, q-heads 64, kv 32 — all divide the
    # 4-way model axis, so the plan shards cleanly with zero fallbacks
    cfg = get_config("llama3-8b-smoke")
    model = build_model(cfg)
    struct = model.param_struct()
    plan = ShardingPlan(mesh)
    specs = param_pspecs(cfg, struct, plan)
    flat_struct = jax.tree_util.tree_leaves(struct)
    flat_specs = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_struct) == len(flat_specs)
    model_sharded = [
        (leaf, spec)
        for leaf, spec in zip(flat_struct, flat_specs, strict=True)
        if "model" in tuple(spec)
    ]

    # place one genuinely sharded leaf across all 8 devices and compute on it
    leaf, spec = max(model_sharded, key=lambda t: len(t[0].shape))
    x = jax.device_put(jnp.ones(leaf.shape, jnp.float32), NamedSharding(mesh, spec))
    shards = x.addressable_shards
    axis = tuple(spec).index("model")
    total = float(jnp.sum(x))  # cross-device reduction actually runs
    assert total == float(math.prod(leaf.shape))

    # indivisible vocab (250 % 4 != 0): the embed/vocab dims must fall back
    # to replication, recorded in plan.fallbacks — never a crash
    cfg_bad = cfg.replace(vocab_size=250)
    model_bad = build_model(cfg_bad)
    plan_bad = ShardingPlan(mesh)
    specs_bad = param_pspecs(cfg_bad, model_bad.param_struct(), plan_bad)
    flat_bad = jax.tree_util.tree_leaves(
        specs_bad, is_leaf=lambda x: isinstance(x, P)
    )
    assert len(flat_bad) == len(jax.tree_util.tree_leaves(model_bad.param_struct()))

    print(
        json.dumps(
            dict(
                n_devices=len(jax.devices()),
                mesh_shape=dict(mesh.shape),
                n_params=len(flat_struct),
                n_model_sharded=len(model_sharded),
                clean_fallbacks=list(plan.fallbacks),
                bad_fallbacks=list(plan_bad.fallbacks),
                placed_leaf_shape=list(leaf.shape),
                placed_shard_shape=list(shards[0].data.shape),
                placed_n_shards=len(shards),
                placed_sharded_axis=axis,
                placed_sum=total,
            )
        )
    )


def test_sharding_plan_on_real_8_device_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, __file__, "--worker"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, f"worker failed:\n{proc.stderr[-4000:]}"
    payload = json.loads(proc.stdout.strip().splitlines()[-1])

    assert payload["n_devices"] == 8
    assert payload["mesh_shape"] == {"data": 2, "model": 4}
    # the smoke config shards cleanly on a 4-way model axis: no fallbacks,
    # and a meaningful fraction of params actually model-sharded
    assert payload["clean_fallbacks"] == []
    assert payload["n_model_sharded"] >= 3
    # the placed leaf really was split 4-way on its model axis over 8 devices
    assert payload["placed_n_shards"] == 8
    ax = payload["placed_sharded_axis"]
    assert payload["placed_shard_shape"][ax] * 4 == payload["placed_leaf_shape"][ax]
    # indivisible vocab triggered the recorded replication fallback
    assert any("250" in f and "replicated" in f for f in payload["bad_fallbacks"])


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker()
    else:
        test_sharding_plan_on_real_8_device_mesh()
        print("ok")
