"""Per-arch reduced-config smoke tests: one forward/train step + one decode
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import build_model


def _batch(cfg, b=2, s=24):
    rng = np.random.default_rng(0)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.is_encdec:
        return dict(
            src=jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32),
            tgt=labels,
            labels=labels,
        )
    if cfg.input_mode == "embeddings":
        return dict(
            inputs=jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32),
            labels=labels,
        )
    return dict(inputs=labels, labels=labels)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits = model.forward_train(params, batch, remat=False)
    s = batch["tgt"].shape[1] if cfg.is_encdec else batch["inputs"].shape[1]
    assert logits.shape == (2, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss)
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_prefill_and_decode(arch):
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg)
    logits, kv = model.prefill(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    cache = model.init_cache(batch=2, max_len=48)
    toks = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.asarray([3, 7], jnp.int32)
    lg, cache2 = model.decode(params, toks, pos, cache)
    assert lg.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all())
    # cache leaves keep shape/dtype
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2), strict=True):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_count_matches_nameplate(arch):
    cfg = get_config(arch)
    nameplate = {
        "grok-1-314b": 314e9,
        "phi3.5-moe-42b-a6.6b": 41.9e9,
        "gemma2-9b": 9.2e9,
        "llama3-8b": 8.0e9,
        "minicpm-2b": 2.7e9,
        "command-r-35b": 35e9,
        "chameleon-34b": 34e9,
        "mamba2-130m": 0.13e9,
        "zamba2-2.7b": 2.7e9,
        "seamless-m4t-medium": 1.2e9,
    }[arch]
    n = cfg.count_params()
    assert 0.45 * nameplate <= n <= 1.25 * nameplate, (arch, n, nameplate)
