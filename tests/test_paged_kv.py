"""Paged KV substrate: allocator, page-mapped prefix cache, and real reuse.

  * `PageAllocator` bookkeeping: tables, refcounted sharing, O(1) token
    accounting, the pressure-evictor hook, shortage-leaves-state-untouched
  * `gather_pages`/`scatter_pages` round-trip through a real model pool
  * pin semantics (the PR-5 eviction bug): LRU eviction never drops blocks
    an in-flight request admitted against, nor pages a live table still maps
  * the parity contract: a paged engine is bit-identical to the slot engine
    on prefix-free workloads — token ids AND per-request ttft / mean_tpot
    (DESIGN.md §kvcache; CI pins the same property via the harness)
  * reuse is real: on prefix-heavy workloads prefill computes exactly
    ``total prompt tokens - reported hit tokens`` on both the single-server
    session and the P/D-disaggregated fleet, with unchanged token outputs
  * the `srpt` and `cache-aware` prefill policies order as documented
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.request import Request, SLOSpec
from repro.models import build_model
from repro.policies import make_prefill
from repro.serving.clock import ManualClock
from repro.serving.disagg import DisaggSession
from repro.serving.engine import DisaggServer, EngineConfig
from repro.serving.kvcache import PageAllocator, gather_pages, scatter_pages
from repro.serving.prefixcache import PrefixCache
from repro.serving.session import ServeSession


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _server(tiny_model, clock=None, **ecfg_kw):
    cfg, model, params = tiny_model
    kw = dict(max_slots=4, max_len=64, chunk_size=16)
    kw.update(ecfg_kw)
    return DisaggServer(
        model, params, EngineConfig(**kw),
        clock=clock if clock is not None else ManualClock(auto_step=1e-4),
    )


def _requests(cfg, n=4, max_out=4, seed=0, arrival_gap=0.0, shared_head=0):
    """n requests; with ``shared_head`` every prompt starts with the same
    head tokens (the prefix-heavy shape) followed by a unique tail."""
    rng = np.random.default_rng(seed)
    head = list(map(int, rng.integers(2, cfg.vocab_size, shared_head)))
    prompts = [
        head + list(map(int, rng.integers(2, cfg.vocab_size, int(rng.integers(4, 14)))))
        for _ in range(n)
    ]
    return [
        (
            Request(rid=i, arrival=arrival_gap * i, input_len=len(p),
                    output_len=max_out, slo=SLOSpec(ttft=120.0, tpot=10.0)),
            p,
        )
        for i, p in enumerate(prompts)
    ]


def _run_session(server, reqs):
    session = ServeSession(server)
    for req, prompt in reqs:
        session.submit(req, prompt)
    while session.has_work:
        session.step()
    return session


# ------------------------------------------------------------- PageAllocator
class TestPageAllocator:
    def test_alloc_link_release_lifecycle(self):
        pa = PageAllocator(page_size=4, n_pages=8)
        assert pa.free_pages == 8 and pa.used_tokens == 0
        t0 = pa.alloc_table(owner=0, n_tokens=9)  # 3 pages
        assert len(t0) == 3 and pa.free_pages == 5
        assert pa.used_tokens == 12  # page-granular, O(1)
        # a second request shares t0's first two pages, draws one fresh
        t1 = pa.alloc_table(owner=1, n_tokens=12, shared=t0[:2])
        assert t1[:2] == t0[:2] and len(t1) == 3
        assert pa.free_pages == 4 and pa.shared_links == 2
        assert pa.refcount[t0[0]] == 2
        # releasing the original owner keeps the shared pages live
        pa.release_table(0)
        assert pa.refcount[t0[0]] == 1 and t0[2] in pa.free
        pa.release_table(1)
        assert pa.free_pages == 8 and not pa.refcount and not pa.tables

    def test_shortage_returns_none_and_leaves_state_untouched(self):
        pa = PageAllocator(page_size=4, n_pages=2)
        t0 = pa.alloc_table(owner=0, n_tokens=8)
        snap = (list(pa.free), dict(pa.refcount))
        assert pa.alloc_table(owner=1, n_tokens=8) is None
        assert (list(pa.free), dict(pa.refcount)) == snap
        # sharing lowers the fresh need below the shortage
        assert pa.can_admit(8, shared=t0) and pa.can_admit(4) is False

    def test_duplicate_owner_and_excess_shared_raise(self):
        pa = PageAllocator(page_size=4, n_pages=4)
        t0 = pa.alloc_table(owner=0, n_tokens=4)
        with pytest.raises(ValueError, match="already holds"):
            pa.alloc_table(owner=0, n_tokens=4)
        with pytest.raises(ValueError, match="exceed"):
            pa.alloc_table(owner=1, n_tokens=2, shared=t0 + t0)

    def test_pressure_evictor_hook_rescues_allocation(self):
        pa = PageAllocator(page_size=4, n_pages=2)
        pa.alloc_table(owner=0, n_tokens=8)
        hoard = pa.tables[0]

        def surrender(want):
            freed = 0
            while hoard and freed < want:
                pa.release_page(hoard.pop())
                freed += 1
            return freed

        pa.evictor = surrender
        del pa.tables[0]  # the "cache" now holds the refs, not an owner
        t1 = pa.alloc_table(owner=1, n_tokens=8)
        assert t1 is not None and pa.pressure_evictions == 2


def test_gather_scatter_pages_roundtrip(tiny_model):
    cfg, model, _ = tiny_model
    ps, n_pages = 4, 8
    pool = model.init_cache(n_pages, ps)
    table = jnp.array([[3, 1, 5], [0, 6, 2]])  # two requests, three pages each
    rng = np.random.default_rng(1)
    sub = {
        name: jnp.asarray(
            rng.standard_normal((leaf.shape[0], 2, 3 * ps, *leaf.shape[3:])),
            dtype=leaf.dtype,
        )
        for name, leaf in pool.items()
    }
    pool2 = scatter_pages(cfg, pool, sub, table)
    back = gather_pages(cfg, pool2, table)
    for name in pool:
        np.testing.assert_array_equal(np.asarray(back[name]), np.asarray(sub[name]))


# ---------------------------------------------------- pin/eviction regression
def test_eviction_never_drops_blocks_pinned_by_inflight_requests():
    """The PR-5 bug: LRU leaf eviction could evict a block an in-flight
    request's admission accounting still referenced. Pinned paths survive
    any pressure; release makes them ordinary LRU victims again."""
    cache = PrefixCache(block=4, max_blocks=3)
    held = list(range(100, 108))  # 2 blocks
    cache.admit(held, rid=7)
    # flood with one-block prompts: way over budget, all strictly younger
    for i in range(6):
        cache.admit([200 + 4 * i + j for j in range(4)])
    assert len(cache) <= 3 or cache.pinned_requests  # over budget only via pins
    assert cache.match(held) == 8  # the pinned path is fully intact
    cache.release(7)
    cache.admit([300, 301, 302, 303])  # any later admit may now evict it
    assert cache.match(held) < 8
    assert len(cache) <= 3

    # release is idempotent and unknown rids are a no-op
    cache.release(7)
    cache.release(999)


def test_eviction_never_frees_pages_mapped_by_live_tables():
    pa = PageAllocator(page_size=4, n_pages=4)
    cache = PrefixCache(block=4, max_blocks=1, pages=pa)
    prompt = [1, 2, 3, 4, 5, 6, 7, 8]
    table = pa.alloc_table(owner=0, n_tokens=8)
    cache.assign_pages(prompt, table)  # cache retains both pages
    assert pa.refcount[table[0]] == 2  # owner + cache
    # over budget (max_blocks=1) but both nodes back live-table pages:
    # eviction must refuse rather than tear KV out from under owner 0
    cache.admit([9, 10, 11, 12])
    assert cache.match(prompt) == 8
    # once the owner releases, the colder block becomes evictable
    pa.release_table(0)
    cache.admit([13, 14, 15, 16])
    assert len(cache) <= 2  # drains back toward budget as pressure allows


# ----------------------------------------------------------- parity contract
def test_paged_engine_bit_identical_to_slot_engine_prefix_free(tiny_model):
    """The acceptance pin: on a prefix-free workload (no shared heads, so
    zero page sharing) the paged engine reproduces the slot engine exactly —
    token ids AND the ManualClock latency metrics, per request."""
    reqs_a = _requests(tiny_model[0], n=5, max_out=4, seed=3, arrival_gap=0.002)
    reqs_b = copy.deepcopy(reqs_a)

    slot = _run_session(_server(tiny_model), reqs_a)
    paged = _run_session(_server(tiny_model, page_size=4), reqs_b)

    assert paged.outputs == slot.outputs
    per_s = {d["rid"]: d for d in slot.summary()["requests"]}
    per_p = {d["rid"]: d for d in paged.summary()["requests"]}
    assert per_p.keys() == per_s.keys()
    for rid in per_s:
        assert per_p[rid]["ttft"] == per_s[rid]["ttft"]
        assert per_p[rid]["mean_tpot"] == per_s[rid]["mean_tpot"]
    # and with no shared prefixes, nothing was skipped or shared
    s = paged.summary()
    assert s["prefix_cached_tokens"] == 0
    assert s["pages"]["shared_links"] == 0


def test_padded_subbatch_never_corrupts_a_live_slot(tiny_model):
    """Regression: with every slot live, a decode sub-batch smaller than its
    bucket used to pad into lane ``max_slots - 1`` — a LIVE slot — and
    overwrite that request's position-0 KV. Both substrates must match the
    scheduling-free sequential reference for every request."""
    from repro.serving.engine import reference_generate

    cfg, model, params = tiny_model
    rng = np.random.default_rng(42)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, n)))
               for n in (12, 8, 12, 10)]  # fills all 4 slots at once
    reqs = [
        (Request(rid=i, arrival=0.0, input_len=len(p), output_len=4,
                 slo=SLOSpec(ttft=120.0, tpot=10.0)), p)
        for i, p in enumerate(prompts)
    ]
    slot = _run_session(_server(tiny_model), copy.deepcopy(reqs))
    paged = _run_session(_server(tiny_model, page_size=4), copy.deepcopy(reqs))
    for i, p in enumerate(prompts):
        ref = reference_generate(model, params, p, 4, 64)
        assert slot.outputs[i] == ref
        assert paged.outputs[i] == ref


# ------------------------------------------------------------- reuse is real
def _run_staggered(server, reqs):
    """Submit one request at a time, draining in between, so each prompt's
    KV pages have landed before the next admission probes the radix cache
    (online traffic, compressed)."""
    session = ServeSession(server)
    for req, prompt in reqs:
        session.submit(req, prompt)
        while session.has_work:
            session.step()
    return session


def test_engine_prefill_computes_exactly_prompts_minus_hits(tiny_model):
    """Prefix-heavy: prefill compute drops by exactly the reported hit
    tokens (not accounting credit — real skipped chunks), tokens unchanged."""
    reqs_a = _requests(tiny_model[0], n=6, max_out=3, seed=4, shared_head=16)
    reqs_b = copy.deepcopy(reqs_a)

    slot = _run_staggered(_server(tiny_model), reqs_a)
    paged = _run_staggered(_server(tiny_model, page_size=4), reqs_b)

    assert paged.outputs == slot.outputs  # reuse never changes tokens
    s, p = slot.summary(), paged.summary()
    total_prompt = sum(len(prompt) for _, prompt in reqs_a)
    assert s["prefill_computed_tokens"] == total_prompt  # slot mode skips nothing
    assert p["prefix_cached_tokens"] > 0
    assert p["prefill_computed_tokens"] == total_prompt - p["prefix_cached_tokens"]
    assert p["pages"]["shared_links"] > 0  # hits rode refcounted pages


def test_disagg_prefill_computes_exactly_prompts_minus_hits(tiny_model):
    """The same invariant across the P/D split: submit-time probe, pinned
    pages on the owning decode worker, prefill skips the hit tokens."""
    def _fleet(page_size=None):
        clock = ManualClock(auto_step=1e-4)
        kw = dict(page_size=page_size) if page_size else {}
        servers = [_server(tiny_model, clock=clock, **kw) for _ in range(2)]
        return DisaggSession(servers[:1], servers[1:])

    reqs_a = _requests(tiny_model[0], n=6, max_out=3, seed=5, shared_head=16)
    reqs_b = copy.deepcopy(reqs_a)

    def _drive(sess, reqs):
        # staggered online traffic: each prompt's pages land on the decode
        # worker before the next submit-time probe runs
        for req, prompt in reqs:
            sess.submit(req, prompt)
            for _ in range(5000):
                if not sess.has_work:
                    break
                sess.step()
            assert not sess.has_work
        return sess.summary()

    s = _drive(_fleet(), reqs_a)
    p = _drive(_fleet(page_size=4), reqs_b)

    total_prompt = sum(len(prompt) for _, prompt in reqs_a)
    assert s["prefill_computed_tokens"] == total_prompt
    assert p["prefix_cached_tokens"] > 0
    assert p["prefill_computed_tokens"] == total_prompt - p["prefix_cached_tokens"]
    assert p["prefix"]["hit_rate"] > 0


# ------------------------------------------------------ new prefill policies
def _queue_req(rid, input_len, output_len, cached=0, ttft=10.0):
    r = Request(rid=rid, arrival=0.0, input_len=input_len, output_len=output_len,
                slo=SLOSpec(ttft=ttft, tpot=1.0))
    r.prefix_cached_tokens = cached
    return r


def test_srpt_orders_by_total_remaining_service():
    srpt = make_prefill("srpt")
    assert srpt.name == "srpt"
    # short prompt + long generation loses to long prompt + nearly done:
    # the index is remaining prefill PLUS remaining decode, unlike sjf
    a = _queue_req(0, input_len=8, output_len=100)  # remaining 108
    b = _queue_req(1, input_len=30, output_len=2)  # remaining 32
    picked = srpt.select([a, b], t_now=0.0, mu=1e4, budget=16)
    assert picked[0][0].rid == 1
    sjf = make_prefill("sjf")
    assert sjf.select([a, b], t_now=0.0, mu=1e4, budget=16)[0][0].rid == 0

    assert srpt.select([], 0.0, 1e4, 64) == []


def test_cache_aware_prefers_cached_prefix_and_degrades_to_urgency():
    ca = make_prefill("cache-aware")
    assert ca.name == "cache-aware"
    # identical requests except one's head is already cached: fewer
    # remaining prefill tokens -> better score -> scheduled first
    cold = _queue_req(0, input_len=20, output_len=4, cached=0)
    warm = _queue_req(1, input_len=20, output_len=4, cached=16)
    assert ca.select([cold, warm], t_now=0.0, mu=1e4, budget=8)[0][0].rid == 1

    # with no cache hits anywhere the ordering IS kairos-urgency's
    ka = make_prefill("kairos-urgency")
    queue = [
        _queue_req(i, input_len=4 + 3 * i, output_len=4, ttft=5.0 + i)
        for i in range(5)
    ]
    pick_ca = [r.rid for r, _ in ca.select(queue, t_now=0.0, mu=1e4, budget=64)]
    pick_ka = [r.rid for r, _ in ka.select(queue, t_now=0.0, mu=1e4, budget=64)]
    assert pick_ca == pick_ka

    assert ca.select([], 0.0, 1e4, 64) == []
