"""Sharding rules (divisibility fallback) + checkpoint/restart fault tolerance
+ training substrate invariants."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_SHAPES, get_config, input_specs
from repro.dist.sharding import ShardingPlan, cache_pspecs, input_pspecs, param_pspecs
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, SyntheticDataset
from repro.training.optimizer import (
    OptimizerConfig,
    adamw_update,
    init_opt_state,
    wsd_schedule,
)
from repro.training.train_step import make_train_step


def _host_mesh():
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))


def _leaf_specs(tree):
    return jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, P))


def test_param_pspecs_respect_divisibility():
    mesh = _host_mesh()  # model axis size 1 divides everything
    cfg = get_config("llama3-8b")
    model = build_model(cfg)
    struct = model.param_struct()
    specs = param_pspecs(cfg, struct, ShardingPlan(mesh))
    # every leaf gets a spec of matching rank
    flat_s, _ = jax.tree_util.tree_flatten(struct)
    flat_p = _leaf_specs(specs)
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_s, flat_p, strict=True):
        assert len(spec) <= len(leaf.shape)


def test_fallback_logged_for_indivisible_dims():
    # a 16-way model axis cannot shard minicpm's 122,753 vocab
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = ShardingPlan(mesh)
    used = set()
    # simulate a 16-way axis via a fake mesh: use pick() directly on real mesh
    # with a non-divisible dim
    got = plan.pick(122753, ["model"], used, "embed.vocab")
    # model axis size 1 divides everything, so no fallback here; exercise the
    # logging path with an impossible candidate
    got2 = plan.pick(7, [("data", "model")], set(), "odd") if mesh.size > 1 else None
    assert got == "model"


def test_cache_pspecs_cover_all_families():
    mesh = _host_mesh()
    for arch in ["llama3-8b", "mamba2-130m", "zamba2-2.7b", "seamless-m4t-medium"]:
        cfg = get_config(arch)
        specs = input_specs(cfg, ALL_SHAPES["decode_32k"])
        pspecs = cache_pspecs(cfg, specs["cache"], ShardingPlan(mesh))
        assert set(jax.tree_util.tree_structure(pspecs).node_data()[1]) == set(
            jax.tree_util.tree_structure(specs["cache"]).node_data()[1]
        )


def test_input_pspecs_batch_rule():
    mesh = _host_mesh()
    cfg = get_config("llama3-8b")
    specs = input_specs(cfg, ALL_SHAPES["train_4k"])
    pspecs = input_pspecs(cfg, specs, ShardingPlan(mesh))
    for s in _leaf_specs(pspecs):
        assert isinstance(s, P)


# ------------------------------------------------------------- checkpointing

def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2)
    tree = {
        "a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.float32), "step": jnp.int32(7)},
    }
    for step in [1, 2, 3]:
        ck.save(step, tree)
    assert ck.latest_step() == 3
    restored, step = ck.restore(tree)
    assert step == 3
    np.testing.assert_array_equal(
        np.asarray(restored["a"], np.float32), np.asarray(tree["a"], np.float32)
    )
    assert restored["a"].dtype == jnp.bfloat16
    # gc kept only the last 2
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_2", "step_3"]


def test_checkpoint_ignores_incomplete_writes(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((3,))}
    ck.save(5, tree)
    # a crashed writer leaves a .tmp dir and possibly a bogus LATEST
    os.makedirs(tmp_path / "step_9.tmp")
    with open(tmp_path / "LATEST", "w") as f:
        f.write("9")
    assert ck.latest_step() == 5  # falls back to newest complete checkpoint
    _, step = ck.restore(tree)
    assert step == 5


def test_training_resume_is_bit_deterministic(tmp_path):
    """Kill/restart mid-training: resumed run must match the uninterrupted
    one exactly (data pipeline is a pure function of step)."""
    cfg = get_config("minicpm-2b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    opt_cfg = OptimizerConfig(warmup_steps=2)
    ds = SyntheticDataset(cfg, DataConfig(seq_len=32, global_batch=4))
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    def run(params, opt, start, n):
        for i in range(start, start + n):
            batch = jax.tree.map(jnp.asarray, ds.batch_at(i))
            params, opt, m = step_fn(params, opt, batch)
        return params, opt, m

    p0 = model.init(jax.random.key(0))
    o0 = init_opt_state(p0)
    # uninterrupted 6 steps
    pA, oA, mA = run(p0, o0, 0, 6)
    # 3 steps, checkpoint, restart, 3 more
    pB, oB, _ = run(p0, o0, 0, 3)
    ck = CheckpointManager(str(tmp_path))
    ck.save(3, {"params": pB, "opt": oB})
    restored, step = ck.restore({"params": pB, "opt": oB})
    pC, oC, mC = run(restored["params"], restored["opt"], step, 3)
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pC), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(mA["loss"]) == pytest.approx(float(mC["loss"]), abs=0)


# ----------------------------------------------------------------- optimizer

def test_wsd_schedule_phases():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, stable_steps=20, decay_steps=10, min_lr_frac=0.1)
    lrs = [float(wsd_schedule(cfg, jnp.int32(s))) for s in [0, 5, 10, 25, 35, 45]]
    assert lrs[0] < lrs[1] < cfg.lr  # warmup
    assert lrs[2] == pytest.approx(cfg.lr)
    assert lrs[3] == pytest.approx(cfg.lr)  # stable
    assert lrs[4] < cfg.lr  # decaying
    assert lrs[5] == pytest.approx(cfg.lr * cfg.min_lr_frac, rel=1e-5)


def test_grad_clipping_bounds_update():
    cfg = OptimizerConfig(grad_clip=1.0, lr=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    opt = init_opt_state(params)
    grads = {"w": jnp.full((4,), 100.0)}
    p2, opt2, m = adamw_update(cfg, params, grads, opt)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    assert np.all(np.isfinite(np.asarray(p2["w"])))


def test_microbatch_grad_accum_matches_full_batch():
    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    ds = SyntheticDataset(cfg, DataConfig(seq_len=16, global_batch=8))
    batch = jax.tree.map(jnp.asarray, ds.batch_at(0))
    from repro.training.train_step import loss_and_grad_accum

    params = model.init(jax.random.key(0))
    l1, g1 = loss_and_grad_accum(model, params, batch, n_micro=1)
    l4, g4 = loss_and_grad_accum(model, params, batch, n_micro=4)
    # per-microbatch token counts are equal here, so means match
    assert float(l1) == pytest.approx(float(l4), rel=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4), strict=True):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-4, atol=2e-5
        )
