"""Event-level parity across backends (the tracing mirror of the serving
bit-parity contracts): the same request served by different substrates must
tell the same lifecycle story in the shared repro.obs schema.

  * sim vs engine: identical per-request event-TYPE sequences (timestamps
    live in different time bases — cost-model virtual seconds vs ManualClock
    reads — so only the shape is comparable);
  * async-engine vs 1-replica router, and 1-replica router vs 1P:1D
    never-deflection disagg: identical per-request (type, timestamp)
    sequences, exact floats — these pairs share one clock discipline, so
    the event streams inherit the serving layer's bit-parity.

Backend-tag events (ROUTE, DEFLECT) are excluded: they narrate where a
backend-specific layer placed work, not the request's lifecycle.
"""
import asyncio
import copy

import numpy as np
import pytest

from repro.core.request import Phase, Request, SLOSpec
from repro.obs import EventType, TraceRecorder

_BACKEND_TAGS = {EventType.ROUTE, EventType.DEFLECT}


def _signature(events, with_times=True):
    """(per-rid lifecycle sequences, scheduler DECODE_STEP count), tags
    excluded. ``with_times=False`` compares shape only (cross-time-base)."""
    per, steps = {}, 0
    for e in events:
        if e.type in _BACKEND_TAGS:
            continue
        if e.rid < 0:
            steps += 1
            continue
        item = (e.type.value, e.t) if with_times else e.type.value
        per.setdefault(e.rid, []).append(item)
    return per, steps


@pytest.fixture(scope="module")
def tiny_model():
    import jax

    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _server(tiny_model, clock=None, trace=None):
    from repro.serving.clock import ManualClock
    from repro.serving.engine import DisaggServer, EngineConfig

    cfg, model, params = tiny_model
    return DisaggServer(
        model, params, EngineConfig(max_slots=4, max_len=64, chunk_size=16),
        clock=clock or ManualClock(auto_step=1e-4), trace=trace,
    )


def _requests(cfg, n=5, max_out=4, seed=2, arrival_gap=0.01):
    rng = np.random.default_rng(seed)
    pairs = []
    for i in range(n):
        length = int(rng.integers(4, 14))
        prompt = list(map(int, rng.integers(2, cfg.vocab_size, length)))
        pairs.append((
            Request(rid=i, arrival=i * arrival_gap, input_len=length,
                    output_len=max_out, slo=SLOSpec(ttft=120.0, tpot=10.0)),
            prompt,
        ))
    return pairs


def test_sim_and_engine_tell_the_same_lifecycle(tiny_model):
    """One request, prompt within a single prefill chunk: the simulator and
    the live engine emit the identical event-type sequence — submit, admit,
    one prefill slice, the END->QUEUED->START handoff burst with the first
    token, attach, then per-step tokens and done."""
    from repro.serving.session import ServeSession
    from repro.sim.simulator import DisaggSimulator

    cfg = tiny_model[0]
    tr_engine = TraceRecorder()
    sess = ServeSession(_server(tiny_model, trace=tr_engine))
    prompt = list(map(int, np.random.default_rng(0).integers(2, cfg.vocab_size, 8)))
    req = Request(rid=0, arrival=0.0, input_len=8, output_len=3,
                  slo=SLOSpec(ttft=120.0, tpot=10.0))
    sess.run([(req, prompt)])
    assert req.phase == Phase.DONE

    tr_sim = TraceRecorder()
    sim = DisaggSimulator(trace=tr_sim)
    twin = Request(rid=0, arrival=0.0, input_len=8, output_len=3,
                   slo=SLOSpec(ttft=120.0, tpot=10.0))
    sim.run([twin])
    assert twin.phase == Phase.DONE

    sig_e, steps_e = _signature(tr_engine.events, with_times=False)
    sig_s, steps_s = _signature(tr_sim.events, with_times=False)
    assert sig_e == sig_s
    # the first token rides the prefill-finish burst, so output_len=3 takes
    # exactly two decode steps — on both substrates
    assert steps_e == steps_s == 2


def test_one_replica_router_events_match_async_engine(tiny_model):
    from repro.serving.frontend import AsyncServeSession
    from repro.serving.router import RouterSession

    cfg = tiny_model[0]
    pairs_a = _requests(cfg)
    pairs_r = copy.deepcopy(pairs_a)

    async def run_async():
        tr = TraceRecorder()
        frontend = AsyncServeSession(_server(tiny_model), trace=tr)
        async with frontend:
            await frontend.replay(pairs_a, clients=3)
        return tr

    async def run_router():
        tr = TraceRecorder()
        router = RouterSession([_server(tiny_model)], policy="round-robin",
                               trace=tr)
        async with router:
            await router.replay(pairs_r, clients=3)
        return tr

    tr_a = asyncio.run(run_async())
    tr_r = asyncio.run(run_router())
    # the router timeline carries one extra ROUTE tag per request, nothing else
    assert sum(e.type is EventType.ROUTE for e in tr_r.events) == len(pairs_r)
    sig_a, steps_a = _signature(tr_a.events)
    sig_r, steps_r = _signature(tr_r.events)
    assert sig_a == sig_r  # exact (type, timestamp) floats, per request
    assert steps_a == steps_r


def test_disagg_1p1d_never_deflection_events_match_router(tiny_model):
    from repro.serving.clock import ManualClock
    from repro.serving.disagg import DisaggFleetSession
    from repro.serving.engine import DisaggServer, EngineConfig

    cfg, model, params = tiny_model
    pairs_r = _requests(cfg)
    pairs_d = copy.deepcopy(pairs_r)

    async def run_router():
        from repro.serving.router import RouterSession

        tr = TraceRecorder()
        router = RouterSession([_server(tiny_model)], policy="round-robin",
                               trace=tr)
        async with router:
            await router.replay(pairs_r, clients=3)
        return tr

    async def run_disagg():
        tr = TraceRecorder()
        clock = ManualClock(auto_step=1e-4)
        ecfg = EngineConfig(max_slots=4, max_len=64, chunk_size=16)
        mk = lambda: DisaggServer(model, params, ecfg, clock=clock)
        fleet = DisaggFleetSession([mk()], [mk()], deflection="never", trace=tr)
        async with fleet:
            await fleet.replay(pairs_d, clients=3)
        return tr

    tr_r = asyncio.run(run_router())
    tr_d = asyncio.run(run_disagg())
    sig_r, steps_r = _signature(tr_r.events)
    sig_d, steps_d = _signature(tr_d.events)
    assert sig_r == sig_d  # exact (type, timestamp) floats, per request
    assert steps_r == steps_d
    # the two timelines differ only in backend tags and pool labels
    pools_d = {e.pool for e in tr_d.events}
    assert {"prefill:0", "decode:0"} <= pools_d
