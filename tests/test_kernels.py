"""Pallas kernel correctness: shape/dtype sweeps vs pure-jnp oracles
(interpret mode on CPU; same code path compiles for TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.prefill_attention.ops import prefill_attention
from repro.kernels.prefill_attention.ref import prefill_attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.models.ssm import ssd_chunked

_TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize(
    "b,sq,skv,hq,hkv,dh",
    [
        (2, 128, 256, 4, 2, 64),
        (1, 64, 64, 8, 8, 128),  # MHA
        (2, 100, 300, 6, 2, 32),  # unaligned seq
        (1, 256, 512, 4, 1, 128),  # MQA
        (3, 32, 160, 4, 2, 16),  # tiny head dim
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefill_attention_kernel(b, sq, skv, hq, hkv, dh, dtype, rng):
    q = jnp.asarray(rng.standard_normal((b, sq, hq, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((b, skv, hkv, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((b, skv, hkv, dh)), dtype)
    offs = rng.integers(0, skv - sq + 1, size=b)
    q_pos = jnp.asarray(offs[:, None] + np.arange(sq)[None, :], jnp.int32)
    kv_len = jnp.asarray(offs + sq, jnp.int32)
    out = prefill_attention(q, k, v, q_pos, kv_len, block_q=64, block_k=64)
    ref = prefill_attention_ref(q, k, v, q_pos, kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_TOL[dtype]
    )


def test_prefill_attention_logit_cap(rng):
    b, sq, skv, hq, hkv, dh = 1, 64, 128, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((b, sq, hq, dh)) * 3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, skv, hkv, dh)) * 3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, skv, hkv, dh)), jnp.float32)
    q_pos = jnp.asarray(np.arange(sq)[None, :] + 64, jnp.int32)
    kv_len = jnp.asarray([128], jnp.int32)
    out = prefill_attention(q, k, v, q_pos, kv_len, logit_cap=30.0, block_q=64, block_k=64)
    ref = prefill_attention_ref(q, k, v, q_pos, kv_len, logit_cap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize(
    "b,s,hq,hkv,dh",
    [
        (4, 512, 8, 2, 64),
        (2, 300, 4, 4, 128),
        (1, 1024, 16, 2, 32),
        (3, 96, 8, 1, 128),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_kernel(b, s, hq, hkv, dh, dtype, rng):
    q = jnp.asarray(rng.standard_normal((b, hq, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), dtype)
    kv_len = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
    out = decode_attention(q, k, v, kv_len, block_k=128)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_TOL[dtype]
    )


def test_decode_attention_ignores_stale_cache_tail(rng):
    """Entries past kv_len must not leak into the output."""
    b, s, hq, hkv, dh = 2, 256, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((b, hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), jnp.float32)
    kv_len = jnp.asarray([100, 17], jnp.int32)
    out1 = decode_attention(q, k, v, kv_len)
    k2 = k.at[:, 200:].set(1e4)
    v2 = v.at[:, 200:].set(-1e4)
    out2 = decode_attention(q, k2, v2, kv_len)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


@pytest.mark.parametrize(
    "b,l,h,p,n,chunk",
    [
        (2, 256, 4, 64, 32, 64),
        (1, 100, 2, 32, 16, 32),
        (2, 128, 8, 16, 64, 128),
    ],
)
def test_ssd_scan_kernel(b, l, h, p, n, chunk, rng):
    x = jnp.asarray(rng.standard_normal((b, l, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, (h,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, l, n)) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, l, n)) * 0.3, jnp.float32)
    y, fs = ssd(x, dt, A, Bm, Cm, chunk=chunk)
    yr, fsr = ssd_chunked(x, dt, A, Bm[:, :, None, :], Cm[:, :, None, :], chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fsr), rtol=1e-4, atol=1e-4)


def test_ssd_kernel_state_feeds_decode(rng):
    """Kernel final state must continue correctly through the recurrence."""
    b, l, h, p, n = 1, 64, 2, 16, 8
    x = jnp.asarray(rng.standard_normal((b, l + 1, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, l + 1, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, (h,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, l + 1, n)) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, l + 1, n)) * 0.3, jnp.float32)
    # full scan over l+1
    y_all, _ = ssd(x, dt, A, Bm, Cm, chunk=32)
    # scan over l, then one recurrent step with the kernel's final state
    _, fs = ssd(x[:, :l], dt[:, :l], A, Bm[:, :l], Cm[:, :l], chunk=32)
    dA = jnp.exp(dt[:, l] * A)  # (b, h)
    inc = jnp.einsum("bhp,bn->bhpn", x[:, l].astype(jnp.float32) * dt[:, l][..., None], Bm[:, l])
    state = fs * dA[..., None, None] + inc
    y_step = jnp.einsum("bn,bhpn->bhp", Cm[:, l], state)
    np.testing.assert_allclose(
        np.asarray(y_step), np.asarray(y_all[:, l]), rtol=1e-4, atol=1e-4
    )
