"""Sim/engine parity over the policy registry (ISSUE 2 acceptance): one
`PolicySpec` surface drives both backends. Every registered policy name must
be accepted by `DisaggSimulator` AND `DisaggServer`, and both must emit
per-request TTFT/TPOT metrics for it."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.request import Phase, Request, SLOSpec
from repro.models import build_model
from repro.policies import PolicySpec, available_policies
from repro.serving.clock import ManualClock
from repro.serving.engine import DisaggServer, EngineConfig
from repro.sim.simulator import run_policy
from repro.sim.trace import TraceConfig, generate_trace


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _engine_requests(cfg, n=2, max_out=3, seed=0):
    rng = np.random.default_rng(seed)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, 6 + 2 * i)))
               for i in range(n)]
    return [
        (
            Request(rid=i, arrival=0.0, input_len=len(p), output_len=max_out,
                    slo=SLOSpec(ttft=120.0, tpot=10.0)),
            p,
        )
        for i, p in enumerate(prompts)
    ]


def _combos():
    pol = available_policies()
    combos = [(p, "kairos-slack") for p in pol["prefill"]]
    combos += [("kairos-urgency", d) for d in pol["decode"]]
    return combos


def test_simulator_accepts_every_registered_policy_with_metrics():
    reqs = generate_trace(TraceConfig(n_requests=20, qps=2.0, seed=4))
    for pname, dname in _combos():
        res = run_policy(reqs, pname, dname)
        done = res.completed()
        assert len(done) == 20, (pname, dname)
        for r in done:
            assert r.ttft() is not None, (pname, dname)
            assert r.mean_tpot() is not None, (pname, dname)


def test_engine_accepts_every_registered_policy_with_metrics(tiny_model):
    cfg, model, params = tiny_model
    for pname, dname in _combos():
        reqs = _engine_requests(cfg)
        ecfg = EngineConfig(
            max_slots=4, max_len=64, chunk_size=16,
            prefill_policy=pname, decode_policy=dname,
        )
        server = DisaggServer(model, params, ecfg, clock=ManualClock(auto_step=1e-4))
        outs = server.serve(reqs)
        for r, _ in reqs:
            assert r.phase == Phase.DONE, (pname, dname)
            assert len(outs[r.rid]) == r.output_len, (pname, dname)
            assert r.ttft() is not None, (pname, dname)
            assert r.mean_tpot() is not None, (pname, dname)


def test_same_spec_object_drives_both_backends(tiny_model):
    """The acceptance bar verbatim: one PolicySpec (with kwargs) is consumed
    by simulator and engine without translation."""
    cfg, model, params = tiny_model
    pspec = PolicySpec("kairos-urgency-plus")
    dspec = PolicySpec("kairos-slack", {"slo_margin": 0.85})

    res = run_policy(generate_trace(TraceConfig(n_requests=10, qps=2.0, seed=9)),
                     pspec, dspec)
    assert len(res.completed()) == 10

    reqs = _engine_requests(cfg)
    server = DisaggServer(
        model, params,
        EngineConfig(max_slots=4, max_len=64, chunk_size=16,
                     prefill_policy=pspec, decode_policy=dspec),
        clock=ManualClock(auto_step=1e-4),
    )
    server.serve(reqs)
    assert server.decode_sched.slo_margin == 0.85
    assert all(r.phase == Phase.DONE for r, _ in reqs)
