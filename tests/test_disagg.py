"""P/D disaggregation: pools, KV handoff, and prefill deflection.

  * deflection policies as pure functions of the fleet view, registered
    under the fourth registry side (`never` / `short-prompt-threshold` /
    `prefill-pressure` / `slack-aware`)
  * `DisaggSession` placement: join-shortest-token-backlog with the
    least-assigned tiebreak round-robins an idle prefill pool
  * KV-handoff lifecycle: decode_start is gated by the priced transfer
    (`CostModel.transfer_time`) on BOTH the single-server session and the
    fleet; the bounded in-flight window queues handoffs under pressure
  * cancel mid-handoff reclaims everything: the queued/in-flight transfer
    entry, the prefill KV, and the reserved decode slot
  * 1P:1D under `never` deflection is bit-identical to a 1-replica router
    fleet on a `ManualClock` — disaggregating adds no clock reads
  * harness `disagg` backend cell schema + evaluate/loadgen CLI flags
  * `attainment_by_pool` groups by worker label with an `unassigned` bucket
"""
import asyncio
import copy
from dataclasses import dataclass, field
from typing import List

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.request import Phase, Request, SLOSpec
from repro.models import build_model
from repro.policies import (
    available_deflection_policies,
    available_policies,
    make_deflection,
)
from repro.serving.clock import ManualClock
from repro.serving.disagg import DisaggFleetSession, DisaggSession
from repro.serving.engine import DisaggServer, EngineConfig
from repro.serving.router import RouterSession
from repro.serving.session import ServeSession


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _server(tiny_model, clock=None, **ecfg_kw):
    cfg, model, params = tiny_model
    kw = dict(max_slots=4, max_len=64, chunk_size=16)
    kw.update(ecfg_kw)
    return DisaggServer(
        model, params, EngineConfig(**kw),
        clock=clock if clock is not None else ManualClock(auto_step=1e-4),
    )


def _fleet(tiny_model, n_prefill=1, n_decode=1, **ecfg_kw):
    """P+D servers on ONE shared ManualClock (the fleet requirement)."""
    clock = ManualClock(auto_step=1e-4)
    servers = [
        _server(tiny_model, clock=clock, **ecfg_kw)
        for _ in range(n_prefill + n_decode)
    ]
    return servers[:n_prefill], servers[n_prefill:]


def _requests(cfg, n=4, max_out=4, seed=0, arrival_gap=0.0, prompt_len=None):
    rng = np.random.default_rng(seed)
    prompts = [
        list(map(int, rng.integers(
            2, cfg.vocab_size,
            prompt_len if prompt_len else int(rng.integers(4, 14)),
        )))
        for _ in range(n)
    ]
    return [
        (
            Request(rid=i, arrival=arrival_gap * i, input_len=len(p),
                    output_len=max_out, slo=SLOSpec(ttft=120.0, tpot=10.0)),
            p,
        )
        for i, p in enumerate(prompts)
    ]


def _drain(sess, max_steps=2000):
    for _ in range(max_steps):
        if not sess.has_work:
            return
        sess.step()
    raise AssertionError("disagg fleet did not drain")


# -------------------------------------------------------- deflection policies
@dataclass
class FakeWorker:
    pending_prefill_tokens: int = 0
    queue_len: int = 0
    mu: float = 100.0
    free_slots: int = 4


@dataclass
class FakeFleet:
    prefill_pool: List[FakeWorker] = field(default_factory=list)
    decode_pool: List[FakeWorker] = field(default_factory=list)
    capacity: bool = True

    def decode_has_capacity(self):
        return self.capacity


def _req(input_len, ttft=1.0):
    return Request(rid=0, arrival=0.0, input_len=input_len, output_len=4,
                   slo=SLOSpec(ttft=ttft, tpot=10.0))


def test_deflection_registry_side():
    names = available_deflection_policies()
    assert list(names) == sorted(names)
    assert set(names) >= {
        "never", "short-prompt-threshold", "prefill-pressure", "slack-aware"
    }
    assert list(available_policies()["deflection"]) == list(names)


def test_never_deflect_always_declines():
    fleet = FakeFleet(prefill_pool=[FakeWorker(pending_prefill_tokens=10_000)],
                      decode_pool=[FakeWorker()])
    pol = make_deflection("never")
    assert pol.name == "never"
    assert pol.decide(fleet, _req(2), [1, 2]) is False


def test_short_prompt_threshold_is_load_blind():
    fleet = FakeFleet(prefill_pool=[FakeWorker()], decode_pool=[FakeWorker()])
    pol = make_deflection("short-prompt-threshold")
    # deflects short prompts even with a completely idle prefill pool...
    assert pol.decide(fleet, _req(8), [0] * 8)
    assert not pol.decide(fleet, _req(9), [0] * 9)  # ...but only short ones
    fleet.capacity = False
    assert not pol.decide(fleet, _req(8), [0] * 8)  # and never over capacity


def test_prefill_pressure_watermark_is_pool_total():
    pol = make_deflection("prefill-pressure")
    idle = FakeFleet(prefill_pool=[FakeWorker(), FakeWorker()],
                     decode_pool=[FakeWorker()])
    assert not pol.decide(idle, _req(4), [0] * 4)  # no pressure, no deflection
    # pressure is the pool TOTAL: one busy + one idle worker still trips the
    # watermark (a min-based signal would be pinned to 0 by the idle worker)
    pressured = FakeFleet(
        prefill_pool=[FakeWorker(pending_prefill_tokens=6), FakeWorker()],
        decode_pool=[FakeWorker()],
    )
    assert pol.decide(pressured, _req(4), [0] * 4)
    assert not pol.decide(pressured, _req(40), [0] * 40)  # long prompts stay
    pressured.capacity = False
    assert not pol.decide(pressured, _req(4), [0] * 4)


def test_prefill_pressure_watermark_override():
    from repro.policies import PolicySpec

    pol = make_deflection(PolicySpec("prefill-pressure",
                                     {"watermark_tokens": 100}))
    fleet = FakeFleet(prefill_pool=[FakeWorker(pending_prefill_tokens=99)],
                      decode_pool=[FakeWorker()])
    assert not pol.decide(fleet, _req(4), [0] * 4)
    fleet.prefill_pool[0].pending_prefill_tokens = 100
    assert pol.decide(fleet, _req(4), [0] * 4)


def test_slack_aware_deflects_only_when_decode_wins():
    pol = make_deflection("slack-aware")
    # prefill pool clears the prompt well inside the TTFT budget: stay
    fast = FakeFleet(prefill_pool=[FakeWorker(mu=1000.0)],
                     decode_pool=[FakeWorker(mu=1000.0)])
    assert not pol.decide(fast, _req(4, ttft=1.0), [0] * 4)
    # prefill pool blows the budget and the decode pool beats its ETA: go
    slow = FakeFleet(
        prefill_pool=[FakeWorker(pending_prefill_tokens=500, mu=100.0)],
        decode_pool=[FakeWorker(mu=100.0)],
    )
    assert pol.decide(slow, _req(4, ttft=1.0), [0] * 4)
    # decode pool just as backed up: deflecting buys nothing
    slow.decode_pool[0].pending_prefill_tokens = 500
    assert not pol.decide(slow, _req(4, ttft=1.0), [0] * 4)


# ------------------------------------------------------- fleet construction
def test_fleet_requires_one_shared_clock(tiny_model):
    with pytest.raises(ValueError, match="share one Clock"):
        DisaggSession([_server(tiny_model)], [_server(tiny_model)])


def test_fleet_requires_both_pools(tiny_model):
    prefill, _ = _fleet(tiny_model, 1, 1)
    with pytest.raises(ValueError, match="prefill and >= 1 decode"):
        DisaggSession(prefill, [])


def test_prefill_placement_round_robins_an_idle_pool(tiny_model):
    prefill, decode = _fleet(tiny_model, 2, 1)
    sess = DisaggSession(prefill, decode)
    for r, p in _requests(tiny_model[0], n=4, prompt_len=6):
        sess.submit(r, p)
    labels = sess.pool_labels()["prefill"]
    # equal-length prompts: backlog/queue keys tie, the assigned tiebreak
    # alternates instead of pinning everything to prefill:0
    assert sorted(labels.values()) == ["prefill:0", "prefill:0",
                                      "prefill:1", "prefill:1"]
    assert [w.assigned for w in sess.prefill_pool] == [2, 2]


# ------------------------------------------------------------ KV handoff
def test_decode_start_gated_by_transfer_time_single_server(tiny_model):
    """Satellite unification: the single-server session prices its
    prefill->decode admission with the SAME CostModel.transfer_time the
    fleet uses for cross-server handoff."""
    srv = _server(tiny_model, transfer_lat=0.05)
    sess = ServeSession(srv)
    pairs = _requests(tiny_model[0], n=2)
    for r, p in pairs:
        sess.submit(r, p)
    for _ in range(2000):
        if not sess.has_work:
            break
        sess.step()
    for r, _ in pairs:
        assert r.phase == Phase.DONE
        gap = r.decode_start - r.prefill_finish
        assert gap >= srv.cost.transfer_time(r.input_len)


def test_decode_start_gated_by_transfer_time_fleet(tiny_model):
    prefill, decode = _fleet(tiny_model, 1, 1, transfer_lat=0.05)
    sess = DisaggSession(prefill, decode)
    pairs = _requests(tiny_model[0], n=2)
    for r, p in pairs:
        sess.submit(r, p)
    _drain(sess)
    cost = prefill[0].cost
    for r, _ in pairs:
        assert r.phase == Phase.DONE
        assert r.decode_start - r.prefill_finish >= cost.transfer_time(r.input_len)
    h = sess.handoff_summary()
    assert h["transfers_completed"] == 2
    assert h["cross_transfers"] == 2 and h["local_transfers"] == 0
    assert h["bytes_transferred"] == pytest.approx(
        sum(r.input_len for r, _ in pairs) * prefill[0].ecfg.kv_bytes_per_token
    )


def test_cancel_mid_handoff_reclaims_everything(tiny_model):
    """A cancel landing while the KV is on the wire must reclaim the
    transfer-window entry, the prefill cache, AND the decode slot that was
    reserved at transfer start — no leaked slots in either pool."""
    prefill, decode = _fleet(tiny_model, 1, 1, transfer_lat=0.5)
    sess = DisaggSession(prefill, decode)
    (r, p), = _requests(tiny_model[0], n=1)
    sess.submit(r, p)
    sess.step()  # prefill completes; the 0.5s transfer is now in flight
    assert r.phase == Phase.TRANSFER
    assert len(sess.inflight) == 1
    tr = sess.inflight[0]
    assert len(tr.dst.server.decode.alloc.free) == 3  # slot reserved
    assert sess.cancel(r.rid)
    assert r.phase == Phase.CANCELLED
    assert not sess.inflight and not sess.pending_handoff
    assert tr.lr.prefill_cache is None
    assert len(tr.dst.server.decode.alloc.free) == 4  # slot reclaimed
    assert sess.handoff.transfers_cancelled == 1
    assert not sess.has_work
    assert sess.metrics.cancelled == 1 and r.rid in sess.metrics.cancelled_rids


def test_cancel_queued_handoff_reclaims_entry(tiny_model):
    """Same contract one stage earlier: a cancel while the handoff is still
    queued (window full) drops the queue entry; no decode slot was reserved
    yet, so the decode pool is untouched."""
    prefill, decode = _fleet(tiny_model, 1, 1, transfer_lat=0.5)
    sess = DisaggSession(prefill, decode, max_inflight_transfers=1)
    pairs = _requests(tiny_model[0], n=2)
    for r, p in pairs:
        sess.submit(r, p)
    for _ in range(10):  # prefills finish; window of 1 -> second handoff queues
        sess.step()
        if sess.pending_handoff:
            break
    assert len(sess.inflight) == 1 and len(sess.pending_handoff) == 1
    queued = sess.pending_handoff[0].lr.req
    assert sess.cancel(queued.rid)
    assert queued.phase == Phase.CANCELLED
    assert not sess.pending_handoff
    assert sess.handoff.transfers_cancelled == 1
    assert len(decode[0].decode.alloc.free) == 3  # only the in-flight slot


def test_bounded_inflight_window_queues_handoffs(tiny_model):
    prefill, decode = _fleet(tiny_model, 1, 1, transfer_lat=0.01)
    sess = DisaggSession(prefill, decode, max_inflight_transfers=1)
    pairs = _requests(tiny_model[0], n=3)
    for r, p in pairs:
        sess.submit(r, p)
    _drain(sess)
    h = sess.handoff_summary()
    assert all(r.phase == Phase.DONE for r, _ in pairs)
    assert h["transfers_completed"] == 3
    assert h["inflight_peak"] == 1  # the window bound held
    assert h["queued_peak"] >= 1  # and handoffs actually queued behind it
    assert h["queue_wait_total"] > 0.0
    assert h["queue_wait_max"] > 0.0


def test_deflected_prefill_stays_local(tiny_model):
    """`short-prompt-threshold` sends every short prompt to the decode pool:
    its prefill runs there and the handoff never crosses servers."""
    prefill, decode = _fleet(tiny_model, 1, 1)
    sess = DisaggSession(prefill, decode, deflection="short-prompt-threshold")
    pairs = _requests(tiny_model[0], n=4, prompt_len=6)  # all <= 8 tokens
    for r, p in pairs:
        sess.submit(r, p)
    _drain(sess)
    d = sess.deflection_summary()
    assert d["policy"] == "short-prompt-threshold"
    assert d["deflected"] == 4 and d["by_dst"] == {"decode:0": 4}
    h = sess.handoff_summary()
    assert h["local_transfers"] == 4 and h["cross_transfers"] == 0
    labels = sess.pool_labels()
    assert all(v == "decode:0" for v in labels["prefill"].values())
    assert all(r.phase == Phase.DONE for r, _ in pairs)


# ------------------------------------------------------------- bit-parity
def test_1p1d_never_deflection_is_bit_identical_to_router(tiny_model):
    """The disaggregation determinism contract: a 1P:1D fleet under `never`
    deflection replays bit-for-bit against a 1-replica router fleet on a
    ManualClock — splitting prefill from decode adds no clock reads, and
    the handoff prices exactly the admission gate the single server runs."""
    pairs_router = _requests(tiny_model[0], n=5, max_out=4, seed=2,
                             arrival_gap=0.01)
    pairs_disagg = copy.deepcopy(pairs_router)

    async def run_router():
        router = RouterSession([_server(tiny_model)], policy="round-robin")
        async with router:
            return await router.replay(pairs_router, clients=3)

    async def run_disagg():
        prefill, decode = _fleet(tiny_model, 1, 1)
        fleet = DisaggFleetSession(prefill, decode, deflection="never")
        async with fleet:
            return await fleet.replay(pairs_disagg, clients=3)

    outs_router = asyncio.run(run_router())
    outs_disagg = asyncio.run(run_disagg())
    assert outs_router == outs_disagg
    for (rr, _), (rd, _) in zip(pairs_router, pairs_disagg, strict=True):
        assert rr.phase == rd.phase == Phase.DONE
        # exact equality: same virtual clock reads in the same order
        assert rr.ttft() == rd.ttft()
        assert rr.mean_tpot() == rd.mean_tpot()
        assert rr.token_times == rd.token_times


def test_harness_disagg_1p1d_matches_router_report():
    """The same parity at the report level: the disagg cell with a 1:1
    split and `never` deflection carries exactly the 1-replica router
    cell's attainment and goodput."""
    from repro.workloads.harness import HarnessConfig, evaluate_cell

    hcfg = HarnessConfig(n_requests=10, router_replicas=1,
                         router_policy="round-robin",
                         disagg_prefill=1, disagg_decode=1,
                         deflect_policy="never")
    router_cell = evaluate_cell("multi-tenant", "kairos-urgency",
                                "kairos-slack", "router", hcfg=hcfg)
    disagg_cell = evaluate_cell("multi-tenant", "kairos-urgency",
                                "kairos-slack", "disagg", hcfg=hcfg)
    assert disagg_cell["backend"] == "disagg"
    assert disagg_cell["attainment"] == router_cell["attainment"]
    assert disagg_cell["per_tenant"] == router_cell["per_tenant"]
    assert disagg_cell["goodput"] == router_cell["goodput"]
    block = disagg_cell["disagg"]
    assert block["pools"] == dict(prefill=1, decode=1)
    assert block["deflect"] == "never"
    assert block["deflection"]["deflected"] == 0
    assert block["handoff"]["transfers_completed"] == disagg_cell["n_completed"]
    assert set(block["attainment_by_prefill_pool"]) == {"prefill:0"}
    assert set(block["attainment_by_decode_pool"]) == {"decode:0"}


# -------------------------------------------------------- metrics / report
def test_attainment_by_pool_groups_and_unassigned():
    from repro.sim.metrics import attainment_by_pool

    def req(rid, phase):
        r = Request(rid=rid, arrival=0.0, input_len=4, output_len=2,
                    slo=SLOSpec(ttft=1.0, tpot=1.0))
        r.phase = phase
        if phase == Phase.DONE:
            r.prefill_finish = 0.1
            r.first_token_time = 0.1
            r.n_generated = 2
            r.token_times = [0.1, 0.2]
            r.done_time = 0.2
        return r

    reqs = [req(0, Phase.DONE), req(1, Phase.DONE), req(2, Phase.FAILED)]
    out = attainment_by_pool(reqs, {0: "prefill:0", 1: "prefill:1"})
    assert set(out) == {"prefill:0", "prefill:1", "unassigned"}
    assert out["prefill:0"].n == 1 and out["prefill:0"].ttft == 1.0
    assert out["unassigned"].n_shed == 1


# ------------------------------------------------------------------- CLIs
def test_parse_pools():
    from repro.workloads.harness import parse_pools

    assert parse_pools("2:2") == (2, 2)
    assert parse_pools("1:3") == (1, 3)
    with pytest.raises(ValueError):
        parse_pools("2")
    with pytest.raises(ValueError):
        parse_pools("0:2")
    with pytest.raises(ValueError):
        parse_pools("a:b")


def test_evaluate_cli_rejects_bad_pools():
    from repro.launch.evaluate import build_parser

    with pytest.raises(SystemExit):
        build_parser().parse_args(["--pools", "nope"])


def test_evaluate_cli_disagg_flags_parse():
    from repro.launch.evaluate import build_parser

    args = build_parser().parse_args(
        ["--backend", "disagg", "--pools", "3:1", "--deflect",
         "prefill-pressure", "--transfer-lat", "0.01", "--transfer-bw", "1e9"]
    )
    assert args.pools == (3, 1)
    assert args.deflect == "prefill-pressure"
    assert args.transfer_lat == 0.01 and args.transfer_bw == 1e9


def test_evaluate_list_policies_includes_deflection(capsys):
    from repro.launch.evaluate import main

    main(["--list-policies"])
    out = capsys.readouterr().out
    assert "deflection:" in out
    assert "prefill-pressure" in out


def test_loadgen_cli_disagg_flags_parse():
    from repro.launch.loadgen import build_parser

    args = build_parser().parse_args(["--pools", "1:1", "--deflect", "slack-aware"])
    assert args.pools == (1, 1)
    assert args.deflect == "slack-aware"


def test_loadgen_cli_pools_excludes_router():
    from repro.launch.loadgen import main

    with pytest.raises(SystemExit):
        main(["--pools", "1:1", "--servers", "2", "--n", "2"])
