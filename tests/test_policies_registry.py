"""Registry unit tests: one policy surface shared by simulator and engine."""
import pytest

from repro.core.lut import StepTimeLUT
from repro.policies import (
    PolicySpec,
    SlackDecodeScheduler,
    available_autoscaler_policies,
    available_decode_policies,
    available_deflection_policies,
    available_policies,
    available_prefill_policies,
    available_router_policies,
    make_autoscaler,
    make_decode,
    make_prefill,
    register_prefill,
)
from repro.sim.simulator import DisaggSimulator


def _lut():
    return StepTimeLUT(analytic=lambda b, s: 0.005 + 0.0002 * b + 2.4e-7 * s)


def test_available_policies_enumerates_every_side():
    pol = available_policies()
    assert set(pol) == {"prefill", "decode", "router", "deflection", "autoscaler"}
    assert set(pol["prefill"]) == {
        "kairos-urgency", "kairos-urgency-plus", "fcfs", "sjf", "edf",
        "srpt", "cache-aware",
    }
    assert set(pol["decode"]) == {"kairos-slack", "kairos-slack-greedy", "continuous"}
    assert set(pol["router"]) == {
        "round-robin", "least-queued", "slack-aware", "prefix-affinity",
    }
    assert set(pol["deflection"]) == {
        "never", "short-prompt-threshold", "prefill-pressure", "slack-aware",
    }
    assert set(pol["autoscaler"]) == {
        "static", "queue-threshold", "slo-attainment-pid",
    }
    assert pol["prefill"] == available_prefill_policies()
    assert pol["decode"] == available_decode_policies()
    assert pol["router"] == available_router_policies()
    assert pol["deflection"] == available_deflection_policies()
    assert pol["autoscaler"] == available_autoscaler_policies()


def test_autoscaler_side_constructs_and_decides():
    # every registered autoscaler builds by name and returns a clampable
    # target from empty telemetry (the controller's first-tick input)
    empty = dict(window=0.5, n_windows=0, windows=[])
    for name in available_autoscaler_policies():
        pol = make_autoscaler(name)
        assert pol.name == name
        assert pol.decide(empty, 2, 1, 4) == 2  # no evidence -> hold
    qt = make_autoscaler(PolicySpec("queue-threshold", {"high": 2}))
    spike = dict(window=0.5, n_windows=1, windows=[
        dict(queue_depth_max=3, queue_depth_last=3, done=0, shed=0, e2e=0.0)
    ])
    assert qt.decide(spike, 1, 1, 4) == 2


def test_unknown_name_raises_with_known_names():
    with pytest.raises(ValueError) as ei:
        make_prefill("no-such-policy")
    msg = str(ei.value)
    for name in available_prefill_policies():
        assert name in msg
    with pytest.raises(ValueError) as ei:
        make_decode("no-such-policy", _lut())
    msg = str(ei.value)
    for name in available_decode_policies():
        assert name in msg


def test_spec_kwargs_roundtrip():
    spec = PolicySpec("kairos-slack", {"slo_margin": 0.8, "actionable_slack": False})
    sched = make_decode(spec, _lut())
    assert isinstance(sched, SlackDecodeScheduler)
    assert sched.slo_margin == 0.8
    assert sched.actionable_slack is False
    # a bare string coerces to a kwargs-free spec
    assert PolicySpec.coerce("fcfs") == PolicySpec("fcfs")
    assert PolicySpec.coerce(spec) is spec


def test_explicit_unknown_kwarg_is_strict():
    with pytest.raises(ValueError, match="does not accept"):
        make_decode(PolicySpec("continuous", {"slo_margin": 0.5}), _lut())


def test_soft_defaults_dropped_when_not_accepted():
    # the engine forwards its config-level slo_margin to every decode policy;
    # policies that do not take it must not explode
    sched = make_decode("continuous", _lut(), slo_margin=0.7)
    assert sched.name == "continuous"
    sched2 = make_decode("kairos-slack", _lut(), slo_margin=0.7)
    assert sched2.slo_margin == 0.7
    # explicit spec kwargs beat soft defaults
    sched3 = make_decode(PolicySpec("kairos-slack", {"slo_margin": 0.95}), _lut(), slo_margin=0.7)
    assert sched3.slo_margin == 0.95


def test_variant_registration_defaults_and_name_stamp():
    sched = make_decode("kairos-slack-greedy", _lut())
    assert isinstance(sched, SlackDecodeScheduler)
    assert sched.require_throughput_gain is False
    assert sched.name == "kairos-slack-greedy"  # stamped with registered name
    base = make_decode("kairos-slack", _lut())
    assert base.require_throughput_gain is True
    assert base.name == "kairos-slack"


def test_every_registered_name_constructs_for_the_simulator():
    for pname in available_prefill_policies():
        sim = DisaggSimulator(prefill_policy=pname)
        assert sim.prefill_sched.select([], 0.0, 1e4, 64) == []
    for dname in available_decode_policies():
        sim = DisaggSimulator(decode_policy=dname)
        assert sim.decode_sched.select([], 0.0) == ([], [])


def test_register_decorator_extends_registry():
    @register_prefill("test-only-reverse")
    class ReversePolicy:
        name = "test-only-reverse"

        def select(self, queue, t_now, mu, budget):
            out = []
            for r in reversed(list(queue)):
                take = min(r.remaining_prefill_tokens, budget)
                if take > 0:
                    out.append((r, take))
                    budget -= take
            return out

    try:
        assert "test-only-reverse" in available_prefill_policies()
        sched = make_prefill("test-only-reverse")
        assert sched.select([], 0.0, 1e4, 64) == []
        # the simulator accepts it with zero extra wiring — the whole point
        DisaggSimulator(prefill_policy="test-only-reverse")
    finally:
        from repro.policies import registry

        registry._PREFILL.pop("test-only-reverse", None)
    assert "test-only-reverse" not in available_prefill_policies()
