"""System-level simulator tests: paper motivation + headline claims + fault
tolerance."""
import copy

import numpy as np
import pytest

from repro.core.request import Phase, Request, SLOSpec
from repro.sim.costmodel import PAPER_COST_MODEL, check_calibration
from repro.sim.metrics import attainment, compare, summarize
from repro.sim.simulator import (
    FaultPlan,
    SimConfig,
    run_distserve,
    run_kairos,
    run_kairos_plus,
    run_policy,
)
from repro.sim.trace import TraceConfig, generate_trace, trace_stats


def test_cost_model_matches_paper_calibration():
    for name, (pred, target) in check_calibration().items():
        assert pred == pytest.approx(target, rel=0.02), name


def test_trace_is_long_tailed():
    stats = trace_stats(generate_trace(TraceConfig(n_requests=2000, seed=3)))
    assert stats["input_p50"] < 3000
    assert stats["input_p99"] > 20 * stats["input_p50"]


def test_all_requests_complete_and_metrics_consistent():
    reqs = generate_trace(TraceConfig(n_requests=120, qps=2.0, seed=7))
    res = run_kairos(reqs)
    done = res.completed()
    assert len(done) == 120
    for r in done:
        assert r.n_generated == r.output_len
        assert r.first_token_time is not None and r.done_time is not None
        assert len(r.token_times) == r.n_generated
        assert all(b >= a for a, b in zip(r.token_times, r.token_times[1:], strict=False))
    s = summarize(res)
    for k in ("ttft", "tpot", "e2e"):
        assert 0.0 <= s[k] <= 1.0
    assert s["e2e"] <= min(s["ttft"], s["tpot"]) + 1e-9


def test_hol_blocking_motivation():
    """Paper §2.2: a 128K request ahead of shorts destroys their TTFT under
    FCFS. Faithful Kairos rescues the early (positive-slack) shorts; shorts
    whose FCFS-predicted slack flips negative fall into the Alg.1 ordering
    inversion (see DESIGN.md §5) and only urgency-plus rescues them all."""
    slo = SLOSpec(ttft=8.0, tpot=0.05)
    reqs = [Request(rid=0, arrival=0.0, input_len=131_072, output_len=16, slo=slo)]
    reqs += [
        Request(rid=i, arrival=0.05 * i, input_len=8_192, output_len=16, slo=slo)
        for i in range(1, 11)
    ]
    rd = run_distserve(reqs)
    rk = run_kairos(reqs)
    rp = run_kairos_plus(reqs)
    frac = lambda res: np.mean([r.meets_ttft() for r in res.completed() if r.rid != 0])
    assert frac(rd) == 0.0  # FCFS: every short blocked behind the 8.8 s prefill
    assert frac(rk) >= 0.4  # faithful Kairos rescues the positive-slack shorts
    assert frac(rp) == 1.0  # urgency-plus rescues all of them


def test_kairos_beats_distserve_at_moderate_load():
    reqs = generate_trace(TraceConfig(n_requests=500, qps=3.0, seed=1))
    rk, rd = run_kairos(reqs), run_distserve(reqs)
    ka, da = attainment(rk.requests), attainment(rd.requests)
    assert ka.e2e > da.e2e
    assert ka.ttft >= da.ttft
    deltas = compare(rk, rd)
    assert deltas["e2e_gain_pp"] > 5.0


def test_kairos_plus_dominates_both():
    reqs = generate_trace(TraceConfig(n_requests=400, qps=3.0, seed=1))
    rp = run_kairos_plus(reqs)
    rd = run_distserve(reqs)
    pa, da = attainment(rp.requests), attainment(rd.requests)
    assert pa.e2e > da.e2e + 0.2
    assert pa.ttft > 0.9


def test_scheduler_does_not_change_token_counts():
    """Scheduling reorders execution; every request still gets exactly its
    output tokens under every policy."""
    reqs = generate_trace(TraceConfig(n_requests=60, qps=2.0, seed=5))
    for runner in (run_kairos, run_distserve, run_kairos_plus):
        res = runner(reqs)
        for orig, r in zip(sorted(reqs, key=lambda x: x.rid),
                           sorted(res.requests, key=lambda x: x.rid), strict=True):
            assert r.n_generated == orig.output_len


def test_decode_fault_recovery():
    """Decode node dies mid-run: all requests still complete (re-prefilled),
    restarts are recorded."""
    reqs = generate_trace(TraceConfig(n_requests=80, qps=2.0, seed=11))
    plan = FaultPlan(decode_failures=(10.0,), recovery_time=3.0)
    res = run_kairos(reqs, fault_plan=plan)
    done = res.completed()
    assert len(done) == 80
    assert sum(r.restarts for r in done) > 0


def test_prefix_cache_reduces_prefill_work():
    reqs = generate_trace(TraceConfig(n_requests=100, qps=2.5, seed=2))
    base = run_kairos(reqs)
    cached = run_kairos(reqs, sim_cfg=SimConfig(prefix_cache_hit_frac=0.5))
    assert cached.prefill_busy < 0.7 * base.prefill_busy


def test_sjf_starves_long_requests():
    """Paper §3.1: SJF is impractical — long requests starve behind a steady
    stream of shorts."""
    slo = SLOSpec(ttft=8.0, tpot=0.05)
    reqs = [Request(rid=0, arrival=0.0, input_len=100_000, output_len=8, slo=slo)]
    reqs += [
        Request(rid=i, arrival=0.3 * i, input_len=6_000, output_len=8, slo=slo)
        for i in range(1, 120)
    ]
    res = run_policy(reqs, "sjf", "continuous")
    long_r = next(r for r in res.requests if r.rid == 0)
    assert not long_r.meets_ttft()
    # kairos keeps serving it with leftover budget: strictly earlier finish
    res_k = run_policy(reqs, "kairos-urgency", "continuous")
    long_k = next(r for r in res_k.requests if r.rid == 0)
    assert long_k.prefill_finish <= long_r.prefill_finish
