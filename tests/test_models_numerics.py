"""Numerical invariants: MoE dispatch vs dense oracle, SSD chunk-size
invariance, decode-vs-prefill consistency, blockwise attention exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models.layers import attention, blockwise_attention, naive_attention, attention_mask
from repro.models.moe import init_moe_params, moe_ffn, moe_ffn_ref
from repro.models.ssm import init_ssm_params, ssd_chunked, ssm_decode_step, ssm_forward


def test_moe_matches_dense_oracle_when_capacity_ample():
    cfg = get_config("phi3.5-moe-42b-a6.6b-smoke").replace(
        dtype="float32", capacity_factor=8.0, moe_group_size=16
    )
    params = init_moe_params(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (3, 10, cfg.d_model), jnp.float32)
    out = moe_ffn(x, params, cfg)
    ref = moe_ffn_ref(x, params, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-4)


def test_moe_capacity_drops_are_partial_not_corrupt():
    cfg = get_config("grok-1-314b-smoke").replace(
        dtype="float32", capacity_factor=0.5, moe_group_size=16
    )
    params = init_moe_params(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    out = moe_ffn(x, params, cfg)
    assert bool(jnp.isfinite(out).all())


def test_ssd_chunk_size_invariance():
    b, l, h, p, n = 2, 96, 4, 16, 8
    key = jax.random.key(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, l, h, p)) * 0.3
    dt = jax.random.uniform(ks[1], (b, l, h), minval=0.001, maxval=0.1)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, 1, n)) * 0.3
    C = jax.random.normal(ks[0], (b, l, 1, n)) * 0.3
    y16, s16 = ssd_chunked(x, dt, A, B, C, chunk=16)
    y32, s32 = ssd_chunked(x, dt, A, B, C, chunk=32)
    y96, s96 = ssd_chunked(x, dt, A, B, C, chunk=96)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y32), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y96), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s16), np.asarray(s32), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s16), np.asarray(s96), rtol=1e-4, atol=1e-5)


def test_ssm_decode_matches_forward_stepwise():
    cfg = get_config("mamba2-130m-smoke").replace(dtype="float32")
    params = init_ssm_params(jax.random.key(0), cfg, jnp.float32)
    b, l = 1, 12
    x = jax.random.normal(jax.random.key(1), (b, l, cfg.d_model)) * 0.3
    y_full, cache_full = ssm_forward(params, x, cfg)

    # replay the same tokens through the recurrent decode path
    W = cfg.ssm_conv_width
    from repro.models.ssm import conv_channels
    cache = dict(
        conv=jnp.zeros((b, W - 1, conv_channels(cfg)), jnp.float32),
        state=jnp.zeros((b, cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state_dim), jnp.float32),
    )
    ys = []
    for t in range(l):
        y_t, cache = ssm_decode_step(params, x[:, t : t + 1], cfg, cache)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(cache["state"]), np.asarray(cache_full["state"]), rtol=2e-4, atol=2e-4
    )


def test_blockwise_attention_matches_naive():
    b, sq, skv, hq, hkv, dh = 2, 64, 192, 4, 2, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, dh))
    k = jax.random.normal(ks[1], (b, skv, hkv, dh))
    v = jax.random.normal(ks[2], (b, skv, hkv, dh))
    q_pos = jnp.broadcast_to(jnp.arange(sq) + 100, (b, sq)).astype(jnp.int32)
    kv_valid = jnp.asarray([150, 192], jnp.int32)
    out_blk = blockwise_attention(q, k, v, q_pos, kv_valid, window=0, causal=True,
                                  logit_cap=0.0, kv_block=32)
    mask = attention_mask(q_pos, skv, kv_valid, 0, True)
    out_ref = naive_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out_blk), np.asarray(out_ref), rtol=2e-5, atol=2e-5)


def test_sliding_window_masks_old_positions():
    b, s, h, dh = 1, 32, 2, 8
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, h, dh))
    v = jax.random.normal(ks[2], (b, s, h, dh))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    out_win = attention(q, k, v, pos, window=4)
    # last query must equal attention computed over only its last 4 keys
    out_ref = attention(q[:, -1:], k[:, -4:], v[:, -4:], pos[:, -1:] - 28, causal=True)
    np.testing.assert_allclose(
        np.asarray(out_win[:, -1]), np.asarray(out_ref[:, 0]), rtol=2e-5, atol=2e-5
    )


def test_decode_step_consistent_with_prefill():
    """Greedy: prefill(prompt) last logits == decode path replaying tokens."""
    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = list(np.random.default_rng(0).integers(2, cfg.vocab_size, 9))
    logits_pf, _ = model.prefill(params, dict(inputs=jnp.asarray([prompt], jnp.int32)))

    cache = model.init_cache(1, 32)
    lg = None
    for t, tok in enumerate(prompt):
        lg, cache = model.decode(
            params, jnp.asarray([[tok]], jnp.int32), jnp.asarray([t], jnp.int32), cache
        )
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_pf), rtol=2e-4, atol=2e-4)
