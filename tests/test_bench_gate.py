"""CI bench-gate unit tests: the regression comparator and the
refresh-check staleness predicate over BENCH_workloads.json-shaped records
(no benchmarks are actually run — records are synthesized)."""
import copy

from benchmarks.check_regression import compare, materially_equal


def _record(tput=20.0, scenarios=("a", "b")):
    return dict(
        grid=dict(scenarios=list(scenarios), prefills=["p"], decodes=["d"],
                  backends=["sim"]),
        n_requests=10,
        total_wall_s=1.0,
        cells=[
            dict(scenario=s, prefill="p", decode="d", backend="sim",
                 wall_time_s=0.5, decode_tput_p50=tput, decode_tput_mean=tput + 5,
                 goodput=100.0, e2e=0.5)
            for s in scenarios
        ],
    )


def test_identical_records_pass_the_gate():
    rec = _record()
    ok, report = compare(rec, rec, max_regress=0.25)
    assert ok and "0 regression(s)" in report


def test_drop_beyond_threshold_fails_only_past_the_threshold():
    base = _record(tput=20.0)
    ok, _ = compare(base, _record(tput=16.0), max_regress=0.25)  # -20%: fine
    assert ok
    ok, report = compare(base, _record(tput=10.0), max_regress=0.25)  # -50%
    assert not ok and "REGRESSION" in report


def test_improvements_and_new_cells_never_fail():
    base = _record(tput=20.0)
    grown = _record(tput=40.0, scenarios=("a", "b", "c"))  # faster + new cell
    ok, report = compare(base, grown, max_regress=0.25)
    assert ok and "new cell" in report


def test_zero_overlap_fails_the_gate():
    ok, _ = compare(_record(scenarios=("a",)), _record(scenarios=("z",)), 0.25)
    assert not ok


def test_refresh_check_ignores_wall_time_but_not_metrics():
    rec = _record()
    wall_only = copy.deepcopy(rec)
    wall_only["cells"][0]["wall_time_s"] = 99.0
    wall_only["total_wall_s"] = 123.0
    assert materially_equal(rec, wall_only)  # no bot commit for timer noise
    moved = copy.deepcopy(rec)
    moved["cells"][0]["decode_tput_p50"] *= 1.01
    assert not materially_equal(rec, moved)
    regrown = _record(scenarios=("a", "b", "c"))
    assert not materially_equal(rec, regrown)  # grid change => refresh
