"""Evaluation harness: grid reports over both backends, engine-scale
request mapping, per-tenant quota shedding through ServeSession, and the
`launch/evaluate.py` CLI (the acceptance command, shrunk)."""
import json

import numpy as np
import pytest

from repro.core.request import Phase
from repro.launch.evaluate import main as evaluate_main
from repro.workloads import HarnessConfig, run_grid, to_engine_requests
from repro.workloads.harness import _EngineBundle, evaluate_cell

CELL_KEYS = {
    "scenario", "prefill", "decode", "backend", "wall_time_s", "n_requests",
    "n_completed", "attainment", "per_tenant", "per_class", "goodput", "shed",
    "cancelled",
}


@pytest.fixture(scope="module")
def engine_bundle():
    return _EngineBundle("llama3-8b-smoke").build()


# ------------------------------------------------------------------- sim
def test_sim_grid_runs_the_full_cartesian_product():
    rep = run_grid(
        ["paper-longtail", "heavy-head"],
        ["kairos-urgency", "fcfs"],
        ["kairos-slack"],
        ["sim"],
        HarnessConfig(n_requests=40, seed=1),
    )
    assert len(rep["cells"]) == 4
    assert rep["grid"]["scenarios"] == ["paper-longtail", "heavy-head"]
    for c in rep["cells"]:
        assert set(c) == CELL_KEYS
        assert c["n_requests"] == 40
        assert c["n_completed"] == 40  # sim never sheds
        assert c["shed"]["total"] == 0
        assert 0.0 <= c["attainment"]["e2e"] <= 1.0
        assert c["goodput"] >= 0.0


def test_sim_multi_tenant_reports_per_tenant_and_per_class():
    cell = evaluate_cell(
        "multi-tenant", "kairos-urgency", "kairos-slack", "sim",
        HarnessConfig(n_requests=60, seed=1),
    )
    assert set(cell["per_tenant"]) == {"interactive", "standard", "batch"}
    assert set(cell["per_class"]) == {"premium", "standard", "batch"}
    assert sum(v["n"] for v in cell["per_tenant"].values()) == 60
    for att in cell["per_tenant"].values():
        assert {"ttft", "tpot", "e2e", "n", "n_shed"} <= set(att)


# ---------------------------------------------------------------- engine
def test_engine_scale_mapping_preserves_labels_and_budget(engine_bundle):
    from repro.workloads import generate_scenario

    reqs = generate_scenario("multi-tenant", seed=3, n_requests=40)
    hcfg = HarnessConfig(seed=3)
    pairs = to_engine_requests(reqs, hcfg, engine_bundle.cfg.vocab_size,
                               np.random.default_rng(0))
    assert len(pairs) == len(reqs)
    for orig, (twin, prompt) in zip(reqs, pairs, strict=True):
        assert twin.input_len == len(prompt)
        assert 2 <= twin.input_len <= hcfg.engine_max_prompt
        assert 1 <= twin.output_len <= hcfg.engine_max_output
        assert (twin.tenant, twin.slo_class) == (orig.tenant, orig.slo_class)
        # SLO targets compress into engine virtual time, preserving tier
        # ratios; TTFT follows the arrival compression unless overridden
        assert hcfg.slo_ttft_scale == hcfg.engine_arrival_scale
        assert twin.slo.ttft == pytest.approx(orig.slo.ttft * hcfg.slo_ttft_scale)
        assert twin.slo.tpot == pytest.approx(orig.slo.tpot * hcfg.engine_slo_tpot_scale)
        assert twin.arrival == pytest.approx(orig.arrival * hcfg.engine_arrival_scale)
    # relative length ordering survives the rescale
    longest = max(reqs, key=lambda r: r.input_len)
    assert pairs[longest.rid][0].input_len == hcfg.engine_max_prompt


def test_engine_multi_tenant_quota_sheds_and_reports_per_tenant(engine_bundle):
    """The tentpole loop: a multi-tenant burst on the live engine with a
    per-tenant quota sheds through ServeSession and shows up per tenant."""
    cell = evaluate_cell(
        "multi-tenant", "kairos-urgency", "kairos-slack-greedy", "engine",
        HarnessConfig(n_requests=16, seed=1, tenant_quota=1,
                      engine_arrival_scale=1e-4),  # near-simultaneous burst
        _bundle=engine_bundle,
    )
    assert cell["backend"] == "engine"
    assert cell["shed"]["total"] > 0
    assert cell["shed"]["by_tenant"]  # attributed to specific tenants
    assert sum(cell["shed"]["by_tenant"].values()) == cell["shed"]["total"]
    assert cell["n_completed"] + cell["shed"]["total"] == cell["n_requests"]
    # shed requests count against their tenant's attainment denominator
    for tenant, n_shed in cell["shed"]["by_tenant"].items():
        assert cell["per_tenant"][tenant]["n_shed"] == n_shed


def test_sim_and_engine_cells_share_one_schema(engine_bundle):
    sim = evaluate_cell(
        "multi-tenant", "kairos-urgency", "kairos-slack-greedy", "sim",
        HarnessConfig(n_requests=12, seed=1),
    )
    eng = evaluate_cell(
        "multi-tenant", "kairos-urgency", "kairos-slack-greedy", "engine",
        HarnessConfig(n_requests=12, seed=1),
        _bundle=engine_bundle,
    )
    assert set(sim) == set(eng) == CELL_KEYS
    assert set(sim["attainment"]) == set(eng["attainment"])
    assert set(sim["shed"]) == set(eng["shed"]) == {"total", "by_tenant"}
    for tenant in sim["per_tenant"]:
        assert set(sim["per_tenant"][tenant]) == set(eng["per_tenant"].get(tenant, sim["per_tenant"][tenant]))


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="backend"):
        evaluate_cell("paper-longtail", "fcfs", "continuous", "gpu-cluster")


# ------------------------------------------------------------------ churn
def test_churn_cell_kills_a_replica_and_reports_the_fleet_block(engine_bundle):
    """The churn backend end-to-end through evaluate_cell: a scheduled kill
    on a flash crowd, every request still completing, the control-plane
    record in the cell's ``churn`` block."""
    from repro.workloads.harness import parse_kills

    cell = evaluate_cell(
        "flash-crowd", "fcfs", "kairos-slack", "churn",
        HarnessConfig(
            n_requests=12, seed=1, router_replicas=3,
            churn_kills=parse_kills(["0.002:1"]),
            autoscaler_policy="static",
        ),
        _bundle=engine_bundle,
    )
    assert cell["backend"] == "churn"
    assert cell["n_completed"] == 12
    fleet = cell["churn"]["fleet"]
    assert fleet["kills"] == 1
    assert fleet["replicas_live"] == 2
    assert fleet["autoscaler"] == "static"
    [rec] = fleet["recoveries"]
    # the recovery record replays the dist/fault.py narrative
    assert [s[0] for s in rec["steps"][:2]] == ["drain", "checkpoint"]
    # the churn block embeds the router block (the fleet IS a router)
    assert cell["churn"]["replicas"] == 3
    assert len(cell["churn"]["per_replica"]) == 3


def test_parse_kills_parses_and_validates():
    from repro.workloads.harness import parse_kills

    assert parse_kills(["0.5:1", "0.1:0"]) == ((0.1, 0), (0.5, 1))
    with pytest.raises(ValueError, match="T:IDX"):
        parse_kills(["nope"])
    with pytest.raises(ValueError, match=">= 0"):
        parse_kills(["1.0:-2"])


# ------------------------------------------------------------------- CLI
def test_cli_acceptance_command_emits_full_report(tmp_path):
    """The ISSUE acceptance command (shrunk to 10 requests), engine backend."""
    out = tmp_path / "report.json"
    evaluate_main([
        "--scenario", "multi-tenant", "--backend", "engine",
        "--prefill", "kairos-urgency", "--decode", "kairos-slack-greedy",
        "--n", "10", "--seed", "1", "--out", str(out),
    ])
    rep = json.loads(out.read_text())
    [cell] = rep["cells"]
    assert cell["backend"] == "engine"
    for tenant_att in cell["per_tenant"].values():
        for k in ("ttft", "tpot", "e2e", "n", "n_shed"):
            assert k in tenant_att
    assert "total" in cell["shed"] and "by_tenant" in cell["shed"]


def test_cli_same_grid_on_sim_backend_matches_schema(tmp_path):
    out_sim = tmp_path / "sim.json"
    evaluate_main([
        "--scenario", "multi-tenant", "--backend", "sim",
        "--prefill", "kairos-urgency", "--decode", "kairos-slack-greedy",
        "--n", "30", "--seed", "1", "--out", str(out_sim),
    ])
    rep = json.loads(out_sim.read_text())
    [cell] = rep["cells"]
    assert set(cell) == CELL_KEYS
    assert set(cell["per_tenant"]) == {"interactive", "standard", "batch"}


def test_cli_replay_scenario_round_trips_through_save_trace(tmp_path):
    from repro.sim.trace import save_trace
    from repro.workloads import generate_scenario

    trace = tmp_path / "trace.jsonl"
    save_trace(str(trace), generate_scenario("multi-tenant", seed=4, n_requests=12))
    out = tmp_path / "replay.json"
    evaluate_main([
        "--scenario", "replay", "--replay-trace", str(trace), "--backend", "sim",
        "--prefill", "fcfs", "--decode", "continuous", "--out", str(out),
    ])
    rep = json.loads(out.read_text())
    [cell] = rep["cells"]
    assert cell["scenario"] == "replay"
    assert cell["n_requests"] == 12
    assert set(cell["per_tenant"]) == {"interactive", "standard", "batch"}


def test_cli_requires_trace_for_replay(capsys):
    with pytest.raises(SystemExit):
        evaluate_main(["--scenario", "replay", "--backend", "sim"])
    assert "--replay-trace" in capsys.readouterr().err


# ------------------------------------------------------------ session quota
def test_session_tenant_quota_direct(engine_bundle):
    """Per-tenant quota on ServeSession with ManualClock: tenant A's burst
    is clipped at the quota while tenant B is untouched."""
    from repro.core.request import Request, SLOSpec
    from repro.serving.clock import ManualClock
    from repro.serving.engine import DisaggServer, EngineConfig
    from repro.serving.session import ServeSession

    ecfg = EngineConfig(max_slots=4, max_len=64, chunk_size=16)
    server = DisaggServer(engine_bundle.model, engine_bundle.params, ecfg,
                          clock=ManualClock(auto_step=1e-4))
    session = ServeSession(server, tenant_queue_depth=2)
    rng = np.random.default_rng(0)

    def req(rid, tenant):
        prompt = list(map(int, rng.integers(2, engine_bundle.cfg.vocab_size, 6)))
        return Request(rid=rid, arrival=0.0, input_len=6, output_len=2,
                       slo=SLOSpec(ttft=120.0, tpot=10.0), tenant=tenant), prompt

    results = [session.submit(*req(i, "a")) for i in range(4)]
    results += [session.submit(*req(10, "b"))]
    assert results == [True, True, False, False, True]  # quota hits tenant a only

    m = session.metrics
    assert m.submitted_by_tenant == {"a": 4, "b": 1}
    assert m.rejected_by_tenant == {"a": 2}
    while session.has_work:
        session.step()
    assert session.metrics.completed_by_tenant == {"a": 2, "b": 1}

    s = session.summary()
    assert s["rejected_by_tenant"] == {"a": 2}
    shed = [d for d in s["requests"] if d["phase"] == Phase.FAILED.value]
    assert {d["tenant"] for d in shed} == {"a"}
