"""Mutation-style fixture tests for the `repro.analysis` checker suite.

Each checker gets the same treatment: a fixture mini-repo is seeded with one
violation of the invariant, and the test asserts the checker catches it *at
the right line*, that a justified pragma suppresses it, and that a clean
file yields zero findings — so the static-analysis gate is itself proven to
detect every violation class it claims to."""
from __future__ import annotations

import textwrap
from pathlib import Path
from typing import Optional

import pytest

from repro.analysis import analyze, load_project, run_checkers
from repro.analysis.checkers import (
    ALL_CHECKERS,
    AsyncSafetyChecker,
    ClockHygieneChecker,
    MetricsSchemaChecker,
    RegistryCoverageChecker,
    RngDisciplineChecker,
)
from repro.analysis.checkers.schema import extract_schema
from repro.analysis.core import load_source_file


def make_repo(tmp_path: Path, files: dict, design: str = "", tests: Optional[dict] = None) -> Path:
    """Materialize a fixture mini-repo (pyproject marker anchors the root)."""
    (tmp_path / "pyproject.toml").write_text("[project]\nname='fixture'\n")
    (tmp_path / "DESIGN.md").write_text(design)
    for rel, text in {**files, **{f"tests/{k}": v for k, v in (tests or {}).items()}}.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return tmp_path


def run_on(tmp_path: Path, files: dict, select=None, **kw):
    root = make_repo(tmp_path, files, **kw)
    return analyze([str(root / "src")], select=select, root=root)


# ---------------------------------------------------------------- RPA001


def test_rpa001_catches_wall_clock_read_at_line(tmp_path):
    findings = run_on(
        tmp_path,
        {
            "src/repro/sim/bad.py": """\
            import time


            def now():
                return time.time()
            """
        },
        select=["RPA001"],
    )
    assert [(f.code, f.file, f.line) for f in findings] == [
        ("RPA001", "src/repro/sim/bad.py", 5)
    ]
    assert "Clock" in findings[0].message


def test_rpa001_sees_through_import_aliases(tmp_path):
    findings = run_on(
        tmp_path,
        {
            "src/repro/policies/bad.py": """\
            from time import perf_counter as pc


            def cost():
                return pc()
            """
        },
        select=["RPA001"],
    )
    assert len(findings) == 1 and findings[0].line == 5


def test_rpa001_whitelists_launch_and_clock_py(tmp_path):
    findings = run_on(
        tmp_path,
        {
            # launch CLIs legitimately read wall time: out of scope
            "src/repro/launch/cli.py": "import time\nt = time.time()\n",
            # the injection boundary itself is excluded
            "src/repro/serving/clock.py": "import time\nt = time.monotonic()\n",
        },
        select=["RPA001"],
    )
    assert findings == []


def test_rpa001_justified_pragma_suppresses(tmp_path):
    findings = run_on(
        tmp_path,
        {
            "src/repro/sim/ok.py": """\
            import time

            t0 = time.perf_counter()  # repro: allow[RPA001] host wall time on purpose
            """
        },
        select=["RPA001", "RPA900"],
    )
    assert findings == []


def test_unjustified_pragma_does_not_suppress_and_is_flagged(tmp_path):
    findings = run_on(
        tmp_path,
        {
            "src/repro/sim/sneaky.py": """\
            import time

            t0 = time.perf_counter()  # repro: allow[RPA001]
            """
        },
        select=["RPA001", "RPA900"],
    )
    codes = sorted(f.code for f in findings)
    assert codes == ["RPA001", "RPA900"]


def test_pragma_on_line_above_suppresses(tmp_path):
    findings = run_on(
        tmp_path,
        {
            "src/repro/sim/ok.py": """\
            import time

            # repro: allow[RPA001] wall time measured deliberately here
            t0 = time.perf_counter()
            """
        },
        select=["RPA001", "RPA900"],
    )
    assert findings == []


def test_clean_file_yields_zero_findings(tmp_path):
    findings = run_on(
        tmp_path,
        {
            "src/repro/sim/clean.py": """\
            def f(clock):
                return clock.monotonic()
            """
        },
        select=["RPA001", "RPA002", "RPA003", "RPA004", "RPA900"],
    )
    assert findings == []


# ---------------------------------------------------------------- RPA002


def test_rpa002_seedless_default_rng(tmp_path):
    findings = run_on(
        tmp_path,
        {
            "src/repro/workloads/bad.py": """\
            import numpy as np

            rng = np.random.default_rng()
            ok = np.random.default_rng(42)
            """
        },
        select=["RPA002"],
    )
    assert [(f.code, f.line) for f in findings] == [("RPA002", 3)]
    assert "seed" in findings[0].message


def test_rpa002_global_numpy_and_stdlib_random(tmp_path):
    findings = run_on(
        tmp_path,
        {
            "src/repro/sim/bad.py": """\
            import random

            import numpy as np

            x = np.random.shuffle([1, 2])
            y = random.randint(0, 3)
            """
        },
        select=["RPA002"],
    )
    assert [(f.code, f.line) for f in findings] == [("RPA002", 5), ("RPA002", 6)]


def test_rpa002_threaded_generator_is_fine(tmp_path):
    findings = run_on(
        tmp_path,
        {
            "src/repro/sim/ok.py": """\
            import numpy as np


            def sample(rng: np.random.Generator):
                return rng.integers(0, 10, 4)
            """
        },
        select=["RPA002"],
    )
    assert findings == []


# ---------------------------------------------------------------- RPA003


def test_rpa003_blocking_calls_in_async_def(tmp_path):
    findings = run_on(
        tmp_path,
        {
            "src/repro/serving/frontend.py": """\
            import asyncio
            import time


            async def stepper(self):
                time.sleep(0.1)
                self.server.clock.sleep(0.1)
                self.session.run([])
                with open("x") as fh:
                    pass
                await asyncio.sleep(0)  # fine
            """
        },
        select=["RPA003"],
    )
    assert [f.line for f in findings] == [6, 7, 8, 9]
    assert all(f.code == "RPA003" for f in findings)


def test_rpa003_ignores_sync_defs_and_other_files(tmp_path):
    findings = run_on(
        tmp_path,
        {
            # nested sync def: defined in the coroutine, runs elsewhere
            "src/repro/serving/router.py": """\
            import time


            async def outer():
                def helper():
                    time.sleep(1)
                return helper
            """,
            # same blocking call in a module RPA003 does not patrol
            "src/repro/serving/engine.py": """\
            import time


            async def f():
                time.sleep(1)
            """,
        },
        select=["RPA003"],
    )
    assert findings == []


# ---------------------------------------------------------------- RPA004


_POLICY = """\
from repro.policies.registry import register_prefill, register_decode


@register_prefill("zzz-pol")
class P:
    pass


register_decode("yyy-dec", flag=True)(P)
"""


def test_rpa004_clean_when_tested_and_documented(tmp_path):
    findings = run_on(
        tmp_path,
        {"src/repro/policies/p.py": _POLICY},
        design="| `zzz-pol` | x |\n| `yyy-dec` | y |\n",
        tests={"test_p.py": "NAMES = ['zzz-pol', 'yyy-dec']\n"},
        select=["RPA004"],
    )
    assert findings == []


def test_rpa004_flags_untested_and_undocumented_at_registration_line(tmp_path):
    findings = run_on(
        tmp_path,
        {"src/repro/policies/p.py": _POLICY},
        design="only `zzz-pol` documented\n",
        tests={"test_p.py": "run('zzz-pol')\n"},
        select=["RPA004"],
    )
    # yyy-dec (direct factory-call form, line 9): untested AND undocumented
    assert [f.line for f in findings] == [9, 9]
    assert any("tests/" in f.message for f in findings)
    assert any("DESIGN.md" in f.message for f in findings)


def test_rpa004_substring_match_does_not_count(tmp_path):
    findings = run_on(
        tmp_path,
        {
            "src/repro/policies/p.py": """\
            from repro.policies.registry import register_decode


            @register_decode("kai-slack")
            class D:
                pass
            """
        },
        # mentions only the -greedy variant; must NOT cover "kai-slack"
        design="`kai-slack-greedy` is documented\n",
        tests={"test_d.py": "make('kai-slack-greedy')\n"},
        select=["RPA004"],
    )
    assert len(findings) == 2  # untested + undocumented


# ---------------------------------------------------------------- RPA005


_SESSION = """\
class ServeSession:
    def summary(self):
        agg = dict(alpha=1, beta=2)
        out = dict(gamma=3, **agg)
        out["delta"] = 4
        out.update(epsilon=5)
        return out
"""


def _schema_checker(rel_schema: str):
    chk = MetricsSchemaChecker()
    chk.schema_rel = rel_schema
    chk.specs = (
        ("s.summary", "src/repro/serving/session.py", ("ServeSession", "summary"), "keys"),
    )
    return chk


def test_rpa005_fingerprint_covers_dict_update_subscript_and_star_kwargs(tmp_path):
    root = make_repo(tmp_path, {"src/repro/serving/session.py": _SESSION})
    project = load_project([root / "src"], root=root)
    chk = _schema_checker("schema.json")
    schema = extract_schema(project, chk.specs)
    assert schema["entries"]["s.summary"] == ["alpha", "beta", "delta", "epsilon", "gamma"]


def test_rpa005_drift_is_flagged_both_directions(tmp_path):
    import json

    root = make_repo(tmp_path, {"src/repro/serving/session.py": _SESSION})
    chk = _schema_checker("schema.json")
    committed = dict(
        version=1,
        entries={"s.summary": ["alpha", "beta", "delta", "gamma", "vanished"]},
    )
    (root / "schema.json").write_text(json.dumps(committed))
    project = load_project([root / "src"], root=root)
    findings = run_checkers(project, [chk], select=["RPA005"])
    msgs = [f.message for f in findings]
    assert len(findings) == 2
    assert any("'epsilon'" in m and "not in the committed schema" in m for m in msgs)
    assert any("'vanished'" in m and "no longer emits" in m for m in msgs)
    assert all(f.line == 2 for f in findings)  # anchored at the summary() def


def test_rpa005_missing_schema_file_is_one_finding(tmp_path):
    root = make_repo(tmp_path, {"src/repro/serving/session.py": _SESSION})
    project = load_project([root / "src"], root=root)
    findings = run_checkers(project, [_schema_checker("nope.json")], select=["RPA005"])
    assert len(findings) == 1 and "--write-schema" in findings[0].message


def test_rpa005_matching_schema_is_clean(tmp_path):
    import json

    root = make_repo(tmp_path, {"src/repro/serving/session.py": _SESSION})
    chk = _schema_checker("schema.json")
    project = load_project([root / "src"], root=root)
    (root / "schema.json").write_text(json.dumps(extract_schema(project, chk.specs)))
    assert run_checkers(project, [chk], select=["RPA005"]) == []


# ------------------------------------------------------- framework behavior


def test_syntax_error_degrades_to_rpa000_and_run_continues(tmp_path):
    findings = run_on(
        tmp_path,
        {
            "src/repro/sim/broken.py": "def f(:\n    pass\n",
            "src/repro/sim/bad.py": "import time\nt = time.time()\n",
        },
        select=["RPA000", "RPA001"],
    )
    codes = {f.code for f in findings}
    assert "RPA000" in codes  # broken file reported with its location...
    assert "RPA001" in codes  # ...and the healthy file was still checked
    rpa000 = next(f for f in findings if f.code == "RPA000")
    assert rpa000.file == "src/repro/sim/broken.py" and rpa000.line == 1


def test_select_filters_checkers(tmp_path):
    files = {
        "src/repro/sim/bad.py": "import time\nimport random\nt = time.time()\nr = random.random()\n"
    }
    only_clock = run_on(tmp_path, dict(files), select=["RPA001"])
    assert {f.code for f in only_clock} == {"RPA001"}
    both = run_on(tmp_path, dict(files), select=["RPA001", "RPA002"])
    assert {f.code for f in both} == {"RPA001", "RPA002"}


def test_pragma_requires_exact_code(tmp_path):
    # an RPA002 pragma must not silence an RPA001 finding on the same line
    findings = run_on(
        tmp_path,
        {
            "src/repro/sim/bad.py": """\
            import time

            t0 = time.time()  # repro: allow[RPA002] wrong code entirely
            """
        },
        select=["RPA001"],
    )
    assert [f.code for f in findings] == ["RPA001"]


def test_all_checkers_have_unique_codes_and_descriptions():
    codes = [cls.code for cls in ALL_CHECKERS]
    assert len(codes) == len(set(codes)) == 5
    assert all(cls.description for cls in ALL_CHECKERS)


def test_load_source_file_parses_pragma_lists(tmp_path):
    p = tmp_path / "x.py"
    p.write_text("a = 1  # repro: allow[RPA001,RPA002] two codes, one reason\n")
    sf = load_source_file(p, tmp_path)
    assert sf.allows("RPA001", 1) and sf.allows("RPA002", 1)
    assert sf.allows("RPA001", 2)  # next line covered too
    assert not sf.allows("RPA003", 1)
