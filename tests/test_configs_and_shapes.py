"""Config registry + shape-cell coverage + input_specs invariants."""
import jax
import pytest

from repro.configs import (
    ALL_SHAPES,
    ASSIGNED,
    get_config,
    input_specs,
    list_configs,
    shape_applicable,
)
from repro.configs.shapes import ShapeSpec


def test_registry_covers_all_assigned():
    assert len(ASSIGNED) == 10
    for n in ASSIGNED:
        cfg = get_config(n)
        assert cfg.name == n
        smoke = get_config(n + "-smoke")
        assert smoke.d_model <= 64 and smoke.num_layers <= 4


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("nonexistent-model")


def test_shape_cells_are_40():
    cells = [(a, s) for a in ASSIGNED for s in ALL_SHAPES]
    assert len(cells) == 40
    skips = [c for c in cells if shape_applicable(get_config(c[0]), ALL_SHAPES[c[1]])]
    # long_500k skipped for the 8 full-attention archs
    assert len(skips) == 8
    assert all(s == "long_500k" for _, s in skips)
    runs = {(a, s) for a, s in cells} - set(skips)
    assert ("mamba2-130m", "long_500k") in runs
    assert ("zamba2-2.7b", "long_500k") in runs


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("shape", list(ALL_SHAPES))
def test_input_specs_are_structs_no_allocation(arch, shape):
    cfg = get_config(arch)
    spec = ALL_SHAPES[shape]
    if shape_applicable(cfg, spec):
        pytest.skip("documented skip cell")
    specs = input_specs(cfg, spec)
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
    # shape-cell invariants
    if spec.kind == "train":
        first = jax.tree.leaves(specs)[0]
        assert first.shape[0] == spec.global_batch
    if spec.kind == "decode":
        assert specs["tokens"].shape == (spec.global_batch, 1)
        assert specs["positions"].shape == (spec.global_batch,)
        # the cache covers seq_len positions (attention families)
        for name, leaf in specs["cache"].items():
            if name in ("k", "v"):
                assert leaf.shape[2] == spec.seq_len


def test_smoke_config_round_trip_via_suffix():
    cfg = get_config("grok-1-314b-smoke")
    assert cfg.num_experts == 4 and cfg.family == "moe"


def test_paper_proxy_config_within_nameplate():
    cfg = get_config("minimax-m2.5-proxy")
    assert 180e9 <= cfg.count_params() <= 280e9  # 229B class
    assert cfg.count_active_params() <= 25e9  # A10B class (proxy)
