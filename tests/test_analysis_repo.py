"""Repo-wide static-analysis gate: the committed tree must be clean.

This is the same contract the `static-analysis` CI job enforces — running
it as a tier-1 test means a violation fails the suite locally before CI
ever sees it."""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

from repro.analysis import analyze

REPO_ROOT = Path(__file__).resolve().parent.parent


def test_committed_tree_is_clean():
    findings = analyze([str(REPO_ROOT / "src")], root=REPO_ROOT)
    assert findings == [], "unsuppressed findings:\n" + "\n".join(map(str, findings))


def test_cli_exits_zero_on_committed_tree():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src", "--format", "json"],
        cwd=REPO_ROOT,
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(proc.stdout)
    assert report["count"] == 0 and report["findings"] == []


def test_cli_exits_nonzero_on_violation(tmp_path):
    # a real violation through the real CLI: exit 1 + a parsable finding
    (tmp_path / "pyproject.toml").write_text("[project]\nname='fixture'\n")
    bad = tmp_path / "src" / "repro" / "sim" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\nt = time.monotonic()\n")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(tmp_path / "src"),
         "--select", "RPA001", "--format", "json"],
        cwd=REPO_ROOT,
        capture_output=True,
        text=True,
        env={"PYTHONPATH": str(REPO_ROOT / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 1, proc.stdout + proc.stderr
    report = json.loads(proc.stdout)
    assert report["count"] == 1
    assert report["findings"][0]["code"] == "RPA001"
    assert report["findings"][0]["line"] == 2


def test_cli_degrades_gracefully_on_unparseable_file(tmp_path):
    (tmp_path / "pyproject.toml").write_text("[project]\nname='fixture'\n")
    bad = tmp_path / "src" / "repro" / "sim" / "broken.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(:\n    pass\n")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(tmp_path / "src"),
         "--select", "RPA000,RPA001", "--format", "json"],
        cwd=REPO_ROOT,
        capture_output=True,
        text=True,
        env={"PYTHONPATH": str(REPO_ROOT / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 1, proc.stdout + proc.stderr  # reported, not crashed
    report = json.loads(proc.stdout)
    assert [f["code"] for f in report["findings"]] == ["RPA000"]
    assert report["findings"][0]["file"].endswith("broken.py")


def test_cli_list_names_all_checkers():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--list"],
        cwd=REPO_ROOT,
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0
    for code in ("RPA001", "RPA002", "RPA003", "RPA004", "RPA005"):
        assert code in proc.stdout


def test_committed_metrics_schema_matches_tree():
    """--write-schema must be a no-op on the committed tree (the RPA005
    clean check above implies this; asserting directly gives a sharper
    failure when only the schema file is stale)."""
    from repro.analysis import load_project
    from repro.analysis.checkers.schema import SCHEMA_REL, extract_schema

    project = load_project([REPO_ROOT / "src"], root=REPO_ROOT)
    current = extract_schema(project)
    committed = json.loads((REPO_ROOT / SCHEMA_REL).read_text())
    assert current == committed
