"""Activation-sharding context: exact no-op outside a mesh, state restore."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.act_sharding import (
    active_context,
    constrain_batch,
    use_activation_sharding,
)
from repro.dist.sharding import ShardingPlan
from repro.launch.mesh import make_mesh


def _host_mesh():
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))


def test_constrain_batch_is_identity_outside_context():
    for x in [
        jnp.arange(12, dtype=jnp.bfloat16).reshape(4, 3),
        jnp.ones((2, 3, 5), jnp.float32),
        jnp.zeros((1,), jnp.int32),
    ]:
        y = constrain_batch(x)
        assert y is x  # exact no-op: same object, not a copy
        assert y.dtype == x.dtype
        np.testing.assert_array_equal(
            np.asarray(y, np.float32), np.asarray(x, np.float32)
        )


def test_context_restores_prior_state_on_exit():
    assert active_context() is None
    mesh = _host_mesh()
    with use_activation_sharding(mesh, ("data",)):
        assert active_context() == (mesh, ("data",))
        with use_activation_sharding(mesh, ("data", "model")):
            assert active_context() == (mesh, ("data", "model"))
        assert active_context() == (mesh, ("data",))
    assert active_context() is None


def test_context_restores_state_on_exception():
    mesh = _host_mesh()
    try:
        with use_activation_sharding(mesh, ("data",)):
            raise ValueError("boom")
    except ValueError:
        pass
    assert active_context() is None
    x = jnp.ones((4, 2))
    assert constrain_batch(x) is x


def test_constrain_applies_under_context_and_preserves_values():
    mesh = _host_mesh()
    plan = ShardingPlan(mesh)
    x = jnp.arange(8, dtype=jnp.float32).reshape(4, 2)
    with use_activation_sharding(mesh, plan.batch_axes):
        y = jax.jit(constrain_batch)(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert y.dtype == x.dtype


def test_indivisible_batch_falls_back_to_identity():
    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    n = mesh.shape["data"]
    x = jnp.ones((n + 1 if n > 1 else 3, 2))
    with use_activation_sharding(mesh, ("data",)):
        y = constrain_batch(x)
    if n > 1:
        assert y is x  # batch not divisible by the data axis: replicated
    else:
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
