"""Property tests: the jittable (jax.lax) schedulers match the numpy control
plane exactly, over randomized queues (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import jax_sched
from repro.core.lut import StepTimeLUT
from repro.core.predictor import predict_all_finish_times
from repro.core.request import Phase, Request, SLOSpec
from repro.policies import SlackDecodeScheduler, UrgencyPrefillScheduler

SLO = SLOSpec(ttft=8.0, tpot=0.05)


def _queue(arrivals, lens):
    out = []
    for i, (a, l) in enumerate(zip(arrivals, lens, strict=True)):
        out.append(Request(rid=i, arrival=float(a), input_len=int(l), output_len=10, slo=SLO))
    return out


arrival_lists = st.lists(
    st.integers(min_value=0, max_value=512).map(lambda x: x / 32.0),
    min_size=1, max_size=24,
)
len_lists = st.lists(st.integers(min_value=1, max_value=50_000), min_size=1, max_size=24)


@given(arrival_lists, len_lists, st.integers(0, 200))
@settings(max_examples=40, deadline=None)
def test_fcfs_finish_times_jax_matches_numpy(arrs, lens, tnow_i):
    n = min(len(arrs), len(lens))
    arrs, lens = arrs[:n], lens[:n]
    t_now = tnow_i / 16.0
    mu = 20_000.0
    queue = _queue(arrs, lens)
    ref = predict_all_finish_times(queue, t_now, mu)
    out = jax_sched.fcfs_finish_times(
        jnp.asarray(arrs, jnp.float32),
        jnp.asarray(lens, jnp.float32),
        jnp.ones(n, bool),
        jnp.float32(t_now),
        jnp.float32(mu),
    )
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5, atol=2e-4)


@given(arrival_lists, len_lists, st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_urgency_select_jax_matches_numpy(arrs, lens, budget_k):
    n = min(len(arrs), len(lens))
    arrs, lens = arrs[:n], lens[:n]
    budget = budget_k * 512
    t_now, mu = 2.0, 20_000.0
    queue = _queue(arrs, lens)
    ref_sel = UrgencyPrefillScheduler().select(queue, t_now, mu, budget)
    ref_take = np.zeros(n)
    for r, take in ref_sel:
        ref_take[r.rid] = take
    out = jax_sched.urgency_select(
        jnp.asarray(arrs, jnp.float32),
        jnp.asarray(lens, jnp.float32),
        jnp.asarray(lens, jnp.float32),
        jnp.ones(n, bool),
        jnp.float32(t_now),
        jnp.float32(mu),
        jnp.float32(SLO.ttft),
        budget,
    )
    # scores can tie under f32; compare the token totals and per-slot takes
    # with tolerance for tie permutations: total must match exactly
    assert float(jnp.sum(out)) == pytest.approx(ref_take.sum(), abs=1.0)
    # non-tied slots must match
    u = UrgencyPrefillScheduler().urgency_scores(queue, t_now, mu)
    order = np.argsort(-u)
    tied = len(set(np.round(u * 1e7).astype(np.int64))) < n
    if not tied:
        np.testing.assert_allclose(np.asarray(out), ref_take, atol=1.0)


def _lut():
    return StepTimeLUT(analytic=lambda b, s: 0.005 + 0.0002 * b + 2.4e-7 * s)


@given(
    st.lists(st.integers(64, 200_000), min_size=1, max_size=24),
    st.lists(st.integers(0, 400), min_size=1, max_size=24),
    st.integers(0, 40),
)
@settings(max_examples=40, deadline=None)
def test_slack_select_jax_matches_numpy(seqs, ngens, dt_i):
    n = min(len(seqs), len(ngens))
    seqs, ngens = seqs[:n], ngens[:n]
    t_now = 100.0 + dt_i / 100.0
    lut = _lut()
    reqs = []
    for i in range(n):
        r = Request(rid=i, arrival=0.0, input_len=seqs[i] - min(ngens[i], seqs[i] - 1),
                    output_len=1000, slo=SLO)
        r.n_generated = min(ngens[i], seqs[i] - 1)
        r.n_decoded = r.n_generated
        r.first_token_time = 99.0
        r.decode_start = 99.0
        r.phase = Phase.DECODE
        reqs.append(r)
    sched = SlackDecodeScheduler(lut, slo_margin=1.0)
    batch, _ = sched.select(reqs, t_now)
    ref_mask = np.zeros(n, bool)
    for r in batch:
        ref_mask[r.rid] = True

    bsz_edges, seq_edges, table = lut.as_arrays()
    sel = jax_sched.slack_select(
        jnp.asarray([r.seq_len for r in reqs], jnp.int32),
        jnp.asarray([r.n_decoded for r in reqs], jnp.int32),
        jnp.asarray([r.decode_start for r in reqs], jnp.float32),
        jnp.ones(n, bool),
        jnp.float32(t_now),
        jnp.float32(SLO.tpot),
        jnp.asarray(table),
        jnp.asarray(bsz_edges),
        jnp.asarray(seq_edges),
    )
    got = np.asarray(sel.selected)
    # f32-vs-f64 boundary ties can flip individual inclusion decisions; the
    # batch size must agree within 1 and the fallback-all behavior exactly
    if ref_mask.all():
        assert got.all()
    else:
        assert abs(got.sum() - ref_mask.sum()) <= 1


def test_lut_lookup_and_update_jax():
    lut = _lut()
    bsz_edges, seq_edges, table = (jnp.asarray(x) for x in lut.as_arrays())
    v = jax_sched.lut_lookup(table, bsz_edges, seq_edges, jnp.int32(4), jnp.int32(10_000))
    assert float(v) == pytest.approx(lut.lookup(4, 10_000), rel=1e-6)
    counts = jnp.ones_like(table)
    t2, c2 = jax_sched.lut_update(
        table, counts, bsz_edges, seq_edges, jnp.int32(4), jnp.int32(10_000), jnp.float32(1.0)
    )
    lut.update(4, 10_000, 1.0)
    v2 = jax_sched.lut_lookup(t2, bsz_edges, seq_edges, jnp.int32(4), jnp.int32(10_000))
    assert float(v2) == pytest.approx(lut.lookup(4, 10_000), rel=1e-6)
