"""Fleet control plane: replica failover with stream splicing, elastic
autoscaling on windowed-SLO telemetry, and the `windowed_slo` edge cases
the autoscaler policies depend on.

The two pinned acceptance tests:
  * kill 1 of 3 replicas mid-decode on a flash-crowd — every in-flight
    request on the dead replica completes on a survivor with no
    duplicated or dropped tokens, and every rid ends with exactly one
    terminal event (`check_terminal_invariant`);
  * `queue-threshold` strictly beats `static` on windowed e2e SLO
    attainment when a flash crowd hits an under-provisioned fleet.
"""
import asyncio

import numpy as np
import pytest

from repro.core.request import Phase, Request, SLOSpec
from repro.obs.events import Event, EventType, check_terminal_invariant
from repro.obs.slo import attainment_from_events, windowed_slo


@pytest.fixture(scope="module")
def tiny_model():
    import jax

    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _server(tiny_model):
    from repro.serving.clock import ManualClock
    from repro.serving.engine import DisaggServer, EngineConfig

    cfg, model, params = tiny_model
    return DisaggServer(
        model, params,
        EngineConfig(max_slots=4, max_len=64, chunk_size=16),
        clock=ManualClock(auto_step=1e-4),
    )


# ------------------------------------------------------------- failover
def test_kill_mid_decode_restores_on_survivors(tiny_model):
    """The pinned churn cell: one of three replicas dies mid-decode under
    a flash crowd; its in-flight requests finish on survivors with the
    token streams spliced exactly once (greedy decode regenerates the
    identical prefix, the client sees no duplicate and no gap)."""
    from repro.serving.fleetctl import FleetSession
    from repro.workloads.scenarios import make_scenario

    cfg, _model, _params = tiny_model
    scen_reqs = make_scenario("flash-crowd", n_requests=12).generate(seed=3)
    # engine-scale twins: flash-crowd supplies the arrival pattern and the
    # steady/crowd tenant split; lengths are pinned (6-token decode) so
    # every request spends real time in the decode phase the kill targets
    rng = np.random.default_rng(3)
    max_in = max(r.input_len for r in scen_reqs)
    pairs = []
    for r in scen_reqs:
        n_in = 2 + round(10 * r.input_len / max_in)
        prompt = list(map(int, rng.integers(2, cfg.vocab_size, n_in)))
        pairs.append(
            (
                Request(rid=r.rid, arrival=r.arrival * 1e-4, input_len=n_in,
                        output_len=6, slo=SLOSpec(ttft=120.0, tpot=10.0),
                        tenant=r.tenant, slo_class=r.slo_class),
                prompt,
            )
        )

    async def _run():
        fleet = FleetSession(
            [_server(tiny_model) for _ in range(3)],
            policy="round-robin",
            autoscaler="static",
            autoscale_interval=0.0,
        )
        async with fleet:
            handles = [
                await fleet.submit(req, p, at=req.arrival) for req, p in pairs
            ]

            async def killer():
                # wait for replica 1 to be decoding (some token already
                # generated), then kill it mid-flight
                while True:
                    sess = fleet.replicas[1].frontend.session
                    if any(lr.req.n_generated >= 1 for lr in sess.active):
                        return await fleet.kill_replica(1)
                    await asyncio.sleep(0)

            results = {}

            async def consume(h):
                results[h.rid] = await h.result()

            record, *_ = await asyncio.gather(
                killer(), *(consume(h) for h in handles)
            )
        return fleet, record, results

    fleet, record, results = asyncio.run(_run())

    assert record["restored"], "kill landed on an idle replica (vacuous test)"
    # the recovery record tells the dist/fault.py story against live state
    assert [s[0] for s in record["steps"][:2]] == ["drain", "checkpoint"]
    assert record["steps"][-1][0] == "restore"
    assert record["mesh"]["shape"]
    assert record["snapshot"]["slots_live"] >= 1

    outs = fleet.outputs
    for req, _prompt in pairs:
        # no drops, no duplicates: the client-visible stream equals the
        # engine's own output record, at exactly the requested length
        assert req.phase is Phase.DONE, (req.rid, req.phase)
        assert results[req.rid] == outs[req.rid], req.rid
        assert len(results[req.rid]) == req.output_len, req.rid

    terminals = check_terminal_invariant(fleet.trace.events)
    assert all(len(t) == 1 for t in terminals.values()), terminals

    restores = [e for e in fleet.trace.events if e.type is EventType.RESTORE]
    assert {e.rid for e in restores} == set(record["restored"])
    assert all(e.data["src"] == 1 and e.data["dst"] != 1 for e in restores)

    s = fleet.summary()
    assert s["fleet"]["kills"] == 1
    assert s["fleet"]["restored"] == len(record["restored"])
    # books move with the request: no double-counting across the fleet
    assert s["submitted"] == s["accepted"] == s["completed"] == len(pairs)


def test_kill_refuses_last_live_replica(tiny_model):
    from repro.serving.fleetctl import FleetSession

    async def _run():
        fleet = FleetSession([_server(tiny_model)], autoscale_interval=0.0)
        async with fleet:
            with pytest.raises(RuntimeError, match="last live replica"):
                await fleet.kill_replica(0)

    asyncio.run(_run())


# ----------------------------------------------------------- autoscaling
def _crowd_pairs(cfg, n=36, input_len=40, output_len=4, gap=0.001,
                 ttft=0.015, tpot=1.0):
    """A sustained flash crowd: multi-chunk prefills arriving faster than
    one replica drains them, so the admission-queue gauge stands tall for
    several control intervals."""
    rng = np.random.default_rng(0)
    prompts = [
        list(map(int, rng.integers(2, cfg.vocab_size, input_len)))
        for _ in range(n)
    ]
    return [
        (
            Request(rid=i, arrival=gap * i, input_len=input_len,
                    output_len=output_len, slo=SLOSpec(ttft=ttft, tpot=tpot)),
            p,
        )
        for i, p in enumerate(prompts)
    ]


def _run_autoscaled(tiny_model, autoscaler, interval=0.005):
    from repro.serving.fleetctl import FleetSession

    cfg, _model, _params = tiny_model
    pairs = _crowd_pairs(cfg)

    async def _run():
        fleet = FleetSession(
            [_server(tiny_model)],
            policy="least-queued",
            autoscaler=autoscaler,
            n_min=1, n_max=3,
            autoscale_interval=interval,
            slo_window=interval,
            server_factory=lambda: _server(tiny_model),
        )
        async with fleet:
            await fleet.replay(pairs, clients=8)
        return fleet

    fleet = asyncio.run(_run())
    slo = windowed_slo(fleet.trace.events, interval)
    scored = [w for w in slo["windows"] if w["done"] + w["shed"]]
    windowed_e2e = (
        sum(w["e2e"] * (w["done"] + w["shed"]) for w in scored)
        / sum(w["done"] + w["shed"] for w in scored)
    )
    return fleet, windowed_e2e


def test_queue_threshold_beats_static_on_windowed_e2e(tiny_model):
    """The pinned comparison: the reactive policy must strictly beat the
    fixed fleet on windowed e2e attainment when the crowd hits."""
    static_fleet, static_e2e = _run_autoscaled(tiny_model, "static")
    qt_fleet, qt_e2e = _run_autoscaled(tiny_model, "queue-threshold")

    assert static_fleet.summary()["fleet"]["scale_ups"] == 0
    sqt = qt_fleet.summary()["fleet"]
    assert sqt["scale_ups"] >= 1, "queue-threshold never scaled up"
    assert qt_e2e > static_e2e, (qt_e2e, static_e2e)

    # the SCALE event carries the evidence an operator would audit
    scales = [e for e in qt_fleet.trace.events if e.type is EventType.SCALE]
    assert scales and all(
        e.data["policy"] == "queue-threshold" and "evidence" in e.data
        for e in scales
    )
    # every request still completes under either policy
    assert static_fleet.summary()["completed"] == 36
    assert qt_fleet.summary()["completed"] == 36


def test_scale_up_requires_factory(tiny_model):
    from repro.serving.fleetctl import FleetSession

    async def _run():
        fleet = FleetSession([_server(tiny_model)], autoscale_interval=0.0)
        async with fleet:
            assert not await fleet._scale_up(0.0)  # no server_factory
        assert fleet.summary()["fleet"]["scale_ups"] == 0

    asyncio.run(_run())


# --------------------------------------------------- windowed_slo edges
def _ev(etype, t, rid=-1, **data):
    return Event(type=etype, t=t, rid=rid, data=data)


def _lifecycle(rid, t0, terminal=EventType.DONE, n_tokens=2, tok_dt=0.01,
               slo_ttft=1.0, slo_tpot=1.0):
    evs = [
        _ev(EventType.SUBMIT, t0, rid, arrival=t0, input_len=4,
            output_len=n_tokens, slo_ttft=slo_ttft, slo_tpot=slo_tpot),
        _ev(EventType.ADMIT, t0, rid),
        _ev(EventType.PREFILL_END, t0 + tok_dt / 2, rid),
    ]
    t = t0
    for _ in range(n_tokens):
        t += tok_dt
        evs.append(_ev(EventType.TOKEN, t, rid))
    evs.append(_ev(terminal, t + tok_dt, rid))
    return evs


def test_windowed_slo_empty_stream():
    out = windowed_slo([], 0.5)
    assert out == dict(window=0.5, n_windows=0, windows=[])


def test_windowed_slo_rejects_nonpositive_window():
    with pytest.raises(ValueError, match="positive"):
        windowed_slo([], 0.0)
    with pytest.raises(ValueError, match="positive"):
        windowed_slo(_lifecycle(0, 0.0), -1.0)


def test_windowed_slo_boundary_events():
    # a terminal landing exactly ON a window edge belongs to the window it
    # opens (half-open [t0, t1) buckets), and an event at exactly t_end =
    # k*window still allocates window k
    evs = _lifecycle(0, 0.0, n_tokens=1, tok_dt=0.25)  # terminal at t=0.5
    out = windowed_slo(evs, 0.5)
    assert out["n_windows"] == 2
    assert [w["done"] for w in out["windows"]] == [0, 1]
    assert out["windows"][1]["t0"] == 0.5
    # t=0 events land in window 0
    assert out["windows"][0]["submitted"] == 1


def test_windowed_slo_per_window_counts_sum_to_attainment():
    """Property: windowed counts are a partition of the run — per-window
    done/shed/cancelled sums and attainment numerators reproduce
    `attainment_from_events` exactly, for any seeded stream."""
    rng = np.random.default_rng(7)
    for _trial in range(5):
        evs = []
        n = int(rng.integers(5, 40))
        for rid in range(n):
            t0 = float(rng.uniform(0.0, 3.0))
            terminal = [EventType.DONE, EventType.SHED, EventType.CANCEL][
                int(rng.integers(0, 3)) if rid % 2 else 0
            ]
            evs.extend(
                _lifecycle(
                    rid, t0, terminal=terminal,
                    n_tokens=int(rng.integers(1, 6)),
                    tok_dt=float(rng.uniform(0.005, 0.2)),
                    slo_ttft=float(rng.uniform(0.01, 0.5)),
                    slo_tpot=float(rng.uniform(0.01, 0.3)),
                )
            )
        att = attainment_from_events(evs)
        out = windowed_slo(evs, float(rng.uniform(0.1, 1.0)))
        wins = out["windows"]
        assert sum(w["done"] for w in wins) + sum(
            w["shed"] for w in wins
        ) == att["n"]
        assert sum(w["cancelled"] for w in wins) == att["n_cancelled"]
        assert sum(w["submitted"] for w in wins) == n
        for key in ("ttft", "tpot", "e2e"):
            hits = sum(w[key] * (w["done"] + w["shed"]) for w in wins)
            assert hits == pytest.approx(att[key] * att["n"])
