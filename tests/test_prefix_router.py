"""Multi-server routing + prefix-cache-aware admission, and the slot/session
substrate fixes underneath them:

  * `PrefixCache` trie semantics (block hashing, accounting, LRU leaf
    eviction)
  * `SlotAllocator` regressions: snapshot/restore preserves free-list order
    (replay determinism), `used_tokens` is a maintained counter that always
    equals the re-summed live set, prefix credit charges `need - credit`
  * the `rejected_global` / `rejected_tenant` admission split
  * routing policies as pure functions of the replica view
  * `RouterSession`: cross-replica cancellation reclaims the owning
    replica's slot only; 1-replica routed runs are bit-identical to a bare
    `AsyncServeSession` on a `ManualClock`; prefix-affinity beats
    round-robin on the prefix-heavy scenario's hit rate
  * harness `router` backend + loadgen `--servers/--router` CLI schema
"""
import asyncio
import copy
from dataclasses import dataclass, field
from typing import List

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.request import Phase, Request, SLOSpec
from repro.models import build_model
from repro.policies import available_policies, make_router
from repro.serving.clock import ManualClock
from repro.serving.engine import DisaggServer, EngineConfig
from repro.serving.frontend import AsyncServeSession
from repro.serving.kvcache import SlotAllocator
from repro.serving.prefixcache import PrefixCache
from repro.serving.router import RouterSession
from repro.serving.session import ServeSession


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _server(tiny_model, **ecfg_kw):
    cfg, model, params = tiny_model
    kw = dict(max_slots=4, max_len=64, chunk_size=16)
    kw.update(ecfg_kw)
    return DisaggServer(
        model, params, EngineConfig(**kw), clock=ManualClock(auto_step=1e-4)
    )


def _requests(cfg, n=4, max_out=4, seed=0, arrival_gap=0.0):
    rng = np.random.default_rng(seed)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, int(rng.integers(4, 14)))))
               for _ in range(n)]
    return [
        (
            Request(rid=i, arrival=arrival_gap * i, input_len=len(p), output_len=max_out,
                    slo=SLOSpec(ttft=120.0, tpot=10.0)),
            p,
        )
        for i, p in enumerate(prompts)
    ]


# ------------------------------------------------------------- prefix trie
def test_prefix_trie_match_and_accounting():
    pc = PrefixCache(block=4)
    assert pc.admit(list(range(12))) == (0, 12)  # cold: 3 blocks inserted
    # same 8-token head, new tail: 2 blocks hit, third diverges
    assert pc.admit([*range(8), 99, 98, 97, 96]) == (8, 12)
    assert pc.match(list(range(12))) == 12
    assert pc.match([*range(8), 1, 1, 1, 1]) == 8
    assert pc.match([7] * 12) == 0
    # partial final block never matches (only full blocks are keyed)
    assert pc.match(list(range(6))) == 4
    s = pc.stats
    assert s.lookups == 2 and s.hits == 1
    assert s.hit_tokens == 8 and s.lookup_tokens == 24
    assert s.hit_rate == pytest.approx(8 / 24)


def test_prefix_trie_short_prompt_has_no_full_block():
    pc = PrefixCache(block=16)
    assert pc.admit([1, 2, 3]) == (0, 0)
    assert pc.match([1, 2, 3]) == 0
    assert len(pc) == 0  # nothing inserted, nothing to match later


def test_prefix_trie_lru_evicts_leaves_first():
    pc = PrefixCache(block=2, max_blocks=3)
    pc.admit([1, 2, 3, 4, 5, 6, 7, 8])  # 4 blocks -> one eviction
    assert len(pc) == 3 and pc.stats.evicted_blocks == 1
    # the evicted block is the LRU *leaf*: the deepest suffix goes first,
    # so every surviving block still has its whole prefix chain
    assert pc.match([1, 2, 3, 4, 5, 6, 7, 8]) == 6


def test_prefix_trie_validates_args():
    with pytest.raises(ValueError):
        PrefixCache(block=0)
    with pytest.raises(ValueError):
        PrefixCache(block=4, max_blocks=0)


# ----------------------------------------------------------- slot allocator
def test_restore_preserves_free_list_order():
    """Regression: restore() used to rebuild `free` as a canonical
    descending range, so a snapshot/restore round-trip handed out
    *different* slot ids than an allocator that never snapshotted —
    breaking the replay determinism router failover relies on."""
    a = SlotAllocator(max_slots=4, kv_cap_tokens=1000)
    s0, s1, s2 = a.alloc(1), a.alloc(1), a.alloc(1)
    a.release(s0)
    a.release(s2)  # free order now [3, 0, 2]: NOT the canonical [3, 2, 0]
    snap = a.snapshot()

    b = SlotAllocator(max_slots=4, kv_cap_tokens=1000)
    b.restore(snap)
    assert b.free == a.free  # persisted verbatim, not re-synthesized
    # identical future slot ids with and without the round-trip
    assert [a.alloc(1) for _ in range(3)] == [b.alloc(1) for _ in range(3)]
    assert s1 is not None


def test_used_tokens_counter_tracks_sum():
    """used_tokens must be O(1) bookkeeping, and must agree with the
    re-summed live set through alloc/release/restore churn."""
    a = SlotAllocator(max_slots=8, kv_cap_tokens=500)
    rng = np.random.default_rng(0)
    live: List[int] = []
    for _ in range(100):
        if live and rng.random() < 0.4:
            a.release(live.pop(int(rng.integers(len(live)))))
        else:
            slot = a.alloc(int(rng.integers(1, 80)))
            if slot is not None:
                live.append(slot)
        assert a.used_tokens == sum(a.live_tokens.values())
    snap = a.snapshot()
    b = SlotAllocator(max_slots=8, kv_cap_tokens=500)
    b.restore(snap)
    assert b.used_tokens == sum(b.live_tokens.values()) == a.used_tokens


def test_alloc_credit_charges_need_minus_credit():
    a = SlotAllocator(max_slots=4, kv_cap_tokens=100)
    s = a.alloc(90, credit=30)
    assert s is not None and a.used_tokens == 60
    assert a.can_admit(50, credit=10)  # 60 + 40 <= 100
    assert not a.can_admit(50, credit=9)
    # over-credit clamps at zero, never goes negative
    s2 = a.alloc(10, credit=999)
    assert s2 is not None and a.used_tokens == 60
    a.release(s)
    a.release(s2)
    assert a.used_tokens == 0


# --------------------------------------------------- admission-shed split
def test_rejected_split_global_vs_tenant(tiny_model):
    server = _server(tiny_model)
    sess = ServeSession(server, max_queue_depth=2, tenant_queue_depth=1)

    def req(rid, tenant):
        return Request(rid=rid, arrival=0.0, input_len=3, output_len=2,
                       slo=SLOSpec(ttft=120.0, tpot=10.0), tenant=tenant)

    assert sess.submit(req(0, "a"), [5, 6, 7])
    assert not sess.submit(req(1, "a"), [5, 6, 7])  # tenant quota (global ok)
    assert sess.submit(req(2, "b"), [5, 6, 7])
    assert not sess.submit(req(3, "c"), [5, 6, 7])  # global bound (fleet full)
    m = sess.metrics
    assert m.rejected_tenant == 1 and m.rejected_global == 1
    assert m.rejected == m.rejected_global + m.rejected_tenant == 2
    s = sess.summary()
    assert s["rejected"] == 2
    assert s["rejected_global"] == 1 and s["rejected_tenant"] == 1


# ------------------------------------------------- prefix-aware admission
def test_session_prefix_admission_accounting_and_credit(tiny_model):
    server = _server(tiny_model)
    sess = ServeSession(server, prefix_cache=PrefixCache(block=4))
    shared = [9, 8, 7, 6, 5, 4, 3, 2]

    def req(rid, n):
        return Request(rid=rid, arrival=0.0, input_len=n, output_len=2,
                       slo=SLOSpec(ttft=120.0, tpot=10.0))

    r0, r1 = req(0, 10), req(1, 10)
    assert sess.submit(r0, [*shared, 50, 51])
    assert sess.submit(r1, [*shared, 60, 61])
    assert r0.prefix_hit_tokens == 0
    assert r1.prefix_hit_tokens == 8  # two shared full blocks
    m = sess.metrics
    assert m.prefix_lookups == 2 and m.prefix_hits == 1
    assert m.prefix_hit_tokens == 8 and m.prefix_lookup_tokens == 16
    while sess.has_work:
        sess.step()
    # token outputs are invariant to the cache: full prefill still ran
    assert r0.phase == r1.phase == Phase.DONE
    assert sess.summary()["prefix"]["hit_rate"] == pytest.approx(0.5)


# --------------------------------------------------------- router policies
@dataclass
class _FakeReplica:
    in_flight: int = 0
    pending_prefill_tokens: int = 0
    mu: float = 1000.0
    prefixes: List[int] = field(default_factory=list)  # canned match lengths

    def prefix_match(self, prompt):
        return self.prefixes.pop(0) if self.prefixes else 0


def _req(input_len=8):
    return Request(rid=0, arrival=0.0, input_len=input_len, output_len=4)


def test_router_registry_and_round_robin():
    assert set(available_policies()["router"]) == {
        "round-robin", "least-queued", "slack-aware", "prefix-affinity"
    }
    rr = make_router("round-robin")
    reps = [_FakeReplica(), _FakeReplica(), _FakeReplica()]
    assert [rr.select(reps, _req(), []) for _ in range(5)] == [0, 1, 2, 0, 1]
    with pytest.raises(ValueError, match="router"):
        make_router("nope")


def test_least_queued_picks_min_in_flight():
    pol = make_router("least-queued")
    reps = [_FakeReplica(in_flight=3), _FakeReplica(in_flight=1), _FakeReplica(in_flight=2)]
    assert pol.select(reps, _req(), []) == 1
    reps[1].in_flight = 3
    assert pol.select(reps, _req(), []) == 2


def test_slack_aware_uses_backlog_over_throughput():
    pol = make_router("slack-aware")
    # replica 0: small backlog but slow; replica 1: bigger backlog, much faster
    reps = [
        _FakeReplica(pending_prefill_tokens=100, mu=100.0),   # eta (100+8)/100 ~ 1.08
        _FakeReplica(pending_prefill_tokens=400, mu=1000.0),  # eta (400+8)/1000 ~ 0.41
    ]
    assert pol.select(reps, _req(input_len=8), []) == 1


def test_prefix_affinity_routes_to_match_else_balances():
    pol = make_router("prefix-affinity")
    reps = [_FakeReplica(in_flight=0, prefixes=[0]), _FakeReplica(in_flight=5, prefixes=[8])]
    assert pol.select(reps, _req(), list(range(8))) == 1  # match beats load
    reps = [_FakeReplica(in_flight=5, prefixes=[0]), _FakeReplica(in_flight=0, prefixes=[0])]
    assert pol.select(reps, _req(), list(range(8))) == 1  # no match: balance


# ---------------------------------------------------------- router session
def test_cross_replica_cancel_reclaims_owning_replica_only(tiny_model):
    """A client that disconnects mid-stream from a routed request reclaims
    the decode slot on the OWNING replica only; the other replica's stream
    runs to completion undisturbed."""
    servers = [_server(tiny_model) for _ in range(2)]
    (r0, p0), (r1, p1) = _requests(tiny_model[0], n=2, max_out=6, seed=5)

    async def run():
        router = RouterSession(servers, policy="round-robin")
        async with router:
            h0 = await router.submit(r0, p0)
            h1 = await router.submit(r1, p1)

            async def disconnect_after_first(h):
                async for _ in h.stream():
                    break  # client walks away mid-stream

            async def drain(h):
                async for _ in h.stream():
                    pass

            await asyncio.gather(disconnect_after_first(h0), drain(h1))
        return router

    router = asyncio.run(run())
    assert router.owner_of(r0.rid) == 0 and router.owner_of(r1.rid) == 1
    assert r0.phase == Phase.CANCELLED and r0.n_generated >= 1
    assert r1.phase == Phase.DONE
    own, other = router.replicas[0].frontend.session, router.replicas[1].frontend.session
    assert own.metrics.cancelled == 1 and other.metrics.cancelled == 0
    assert other.metrics.completed == 1
    for sess, srv in zip((own, other), servers, strict=True):
        assert sess.queue == [] and sess.waiting_adm == [] and sess.active == []
        assert srv.decode.alloc.live_tokens == {}
    assert len(router.outputs[r1.rid]) == r1.n_generated
    s = router.summary()
    assert s["cancelled"] == 1 and s["completed"] == 1
    assert s["routing"]["assigned"] == [1, 1]


def test_router_cancel_by_rid_and_unknown_rid(tiny_model):
    servers = [_server(tiny_model) for _ in range(2)]
    (r0, p0), = _requests(tiny_model[0], n=1, max_out=4, seed=6)

    async def run():
        router = RouterSession(servers, policy="least-queued")
        async with router:
            h = await router.submit(r0, p0)
            assert await h.admitted()
            assert router.cancel(r0.rid) is True
            assert router.cancel(999) is False
            await h.result()  # stream terminates via the cancel EOS
        return router

    router = asyncio.run(run())
    assert r0.phase == Phase.CANCELLED
    assert router.summary()["cancelled"] == 1


def test_replica_crash_surfaces_after_others_drain(tiny_model):
    """One replica's engine crash must re-raise out of drain() — but only
    after the healthy replicas finished their work (no orphaned steppers,
    no lost completions on the survivors)."""
    servers = [_server(tiny_model) for _ in range(2)]
    (r0, p0), (r1, p1) = _requests(tiny_model[0], n=2, max_out=2, seed=12)

    def boom(*a, **kw):
        raise RuntimeError("replica exploded")

    async def run():
        router = RouterSession(servers, policy="round-robin")
        router.replicas[0].frontend.session.step = boom
        outs = {}
        with pytest.raises(RuntimeError, match="replica exploded"):
            async with router:
                h0 = await router.submit(r0, p0)
                h1 = await router.submit(r1, p1)
                outs[0] = [t async for t in h0.stream()]  # EOS on crash
                outs[1] = [t async for t in h1.stream()]
        return outs

    outs = asyncio.run(asyncio.wait_for(run(), timeout=60))
    assert outs[0] == []  # the crashed replica delivered nothing
    assert r1.phase == Phase.DONE and outs[1]  # the survivor completed


def test_single_replica_router_is_bit_identical_to_frontend(tiny_model):
    """ManualClock determinism: routing through a 1-replica RouterSession
    must reproduce the bare AsyncServeSession replay bit-for-bit — the
    router adds no clock reads of its own."""
    pairs_direct = _requests(tiny_model[0], n=5, max_out=4, seed=2, arrival_gap=0.01)
    pairs_routed = copy.deepcopy(pairs_direct)

    async def run_direct():
        frontend = AsyncServeSession(_server(tiny_model))
        async with frontend:
            return await frontend.replay(pairs_direct, clients=3)

    async def run_routed():
        router = RouterSession([_server(tiny_model)], policy="round-robin")
        async with router:
            return await router.replay(pairs_routed, clients=3)

    outs_direct = asyncio.run(run_direct())
    outs_routed = asyncio.run(run_routed())
    assert outs_direct == outs_routed
    for (rd, _), (rr, _) in zip(pairs_direct, pairs_routed, strict=True):
        assert rd.phase == rr.phase == Phase.DONE
        # exact equality: same virtual clock reads in the same order
        assert rd.ttft() == rr.ttft()
        assert rd.mean_tpot() == rr.mean_tpot()
        assert rd.token_times == rr.token_times


# ------------------------------------------------------- harness + loadgen
@pytest.mark.parametrize("scenario", ["multi-tenant", "prefix-heavy"])
def test_harness_router_backend_one_replica_matches_async_engine(scenario):
    """The acceptance criterion at the report level: the router cell with 1
    replica carries exactly the async-engine cell's attainment — including
    on prefix-heavy, where the replica's prefix cache is actively granting
    KV credits (timing-neutral while the default kv cap stays slack)."""
    from repro.workloads.harness import HarnessConfig, evaluate_cell

    hcfg = HarnessConfig(n_requests=10, router_replicas=1, router_policy="round-robin")
    async_cell = evaluate_cell(scenario, "kairos-urgency", "kairos-slack",
                               "async-engine", hcfg=hcfg)
    router_cell = evaluate_cell(scenario, "kairos-urgency", "kairos-slack",
                                "router", hcfg=hcfg)
    assert router_cell["backend"] == "router"
    assert router_cell["attainment"] == async_cell["attainment"]
    assert router_cell["per_tenant"] == async_cell["per_tenant"]
    assert router_cell["goodput"] == async_cell["goodput"]
    rb = router_cell["router"]
    assert rb["replicas"] == 1 and rb["policy"] == "round-robin"
    assert sum(p["assigned"] for p in rb["per_replica"]) == router_cell["n_requests"]
    if scenario == "prefix-heavy":
        assert rb["prefix"]["hit_rate"] > 0  # the credit really was active


def test_prefix_affinity_beats_round_robin_hit_rate():
    """The fleet-level claim: on the prefix-heavy scenario with 2 replicas,
    prefix-affinity routing achieves a strictly higher session prefix
    hit-rate than round-robin (which scatters every group across replicas,
    paying the cold miss per group per replica)."""
    import dataclasses

    from repro.workloads.harness import HarnessConfig, evaluate_cell

    base = HarnessConfig(n_requests=24, router_replicas=2)
    cells = {}
    for policy in ("round-robin", "prefix-affinity"):
        hcfg = dataclasses.replace(base, router_policy=policy)
        cells[policy] = evaluate_cell(
            "prefix-heavy", "kairos-urgency", "kairos-slack", "router", hcfg=hcfg
        )
    rates = {k: c["router"]["prefix"]["hit_rate"] for k, c in cells.items()}
    assert rates["prefix-affinity"] > rates["round-robin"], rates
    assert rates["round-robin"] > 0  # shared prefixes hit even when scattered
    for c in cells.values():
        rb = c["router"]
        assert sum(p["assigned"] for p in rb["per_replica"]) == c["n_requests"]
        assert sum(p["completed"] for p in rb["per_replica"]) == c["n_completed"]


def test_prefix_heavy_scenario_stamps_groups():
    from repro.workloads.scenarios import make_scenario

    reqs = make_scenario("prefix-heavy", n_requests=30, n_groups=3).generate(0)
    groups = {r.prefix_group for r in reqs}
    assert groups <= {"app-0", "app-1", "app-2"} and len(groups) >= 2
    assert all(r.prefix_frac == 0.7 for r in reqs)
    # determinism: same seed, same trace
    again = make_scenario("prefix-heavy", n_requests=30, n_groups=3).generate(0)
    assert [(r.rid, r.arrival, r.input_len, r.prefix_group) for r in reqs] == \
           [(r.rid, r.arrival, r.input_len, r.prefix_group) for r in again]


def test_twin_prompts_share_group_prefixes():
    import numpy as np

    from repro.workloads.harness import (
        HarnessConfig,
        _group_prefix_tokens,
        to_engine_requests,
    )
    from repro.workloads.scenarios import make_scenario

    reqs = make_scenario("prefix-heavy", n_requests=30).generate(1)
    pairs = to_engine_requests(reqs, HarnessConfig(), 256, np.random.default_rng(1))
    assert {r.prefix_group for r, _ in pairs} != {""}
    for r, p in pairs:
        # every prompt literally begins with its group's template (cut to
        # this request's own head length — shorter prompts share less)
        k = min(r.input_len - 1, round(r.input_len * r.prefix_frac))
        assert p[:k] == _group_prefix_tokens(r.prefix_group, k, 256)
        assert len(p) == r.input_len


def test_loadgen_cli_router_fleet(tmp_path):
    from repro.launch import loadgen

    out = tmp_path / "router-report.json"
    report = loadgen.main([
        "--scenario", "prefix-heavy", "--n", "10", "--clients", "2",
        "--servers", "2", "--router", "prefix-affinity", "--out", str(out),
    ])
    assert out.exists()
    cell, = report["cells"]
    assert cell["backend"] == "router"
    for key in ("attainment", "per_tenant", "goodput", "shed", "cancelled", "loadgen"):
        assert key in cell
    rb = cell["router"]
    assert rb["policy"] == "prefix-affinity" and rb["replicas"] == 2
    assert sum(rb["assigned"]) == cell["n_requests"]
    assert len(rb["per_replica"]) == 2
