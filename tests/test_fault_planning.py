"""Fault-tolerance policy layer: re-mesh planning under pod degradation."""
import pytest

from repro.dist.fault import FleetState, plan_mesh, plan_recovery


def test_healthy_fleet_keeps_production_mesh():
    plan = plan_mesh(FleetState(pods=(256, 256)))
    assert plan.shape == (2, 16, 16)
    assert plan.axes == ("pod", "data", "model")
    assert not plan.dropped_pods


def test_partial_pod_clamps_rectangle():
    plan = plan_mesh(FleetState(pods=(256, 200)))  # pod 1 lost 56 chips
    assert plan.shape == (2, 12, 16)  # 12*16=192 <= 200, common slice
    assert plan.chips == 384


def test_dying_pod_is_shed():
    plan = plan_mesh(FleetState(pods=(256, 100)))  # below 50% health
    assert plan.shape == (16, 16)
    assert plan.axes == ("data", "model")
    assert plan.dropped_pods == (1,)


def test_all_dead_raises():
    with pytest.raises(RuntimeError):
        plan_mesh(FleetState(pods=(10, 10)))


def test_recovery_plan_narrative():
    rec = plan_recovery(FleetState(pods=(256, 120)))
    steps = rec.describe()
    assert any("shed pods" in s for s in steps)
    assert any("reset_for_restart" in s for s in steps)
    assert any("checkpoint" in s for s in steps)


def test_planned_mesh_is_constructible():
    """The policy's output must be buildable by the mechanism layer."""
    import jax
    from repro.launch.mesh import make_mesh

    plan = plan_mesh(FleetState(pods=(256,)))
    n = len(jax.devices())
    # scale the plan down to the test host's device count shape-compatibly
    mesh = make_mesh((1, n), ("data", "model"))
    assert mesh.shape["model"] == n
