"""Roofline machinery: loop-aware HLO stats exactness + term math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import RooflineTerms, model_flops_for, parse_collective_bytes
from repro.roofline.hlo_stats import analyze
from repro.roofline.hw import V5E
from repro.configs import ALL_SHAPES, get_config


def test_hlo_stats_scan_flops_exact():
    n, iters = 256, 7
    w = jnp.zeros((n, n), jnp.float32)

    def fn(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=iters)
        return y

    c = jax.jit(fn).lower(jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    st = analyze(c.as_text())
    assert st.flops == pytest.approx(2 * n**3 * iters, rel=1e-6)
    assert st.while_trips == [iters]


def test_hlo_stats_nested_loops():
    n = 128
    w = jnp.zeros((n, n), jnp.float32)

    def fn(x):
        def outer(c, _):
            c2, _ = jax.lax.scan(lambda d, __: (d @ w, None), c, None, length=4)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    c = jax.jit(fn).lower(jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    st = analyze(c.as_text())
    assert st.flops == pytest.approx(2 * n**3 * 12, rel=1e-6)
    assert sorted(st.while_trips) == [3, 4]


def test_hlo_stats_bytes_nonzero_and_bounded():
    n = 256
    c = jax.jit(lambda x: (x @ x).sum()).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32)
    ).compile()
    st = analyze(c.as_text())
    assert st.bytes_accessed >= 2 * n * n * 4  # at least read input + write out
    assert st.bytes_accessed < 100 * n * n * 4


def test_roofline_terms_math():
    t = RooflineTerms(
        arch="x", shape="train_4k", mesh="single", chips=256,
        flops_global=256 * 197e12,  # exactly 1 s of compute
        bytes_global=256 * 819e9 * 0.5,  # 0.5 s of HBM
        collective_bytes_per_chip=200e9 * 0.25,  # 0.25 s of ICI
        model_flops=128 * 197e12,
    )
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.collective_s == pytest.approx(0.25)
    assert t.dominant == "compute"
    assert t.useful_flops_frac == pytest.approx(0.5)
    assert t.roofline_frac == pytest.approx(0.5)


def test_model_flops_definitions():
    cfg = get_config("llama3-8b")
    n = cfg.count_params()
    assert model_flops_for(cfg, ALL_SHAPES["train_4k"]) == pytest.approx(
        6.0 * n * 256 * 4096
    )
    assert model_flops_for(cfg, ALL_SHAPES["decode_32k"]) == pytest.approx(2.0 * n * 128)
    moe = get_config("grok-1-314b")
    assert model_flops_for(moe, ALL_SHAPES["train_4k"]) == pytest.approx(
        6.0 * moe.count_active_params() * 256 * 4096
    )


def test_collective_parse_on_real_program():
    from repro.launch.mesh import make_mesh
    n_dev = len(jax.devices())
    if n_dev < 2:
        pytest.skip("needs >1 device")
    mesh = make_mesh((n_dev, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = NamedSharding(mesh, P("data", None))
    c = (
        jax.jit(lambda x: x.sum(), in_shardings=(sh,))
        .lower(jax.ShapeDtypeStruct((n_dev * 4, 8), jnp.float32))
        .compile()
    )
    parsed = parse_collective_bytes(c.as_text())
    assert parsed["count"] >= 1
