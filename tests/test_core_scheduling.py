"""Unit tests for the paper's algorithms (host implementations)."""
import numpy as np
import pytest

from repro.core.lut import StepTimeLUT
from repro.core.pacer import DeliveryPacer
from repro.core.predictor import (
    PrefillThroughputEstimator,
    predict_all_finish_times,
    predict_finish_time_fcfs,
)
from repro.core.request import Phase, Request, SLOSpec
from repro.policies import (
    ContinuousBatchingScheduler,
    FCFSPrefillScheduler,
    SJFPrefillScheduler,
    SlackDecodeScheduler,
    UrgencyPlusPrefillScheduler,
    UrgencyPrefillScheduler,
)


def mk_req(rid, arrival, input_len, output_len=64, ttft=8.0, tpot=0.05):
    return Request(
        rid=rid, arrival=arrival, input_len=input_len, output_len=output_len,
        slo=SLOSpec(ttft=ttft, tpot=tpot),
    )


# ---------------------------------------------------------------- predictor

def test_fcfs_finish_matches_verbatim_algorithm(rng):
    queue = [
        mk_req(i, float(rng.uniform(0, 10)), int(rng.integers(100, 50_000)))
        for i in range(40)
    ]
    mu = 20_000.0
    t_now = 5.0
    fast = predict_all_finish_times(queue, t_now, mu)
    for i, r in enumerate(queue):
        slow = predict_finish_time_fcfs(queue, r, t_now, mu)
        assert fast[i] == pytest.approx(slow, rel=1e-12)


def test_fcfs_finish_monotone_in_arrival():
    queue = [mk_req(i, float(i), 1000) for i in range(10)]
    fin = predict_all_finish_times(queue, 0.0, 10_000.0)
    assert np.all(np.diff(fin) >= 0)


def test_throughput_estimator_ewma():
    est = PrefillThroughputEstimator(mu=1000.0)
    est.update(2000, 1.0)  # first obs replaces the seed
    assert est.mu == pytest.approx(2000.0)
    est.update(1000, 1.0)
    assert 1000 < est.mu < 2000
    est.update(0, 1.0)  # ignored
    est.update(10, 0.0)  # ignored


# ------------------------------------------------------------------ urgency

def test_urgency_budget_and_partial_chunk():
    queue = [mk_req(0, 0.0, 5000), mk_req(1, 0.1, 300), mk_req(2, 0.2, 400)]
    sched = UrgencyPrefillScheduler()
    sel = sched.select(queue, 1.0, 10_000.0, budget=1000)
    assert sum(t for _, t in sel) <= 1000
    total = sum(t for _, t in sel)
    assert total == 1000  # budget filled (work exceeds budget)
    # shorts (positive slack, small len) must precede the long request
    order = [r.rid for r, _ in sel]
    assert order.index(1) < order.index(0)
    assert order.index(2) < order.index(0)


def test_urgency_prefers_short_requests_at_equal_slack():
    # paper's worked example: long arrived first, but the short's score is
    # amplified by 1/len
    long_r = mk_req(0, 0.0, 131_072)
    short_r = mk_req(1, 0.5, 8_192)
    sched = UrgencyPrefillScheduler()
    sel = sched.select([long_r, short_r], 1.0, 20_000.0, budget=8192)
    assert sel[0][0].rid == 1


def test_urgency_negative_slack_inversion_documented():
    """As printed, late (negative-slack) requests invert: the LONGEST ranks
    first among them. This documents the pathology that urgency-plus fixes."""
    mu = 10_000.0
    # the long request makes everyone's FCFS-predicted slack negative
    long_r = mk_req(0, 0.0, 100_000, ttft=8.0)
    shorts = [mk_req(i, 0.01 * i, 500, ttft=8.0) for i in range(1, 4)]
    queue = [long_r, *shorts]
    sched = UrgencyPrefillScheduler()
    scores = sched.urgency_scores(queue, 0.5, mu)
    assert np.all(scores < 0)
    assert np.argmax(scores) == 0  # the long ranks first — inversion

    plus = UrgencyPlusPrefillScheduler()
    sel = plus.select(queue, 0.5, mu, budget=2000)
    # the rescuable shorts go first under the fixed policy (most-behind first
    # within the tier); the long is pushed behind them
    assert set(r.rid for r, _ in sel[:3]) == {1, 2, 3}


def test_fcfs_and_sjf_order():
    queue = [mk_req(0, 0.0, 5000), mk_req(1, 0.1, 100)]
    assert [r.rid for r, _ in FCFSPrefillScheduler().select(queue, 1.0, 1e4, 10_000)] == [0, 1]
    assert [r.rid for r, _ in SJFPrefillScheduler().select(queue, 1.0, 1e4, 10_000)] == [1, 0]


# -------------------------------------------------------------------- slack

def analytic(b, s):
    return 0.005 + 0.0002 * b + 2.4e-7 * s


def active_req(rid, seq, n_gen, t_first, tpot=0.05):
    r = mk_req(rid, 0.0, seq, output_len=1000, tpot=tpot)
    r.first_token_time = t_first
    r.decode_start = t_first
    r.n_generated = n_gen
    r.n_decoded = n_gen
    r.phase = Phase.DECODE
    return r


def test_slack_packs_shorts_and_delays_straggler():
    lut = StepTimeLUT(analytic=analytic)
    sched = SlackDecodeScheduler(lut, slo_margin=1.0)
    t = 10.0
    shorts = [active_req(i, 2000, 10, t - 0.2) for i in range(20)]  # big bank
    straggler = active_req(99, 131_072, 10, t - 0.2)
    batch, delayed = sched.select([*shorts, straggler], t)
    assert straggler not in batch
    assert len(batch) >= 10


def test_slack_fallback_decodes_all():
    lut = StepTimeLUT(analytic=analytic)
    sched = SlackDecodeScheduler(lut, slo_margin=1.0)
    t = 10.0
    # zero bank: elapsed exactly n_gen * tpot, so s ~ tpot - t1 < t_step
    reqs = [active_req(i, 100_000, 10, t - 10 * 0.05) for i in range(4)]
    batch, delayed = sched.select(reqs, t)
    assert len(batch) == len(reqs) and not delayed


def test_slack_eq2_value():
    lut = StepTimeLUT(analytic=analytic)
    sched = SlackDecodeScheduler(lut, slo_margin=1.0, actionable_slack=False)
    r = active_req(0, 4096, 3, t_first=100.0)
    s = sched.slack(r, 100.1)
    expected = 0.05 * 4 - 0.1 - lut.lookup(1, 4096)
    assert s == pytest.approx(expected, rel=1e-9)


def test_continuous_batching_takes_everything():
    lut = StepTimeLUT(analytic=analytic)
    sched = ContinuousBatchingScheduler(lut)
    reqs = [active_req(i, 1000 * (i + 1), 5, 9.0) for i in range(7)]
    batch, delayed = sched.select(reqs, 10.0)
    assert len(batch) == 7 and not delayed


# ---------------------------------------------------------------------- LUT

def test_lut_running_mean_and_fallback():
    lut = StepTimeLUT(analytic=analytic, seed_offline=False)
    assert lut.lookup(4, 10_000) == pytest.approx(analytic(4, 10_000))
    lut.update(4, 10_000, 0.05)
    lut.update(4, 10_000, 0.07)
    assert lut.lookup(4, 10_000) == pytest.approx(0.06)
    # bucket neighbors unaffected
    assert lut.lookup(64, 10_000) == pytest.approx(analytic(64, 10_000))


def test_lut_seeded_offline_counts_as_observation():
    lut = StepTimeLUT(analytic=analytic)
    seed = analytic(1, 512)
    lut.update(1, 512, 3 * seed)
    assert lut.lookup(1, 512) == pytest.approx(2 * seed)


def test_lut_state_roundtrip():
    lut = StepTimeLUT(analytic=analytic)
    lut.update(2, 2000, 0.123)
    st = lut.state_dict()
    lut2 = StepTimeLUT(analytic=analytic)
    lut2.load_state_dict(st)
    assert lut2.lookup(2, 2000) == pytest.approx(lut.lookup(2, 2000))


# -------------------------------------------------------------------- pacer

def test_pacer_immediate_passthrough():
    p = DeliveryPacer(mode="immediate")
    times = [1.0, 1.01, 1.02]
    assert p.delivery_times(times, 1.0, 0.05) == times


def test_pacer_paced_monotone_and_slo_safe():
    p = DeliveryPacer(mode="paced", pace_fraction=0.9)
    gen = [1.0, 1.001, 1.002, 1.003, 2.0]
    out = p.delivery_times(gen, 1.0, 0.05)
    assert all(b >= a for a, b in zip(out, out[1:], strict=False))
    assert all(d >= g for d, g in zip(out, gen, strict=True))
    # mean ITL within the SLO
    itl = (out[-1] - out[0]) / (len(out) - 1)
    assert itl <= 0.05 * 5  # loose: late generation dominates


# ------------------------------------------------------------------ request

def test_request_metrics():
    r = mk_req(0, 10.0, 100, output_len=3)
    r.first_token_time = 11.0
    r.token_times = [11.0, 11.04, 11.08]
    r.n_generated = 3
    r.done_time = 11.08
    r.phase = Phase.DONE
    assert r.ttft() == pytest.approx(1.0)
    assert r.mean_tpot() == pytest.approx(0.04)
    assert r.meets_ttft() and r.meets_tpot() and r.meets_e2e()
    assert r.decode_tput() == pytest.approx(3 / 0.08)


def test_request_restart_resets_prefill():
    r = mk_req(0, 0.0, 100)
    r.prefilled_tokens = 100
    r.prefill_finish = 1.0
    r.decode_start = 1.5
    r.reset_for_restart()
    assert r.remaining_prefill_tokens == 100
    assert r.restarts == 1
    assert r.decode_start is None
