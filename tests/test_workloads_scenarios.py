"""Scenario registry: determinism per seed, trace validity for every
registered name, tenant/SLO structure, arrival-process shape, trace
save/load round-trip, and the shed-inclusive attainment semantics."""
import json

import numpy as np
import pytest

from repro.core.request import Phase, Request, SLOSpec
from repro.sim.metrics import attainment, attainment_by, goodput
from repro.sim.trace import TraceConfig, generate_trace, load_trace, save_trace
from repro.workloads import (
    MarkovModulatedArrivals,
    PoissonArrivals,
    SinusoidalArrivals,
    available_scenarios,
    generate_scenario,
    make_scenario,
)


@pytest.fixture(scope="module")
def replay_path(tmp_path_factory):
    p = tmp_path_factory.mktemp("traces") / "replay.jsonl"
    save_trace(str(p), generate_scenario("multi-tenant", seed=5, n_requests=30))
    return str(p)


def _kwargs_for(name, replay_path):
    if name == "replay":
        return {"path": replay_path}
    return {"n_requests": 60}


def _fingerprint(reqs):
    return [
        (r.arrival, r.input_len, r.output_len, r.tenant, r.slo_class, r.slo.ttft, r.slo.tpot)
        for r in reqs
    ]


# ---------------------------------------------------------------- registry
def test_registry_has_the_six_builtins():
    names = available_scenarios()
    for expected in ("paper-longtail", "bursty", "diurnal", "multi-tenant",
                     "heavy-head", "replay"):
        assert expected in names


def test_every_registered_scenario_generates_a_valid_trace(replay_path):
    for name in available_scenarios():
        reqs = make_scenario(name, **_kwargs_for(name, replay_path)).generate(seed=0)
        assert len(reqs) > 0, name
        arrivals = [r.arrival for r in reqs]
        assert all(b >= a for a, b in zip(arrivals, arrivals[1:], strict=False)), name
        for r in reqs:
            assert isinstance(r, Request)
            assert r.arrival >= 0.0
            assert r.input_len > 0 and r.output_len > 0
            assert r.slo.ttft > 0 and r.slo.tpot > 0
            assert r.tenant and r.slo_class
            assert r.phase == Phase.QUEUED


def test_every_registered_scenario_is_deterministic_per_seed(replay_path):
    """Property over the whole registry: same seed -> identical trace."""
    for name in available_scenarios():
        kw = _kwargs_for(name, replay_path)
        a = make_scenario(name, **kw).generate(seed=7)
        b = make_scenario(name, **kw).generate(seed=7)
        assert _fingerprint(a) == _fingerprint(b), name
        if name != "replay":  # a replay ignores the seed by design
            c = make_scenario(name, **kw).generate(seed=8)
            assert _fingerprint(a) != _fingerprint(c), name


def test_unknown_scenario_raises_with_known_names():
    with pytest.raises(ValueError, match="multi-tenant"):
        make_scenario("nope")


def test_replay_without_path_raises():
    with pytest.raises(ValueError, match="path"):
        make_scenario("replay")


def test_paper_longtail_matches_generate_trace_bit_for_bit():
    old = generate_trace(TraceConfig(n_requests=80, qps=3.0, seed=11))
    new = generate_scenario("paper-longtail", seed=11, n_requests=80)
    assert _fingerprint(old) == _fingerprint(new)


# ------------------------------------------------------------- structure
def test_multi_tenant_has_distinct_tenants_and_slo_classes():
    reqs = generate_scenario("multi-tenant", seed=2, n_requests=300)
    tenants = {r.tenant for r in reqs}
    assert tenants == {"interactive", "standard", "batch"}
    by_class = {r.tenant: r.slo_class for r in reqs}
    assert by_class == {"interactive": "premium", "standard": "standard", "batch": "batch"}
    # distinct SLO targets and length distributions per tenant
    slos = {r.tenant: (r.slo.ttft, r.slo.tpot) for r in reqs}
    assert len(set(slos.values())) == 3
    mean_len = {
        t: np.mean([r.input_len for r in reqs if r.tenant == t]) for t in tenants
    }
    assert mean_len["interactive"] < mean_len["standard"] < mean_len["batch"]


def test_flash_crowd_spikes_at_t_crowd():
    reqs = generate_scenario(
        "flash-crowd", seed=2, n_requests=200, t_crowd=10.0, crowd_qps=40.0,
        qps_base=2.0, crowd_frac=0.5,
    )
    assert {r.tenant for r in reqs} == {"steady", "crowd"}
    crowd = [r for r in reqs if r.tenant == "crowd"]
    assert len(crowd) == 100
    assert all(r.slo_class == "premium" for r in crowd)
    assert min(r.arrival for r in crowd) >= 10.0
    # the spike is a spike: crowd arrivals pack into a far shorter span
    # than the same count of steady traffic
    crowd_span = max(r.arrival for r in crowd) - min(r.arrival for r in crowd)
    assert crowd_span < 0.25 * (100 / 2.0)
    # rids follow global arrival order (the harness contract)
    arrivals = [r.arrival for r in sorted(reqs, key=lambda r: r.rid)]
    assert arrivals == sorted(arrivals)


def test_flash_crowd_validation():
    with pytest.raises(ValueError):
        generate_scenario("flash-crowd", seed=0, n_requests=10, crowd_frac=1.5)
    with pytest.raises(ValueError):
        generate_scenario("flash-crowd", seed=0, n_requests=10, crowd_qps=0.0)


def test_heavy_head_is_heavier_than_paper_longtail():
    heavy = generate_scenario("heavy-head", seed=2, n_requests=400)
    paper = generate_scenario("paper-longtail", seed=2, n_requests=400)
    assert np.mean([r.input_len for r in heavy]) > np.mean([r.input_len for r in paper])


def test_bursty_arrivals_are_burstier_than_poisson():
    rng = np.random.default_rng(0)
    mmpp = MarkovModulatedArrivals().times(2000, rng)
    rng = np.random.default_rng(0)
    pois = PoissonArrivals(qps=3.0).times(2000, rng)

    def cv(ts):
        gaps = np.diff(ts)
        return np.std(gaps) / np.mean(gaps)

    assert cv(mmpp) > 1.5 * cv(pois)  # on/off modulation inflates gap CV


def test_diurnal_rate_oscillates():
    arr = SinusoidalArrivals(qps_mean=3.0, amplitude=0.9, period=100.0)
    ts = arr.times(3000, np.random.default_rng(1))
    # count arrivals in peak vs trough quarters of each cycle
    phase = (ts % 100.0) / 100.0
    peak = np.sum((phase >= 0.0) & (phase < 0.5))  # sin > 0 half
    trough = np.sum((phase >= 0.5) & (phase < 1.0))
    assert peak > 2 * trough


def test_arrival_process_validation():
    with pytest.raises(ValueError):
        PoissonArrivals(qps=0.0)
    with pytest.raises(ValueError):
        SinusoidalArrivals(amplitude=1.5)
    with pytest.raises(ValueError):
        MarkovModulatedArrivals(mean_on=-1.0)


def test_scenario_validation_rejects_unknown_slo_class():
    from repro.workloads import Scenario, TenantSpec

    with pytest.raises(ValueError, match="unknown SLO class"):
        Scenario(name="bad", tenants=(TenantSpec("t", slo_class="gold"),))


# ------------------------------------------------------- trace round trip
def test_save_load_trace_round_trip_preserves_tenant_fields(tmp_path):
    reqs = generate_scenario("multi-tenant", seed=9, n_requests=25)
    p = tmp_path / "t.jsonl"
    save_trace(str(p), reqs)
    back = load_trace(str(p))
    assert len(back) == len(reqs)
    for a, b in zip(reqs, back, strict=True):
        assert (a.arrival, a.input_len, a.output_len) == (b.arrival, b.input_len, b.output_len)
        assert (a.tenant, a.slo_class) == (b.tenant, b.slo_class)
        assert (a.slo.ttft, a.slo.tpot) == (b.slo.ttft, b.slo.tpot)


@pytest.mark.parametrize(
    "line, match",
    [
        ("not json at all", "not valid JSON"),
        ('["a", "list"]', "JSON object"),
        ('{"arrival": 1.0, "output_len": 5}', "input_len"),
        ('{"input_len": "many", "output_len": 5}', "integer"),
        ('{"input_len": 12.9, "output_len": 5}', "integer"),  # would truncate
        ('{"input_len": 0, "output_len": 5}', "positive"),
        ('{"input_len": 4, "output_len": 2, "arrival": "noon"}', "number"),
    ],
)
def test_load_trace_raises_clear_error_on_malformed_line(tmp_path, line, match):
    p = tmp_path / "bad.jsonl"
    good = json.dumps({"arrival": 0.0, "input_len": 4, "output_len": 2})
    p.write_text(good + "\n" + line + "\n")
    with pytest.raises(ValueError, match=match) as exc:
        load_trace(str(p))
    assert ":2:" in str(exc.value)  # names the offending line


def test_replay_qps_rescale_applies_to_the_truncated_prefix(tmp_path):
    """qps must hold for the requests actually replayed, not the whole file
    (a bursty file front would otherwise skew the effective rate)."""
    reqs = generate_scenario("bursty", seed=0, n_requests=200)
    p = tmp_path / "bursty.jsonl"
    save_trace(str(p), reqs)
    replayed = make_scenario("replay", path=str(p), n_requests=50, qps=2.0).generate()
    assert len(replayed) == 50
    span = replayed[-1].arrival - replayed[0].arrival
    assert len(replayed) / span == pytest.approx(2.0, rel=0.05)


# ------------------------------------------------- attainment semantics
def _done_req(rid, ttft_ok=True):
    slo = SLOSpec(ttft=1.0, tpot=1.0)
    r = Request(rid=rid, arrival=0.0, input_len=4, output_len=2, slo=slo)
    r.phase = Phase.DONE
    r.first_token_time = 0.5 if ttft_ok else 5.0
    r.token_times = [r.first_token_time, r.first_token_time + 0.1]
    r.n_generated = 2
    r.done_time = r.token_times[-1]
    return r


def _shed_req(rid, tenant="default"):
    r = Request(rid=rid, arrival=0.0, input_len=4, output_len=2, tenant=tenant)
    r.phase = Phase.FAILED
    return r


def test_attainment_counts_shed_requests_as_misses():
    reqs = [_done_req(0), _done_req(1), _shed_req(2), _shed_req(3)]
    att = attainment(reqs)
    assert att.n == 4 and att.n_shed == 2
    assert att.ttft == att.e2e == 0.5  # 2 met of 4 terminal
    old = attainment(reqs, done_only=True)
    assert old.n == 2 and old.n_shed == 0
    assert old.ttft == old.e2e == 1.0  # historical completed-only view


def test_attainment_by_groups_per_tenant():
    reqs = [_done_req(0), _shed_req(1, tenant="a"), _shed_req(2, tenant="a")]
    by = attainment_by(reqs, "tenant")
    assert set(by) == {"default", "a"}
    assert by["a"].n == 2 and by["a"].e2e == 0.0 and by["a"].n_shed == 2
    assert by["default"].e2e == 1.0


def test_goodput_counts_only_slo_met_tokens():
    ok, late = _done_req(0, ttft_ok=True), _done_req(1, ttft_ok=False)
    # span = first arrival (0.0) -> last completion (5.1)
    assert goodput([ok, late]) == pytest.approx(ok.n_generated / 5.1)
    assert goodput([ok, late], span=1.0) == pytest.approx(float(ok.n_generated))
    assert goodput([_shed_req(2)]) == 0.0
