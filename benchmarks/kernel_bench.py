"""Kernel microbenchmarks (interpret mode on CPU — correctness-path timing,
not TPU performance; TPU perf is assessed via the roofline dry-run) plus the
scheduler decision-latency benchmark (the framework's own hot loop)."""
from __future__ import annotations

import time
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np


def _time_call(fn: Callable, *args, iters: int = 5) -> float:
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def kernel_rows() -> List[str]:
    rng = np.random.default_rng(0)
    rows = []

    from repro.kernels.prefill_attention.ops import prefill_attention
    from repro.kernels.prefill_attention.ref import prefill_attention_ref

    b, sq, skv, hq, hkv, dh = 1, 256, 512, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((b, sq, hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, skv, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, skv, hkv, dh)), jnp.float32)
    qp = jnp.asarray(np.arange(sq)[None] + 256, jnp.int32)
    kl = jnp.asarray([skv], jnp.int32)
    t_kern = _time_call(jax.jit(lambda *a: prefill_attention(*a)), q, k, v, qp, kl)
    t_ref = _time_call(jax.jit(lambda *a: prefill_attention_ref(*a)), q, k, v, qp, kl)
    rows.append(f"prefill_attn_pallas_interp,{t_kern:.0f},ref_jnp={t_ref:.0f}us")

    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref

    b, s, hq2, hkv2 = 4, 1024, 8, 2
    q2 = jnp.asarray(rng.standard_normal((b, hq2, dh)), jnp.float32)
    k2 = jnp.asarray(rng.standard_normal((b, s, hkv2, dh)), jnp.float32)
    v2 = jnp.asarray(rng.standard_normal((b, s, hkv2, dh)), jnp.float32)
    kl2 = jnp.asarray([s] * b, jnp.int32)
    t_kern = _time_call(jax.jit(lambda *a: decode_attention(*a)), q2, k2, v2, kl2)
    t_ref = _time_call(jax.jit(lambda *a: decode_attention_ref(*a)), q2, k2, v2, kl2)
    rows.append(f"decode_attn_pallas_interp,{t_kern:.0f},ref_jnp={t_ref:.0f}us")

    from repro.kernels.ssd_scan.ops import ssd
    from repro.models.ssm import ssd_chunked

    b3, l3, h3, p3, n3 = 1, 512, 4, 64, 32
    x = jnp.asarray(rng.standard_normal((b3, l3, h3, p3)) * 0.3, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b3, l3, h3)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, (h3,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b3, l3, n3)) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b3, l3, n3)) * 0.3, jnp.float32)
    t_kern = _time_call(jax.jit(lambda *a: ssd(*a)[0]), x, dt, A, Bm, Cm)
    t_ref = _time_call(
        jax.jit(lambda x, dt, A, B, C: ssd_chunked(x, dt, A, B[:, :, None], C[:, :, None], 128)[0]),
        x, dt, A, Bm, Cm,
    )
    rows.append(f"ssd_scan_pallas_interp,{t_kern:.0f},ref_jnp={t_ref:.0f}us")
    return rows


def scheduler_rows() -> List[str]:
    """Decision latency of every registered policy at production queue sizes.

    Policies are constructed through the repro.policies registry, so a newly
    registered policy is benchmarked automatically.
    """
    rows = []
    rng = np.random.default_rng(0)
    from repro.core import jax_sched
    from repro.core.lut import StepTimeLUT
    from repro.core.request import Phase, Request, SLOSpec
    from repro.policies import available_policies, make_decode, make_prefill
    from repro.sim.costmodel import PAPER_COST_MODEL as cm

    n = 256
    queue = []
    for i in range(n):
        r = Request(rid=i, arrival=float(rng.uniform(0, 10)),
                    input_len=int(rng.integers(100, 100_000)), output_len=200,
                    slo=SLOSpec())
        queue.append(r)
    for pname in available_policies()["prefill"]:
        sched = make_prefill(pname)
        t0 = time.perf_counter()
        for _ in range(20):
            sched.select(queue, 5.0, 20_000.0, 8192)
        rows.append(
            f"prefill_select_{pname}_n{n},{(time.perf_counter()-t0)/20*1e6:.0f},host"
        )

    arr = jnp.asarray([r.arrival for r in queue], jnp.float32)
    lens = jnp.asarray([r.input_len for r in queue], jnp.float32)
    act = jnp.ones(n, bool)
    fn = jax.jit(lambda a, l, m: jax_sched.urgency_select(a, l, l, m, 5.0, 20_000.0, 8.0, 8192))
    fn(arr, lens, act)
    t0 = time.perf_counter()
    for _ in range(50):
        out = fn(arr, lens, act)
    jax.block_until_ready(out)
    rows.append(f"urgency_select_jax_n{n},{(time.perf_counter()-t0)/50*1e6:.0f},jit")

    lut = StepTimeLUT(analytic=cm.decode_lut_seed)
    active = []
    for i in range(n):
        r = Request(rid=i, arrival=0.0, input_len=int(rng.integers(1000, 131_072)),
                    output_len=500, slo=SLOSpec())
        r.first_token_time = 9.0
        r.decode_start = 9.0
        r.n_generated = int(rng.integers(1, 100))
        r.n_decoded = r.n_generated
        r.phase = Phase.DECODE
        active.append(r)
    for dname in available_policies()["decode"]:
        dsched = make_decode(dname, lut)
        t0 = time.perf_counter()
        for _ in range(20):
            dsched.select(active, 10.0)
        rows.append(
            f"decode_select_{dname}_n{n},{(time.perf_counter()-t0)/20*1e6:.0f},host"
        )

    be, se, tab = (jnp.asarray(x) for x in lut.as_arrays())
    seqs = jnp.asarray([r.seq_len for r in active], jnp.int32)
    ngen = jnp.asarray([r.n_decoded for r in active], jnp.int32)
    ft = jnp.full((n,), 9.0, jnp.float32)
    fn2 = jax.jit(
        lambda s, g, f, m: jax_sched.slack_select(s, g, f, m, 10.0, 0.05, tab, be, se)
    )
    fn2(seqs, ngen, ft, act)
    t0 = time.perf_counter()
    for _ in range(50):
        out = fn2(seqs, ngen, ft, act)
    jax.block_until_ready(out.selected)
    rows.append(f"slack_select_jax_n{n},{(time.perf_counter()-t0)/50*1e6:.0f},jit")
    return rows
