"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Usage:
    PYTHONPATH=src python -m benchmarks.run [--quick] [--workloads-only]

``--workloads-only`` runs just the workloads scenario matrix and writes the
perf record (the slice CI's bench-gate compares against the committed
``BENCH_workloads.json``); ``--bench-out`` redirects that record so a gate
run never overwrites the baseline it is judging itself against.

Event tracing (`repro.obs`) and these benchmarks: benchmark runs leave
``HarnessConfig.trace`` at its ``None`` default, which keeps every emission
site on its no-recorder fast path — the overhead guard in
``tests/test_obs.py`` pins that a trace-enabled run is bit-identical in
virtual time and adds no metric drift, so perf records stay comparable
whether or not a diagnostic rerun traced the same cells.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="skip kernel microbenches")
    ap.add_argument(
        "--workloads-only", action="store_true",
        help="only the workloads scenario matrix + its perf record",
    )
    ap.add_argument(
        "--bench-out", default=None,
        help="where to write the workloads perf record "
        "(default: the repo's BENCH_workloads.json)",
    )
    args = ap.parse_args()

    print("name,value,derived")
    t0 = time.perf_counter()

    if args.workloads_only:
        from benchmarks import paper_figs

        record = paper_figs.workloads_bench_record()
        bench_path = pathlib.Path(
            args.bench_out
            or pathlib.Path(__file__).resolve().parent.parent / "BENCH_workloads.json"
        )
        bench_path.write_text(json.dumps(record, indent=2, sort_keys=True) + "\n")
        print(f"bench_workloads_wall_s,{record['total_wall_s']:.1f},{bench_path.name}")
        print(f"total_bench_wall_s,{time.perf_counter()-t0:.1f},")
        return

    # the policy surface under test, straight from the registry (the same
    # enumeration the simulator, engine, and CLI consume)
    from repro.policies import available_policies

    pol = available_policies()
    print(f"policy_registry,{len(pol['prefill'])}+{len(pol['decode'])},"
          f"prefill={'/'.join(pol['prefill'])};decode={'/'.join(pol['decode'])}")

    from benchmarks import paper_figs

    for fn in [
        paper_figs.fig1a_trace_distribution,
        paper_figs.fig1b_decode_step_vs_seqlen,
        paper_figs.fig3_e2e_attainment,
        paper_figs.fig4_ttft_attainment,
        paper_figs.fig5_tpot_attainment,
        paper_figs.fig6_decode_throughput,
        paper_figs.fig7_scenario_matrix,
        paper_figs.headline_gains,
    ]:
        for row in fn():
            print(row)
        sys.stdout.flush()

    # perf record: scenario-matrix wall time + decode throughput, one JSON
    # file per run so the bench trajectory is diffable across PRs
    record = paper_figs.workloads_bench_record()
    bench_path = pathlib.Path(
        args.bench_out
        or pathlib.Path(__file__).resolve().parent.parent / "BENCH_workloads.json"
    )
    bench_path.write_text(json.dumps(record, indent=2, sort_keys=True) + "\n")
    print(f"bench_workloads_wall_s,{record['total_wall_s']:.1f},{bench_path.name}")

    if not args.quick:
        from benchmarks.kernel_bench import kernel_rows, scheduler_rows

        for row in scheduler_rows():
            print(row)
        for row in kernel_rows():
            print(row)

    print(f"total_bench_wall_s,{time.perf_counter()-t0:.1f},")


if __name__ == "__main__":
    main()
