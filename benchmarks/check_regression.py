"""Perf-regression gate over BENCH_workloads.json records.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline BENCH_workloads.json --candidate bench-new.json \
        --max-regress 0.25

Compares decode throughput (p50 and mean) cell-by-cell between a committed
baseline record and a freshly measured candidate (both produced by
``benchmarks/run.py``). Cells are matched on their full identity
(scenario, prefill, decode, backend, variant — "paged" cells ran on the
paged KV substrate and never match slot cells); the gate FAILS (exit 1)
when any
matched cell's throughput drops by more than ``--max-regress`` (fraction,
default 0.25) relative to the baseline.

Only *throughput* is gated — wall_time_s is reported but never gated, since
CI machine speed varies run to run while the simulator's virtual-time
decode throughput is a seeded, deterministic quantity. Cells present on
one side only are reported (the grid legitimately grows across PRs) but do
not fail the gate; a candidate that matches ZERO baseline cells fails,
because that means the gate is comparing nothing.

``--refresh-check`` flips the tool into a second mode for the on-main
refresh step: exit 0 when the two records are *materially* identical (same
grid, same cells, identical deterministic metrics — wall times ignored, as
they differ every run), exit 1 when the committed record is stale and worth
re-committing. This keeps the refresh commit from firing on every push just
because wall_time_s wiggled.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple

GATED_METRICS = ("decode_tput_p50", "decode_tput_mean")
# deterministic (seeded, virtual-time) cell metrics: these decide whether a
# record refresh is warranted; wall times never do
MATERIAL_METRICS = (*GATED_METRICS, "goodput", "e2e")

Key = Tuple[str, str, str, str, str]


def _cells(record: Dict) -> Dict[Key, Dict]:
    # variant distinguishes KV substrates ("" = slot, "paged" = paged pool);
    # a paged cell regressing against its slot twin is not a regression
    return {
        (
            c["scenario"], c["prefill"], c["decode"],
            c.get("backend", "sim"), c.get("variant", ""),
        ): c
        for c in record["cells"]
    }


def compare(baseline: Dict, candidate: Dict, max_regress: float) -> Tuple[bool, str]:
    """Returns (ok, human-readable report)."""
    base, cand = _cells(baseline), _cells(candidate)
    matched = sorted(set(base) & set(cand))

    def label(key: Key) -> str:
        return "/".join(part for part in key if part)

    lines = []
    failures = 0
    for key in matched:
        for metric in GATED_METRICS:
            b, c = base[key].get(metric), cand[key].get(metric)
            if not b or c is None:  # zero/absent baseline: nothing to gate
                continue
            rel = (c - b) / b
            mark = "ok"
            if rel < -max_regress:
                failures += 1
                mark = f"REGRESSION (>{max_regress:.0%} drop)"
            lines.append(
                f"{label(key)} {metric}: {b:.2f} -> {c:.2f} ({rel:+.1%}) {mark}"
            )
    for key in sorted(set(base) - set(cand)):
        lines.append(f"{label(key)}: only in baseline (not gated)")
    for key in sorted(set(cand) - set(base)):
        lines.append(f"{label(key)}: new cell (not gated)")
    if not matched:
        return False, "no cells in common between baseline and candidate\n" + "\n".join(lines)
    verdict = f"{failures} regression(s) across {len(matched)} matched cells"
    return failures == 0, "\n".join([*lines, verdict])


def materially_equal(baseline: Dict, candidate: Dict) -> bool:
    """True when the records agree on everything deterministic: grid shape,
    request count, cell identities, and every MATERIAL_METRIC."""
    if baseline.get("grid") != candidate.get("grid"):
        return False
    if baseline.get("n_requests") != candidate.get("n_requests"):
        return False
    base, cand = _cells(baseline), _cells(candidate)
    if set(base) != set(cand):
        return False
    return all(
        base[key].get(m) == cand[key].get(m)
        for key in base
        for m in MATERIAL_METRICS
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="committed BENCH_workloads.json")
    ap.add_argument("--candidate", required=True, help="freshly measured record")
    ap.add_argument(
        "--max-regress", type=float, default=0.25,
        help="max allowed fractional throughput drop per cell (default 0.25)",
    )
    ap.add_argument(
        "--refresh-check", action="store_true",
        help="exit 0 iff the records are materially identical (wall times "
        "ignored); used by CI to decide whether to re-commit the record",
    )
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.candidate) as f:
        candidate = json.load(f)
    if args.refresh_check:
        same = materially_equal(baseline, candidate)
        print("refresh-check:", "identical" if same else "stale")
        return 0 if same else 1
    ok, report = compare(baseline, candidate, args.max_regress)
    print(report)
    print("bench-gate:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
