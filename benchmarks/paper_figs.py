"""Benchmarks reproducing the paper's tables/figures (one function each).

Shared QPS sweep (Kairos / Kairos+ / DistServe) is computed once and cached;
each figure function derives its metric from the same runs, mirroring how
the paper reports one experiment four ways (Figs. 3-6).
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Tuple

from repro.sim.metrics import summarize
from repro.sim.simulator import run_distserve, run_kairos, run_kairos_plus
from repro.sim.trace import TraceConfig, generate_trace, trace_stats

QPS_GRID = (2.0, 2.4, 2.8, 3.0, 3.4, 4.0, 5.0)
N_REQ = 400
SEED = 1


@functools.lru_cache(maxsize=None)
def _sweep() -> Dict[Tuple[str, float], Dict]:
    out = {}
    for qps in QPS_GRID:
        reqs = generate_trace(TraceConfig(n_requests=N_REQ, qps=qps, seed=SEED))
        for name, runner in [
            ("kairos", run_kairos),
            ("kairos+", run_kairos_plus),
            ("distserve", run_distserve),
        ]:
            t0 = time.perf_counter()
            res = runner(reqs)
            s = summarize(res)
            s["sim_wall_s"] = time.perf_counter() - t0
            out[(name, qps)] = s
    return out


def _rows(metric: str) -> List[str]:
    sw = _sweep()
    rows = []
    for qps in QPS_GRID:
        k = sw[("kairos", qps)][metric]
        p = sw[("kairos+", qps)][metric]
        d = sw[("distserve", qps)][metric]
        rows.append(f"{metric}@qps{qps},{k:.4f},{p:.4f},{d:.4f}")
    return rows


def fig1a_trace_distribution() -> List[str]:
    st = trace_stats(generate_trace(TraceConfig(n_requests=2000, seed=3)))
    return [f"fig1a_{k},{v:.1f}," for k, v in st.items()]


def fig1b_decode_step_vs_seqlen() -> List[str]:
    from repro.sim.costmodel import PAPER_COST_MODEL as cm

    rows = []
    for s in (8_192, 16_384, 32_768, 65_536, 131_072):
        t = cm.decode_step_time([s]) * 1e3
        rows.append(f"fig1b_decode_ms@{s},{t:.2f},paper:11.0@8k/40.3@128k")
    return rows


def fig3_e2e_attainment() -> List[str]:
    return _rows("e2e")


def fig4_ttft_attainment() -> List[str]:
    return _rows("ttft")


def fig5_tpot_attainment() -> List[str]:
    return _rows("tpot")


def fig6_decode_throughput() -> List[str]:
    return _rows("decode_tput_p50")


@functools.lru_cache(maxsize=None)
def _workload_grid() -> Dict:
    """Beyond-paper scenario matrix: every built-in generated scenario ×
    {kairos, distserve-style} on the simulator (the CI-light slice of what
    `launch/evaluate.py` sweeps)."""
    from repro.workloads.harness import HarnessConfig, run_grid

    return run_grid(
        scenarios=["paper-longtail", "bursty", "diurnal", "multi-tenant", "heavy-head"],
        prefills=["kairos-urgency", "fcfs"],
        decodes=["kairos-slack"],
        backends=["sim"],
        hcfg=HarnessConfig(n_requests=200, seed=SEED),
    )


def fig7_scenario_matrix() -> List[str]:
    """Per-scenario e2e attainment + goodput, kairos vs FCFS prefill."""
    rows = []
    cells = {(c["scenario"], c["prefill"]): c for c in _workload_grid()["cells"]}
    for sc in ("paper-longtail", "bursty", "diurnal", "multi-tenant", "heavy-head"):
        k = cells[(sc, "kairos-urgency")]
        f = cells[(sc, "fcfs")]
        rows.append(
            f"fig7_e2e@{sc},{k['attainment']['e2e']:.4f},fcfs:{f['attainment']['e2e']:.4f}"
        )
        rows.append(f"fig7_goodput@{sc},{k['goodput']:.1f},fcfs:{f['goodput']:.1f}")
    mt = cells[("multi-tenant", "kairos-urgency")]
    for tenant, att in sorted(mt["per_tenant"].items()):
        rows.append(f"fig7_tenant_e2e@{tenant},{att['e2e']:.4f},")
    return rows


@functools.lru_cache(maxsize=None)
def _router_grid() -> Dict:
    """Routed-fleet slice of the record: 2 replicas behind least-queued on
    the prefix-heavy scenario (live engine compute, so it is sized well
    below the sim matrix — the point is tracking routed decode throughput
    and the prefix hit rate under the bench gate, not paper-scale load)."""
    from repro.workloads.harness import HarnessConfig, run_grid

    return run_grid(
        scenarios=["prefix-heavy"],
        prefills=["kairos-urgency"],
        decodes=["kairos-slack"],
        backends=["router"],
        hcfg=HarnessConfig(
            n_requests=24, seed=SEED, router_replicas=2, router_policy="least-queued"
        ),
    )


@functools.lru_cache(maxsize=None)
def _disagg_grid() -> Dict:
    """Disaggregated-fleet slice: a 2P:2D pool split with load-aware
    prefill deflection on the heavy-head scenario (the prompt mix the
    deflection watermark is aimed at). Live engine compute, sized like the
    router slice — the gate tracks decode throughput with the KV-handoff
    stage on the path."""
    from repro.workloads.harness import HarnessConfig, run_grid

    return run_grid(
        scenarios=["heavy-head"],
        prefills=["kairos-urgency"],
        decodes=["kairos-slack"],
        backends=["disagg"],
        hcfg=HarnessConfig(
            n_requests=24, seed=SEED, disagg_prefill=2, disagg_decode=2,
            deflect_policy="prefill-pressure",
        ),
    )


@functools.lru_cache(maxsize=None)
def _paged_grid() -> Dict:
    """Paged-KV slice: the prefix-heavy scenario on the live engine, run
    twice — once on the slot substrate, once on refcounted pages
    (``page_size=4``). The two cells carry distinct ``variant`` keys so the
    gate tracks each substrate against its own committed record; the paged
    row additionally pins the radix-reuse payoff (cached tokens > 0, pages
    shared across requests) as regression-guarded record fields."""
    from repro.workloads.harness import HarnessConfig, run_grid

    common = dict(
        scenarios=["prefix-heavy"],
        prefills=["kairos-urgency"],
        decodes=["kairos-slack"],
        backends=["engine"],
    )
    slot = run_grid(hcfg=HarnessConfig(n_requests=24, seed=SEED), **common)
    paged = run_grid(
        hcfg=HarnessConfig(n_requests=24, seed=SEED, page_size=4), **common
    )
    return dict(slot=slot, paged=paged)


def _record_cell(c: Dict) -> Dict:
    row = dict(
        scenario=c["scenario"],
        prefill=c["prefill"],
        decode=c["decode"],
        backend=c["backend"],
        wall_time_s=c["wall_time_s"],
        decode_tput_p50=c["attainment"]["decode_tput_p50"],
        decode_tput_mean=c["attainment"]["decode_tput_mean"],
        goodput=c["goodput"],
        e2e=c["attainment"]["e2e"],
    )
    if "router" in c:
        row["router_policy"] = c["router"]["policy"]
        row["router_replicas"] = c["router"]["replicas"]
        row["prefix_hit_rate"] = c["router"]["prefix"]["hit_rate"]
    if "disagg" in c:
        d = c["disagg"]
        row["pools"] = f"{d['pools']['prefill']}:{d['pools']['decode']}"
        row["deflect_policy"] = d["deflect"]
        row["deflected"] = d["deflection"]["deflected"]
        row["transfers_completed"] = d["handoff"]["transfers_completed"]
        row["local_transfers"] = d["handoff"]["local_transfers"]
    if c.get("variant"):
        row["variant"] = c["variant"]
    if c.get("kv"):
        kv = c["kv"]
        row["prefix_cached_tokens"] = kv["prefix_cached_tokens"]
        row["prefill_computed_tokens"] = kv["prefill_computed_tokens"]
        row["kv_shared_links"] = kv["pages"]["shared_links"]
    return row


def workloads_bench_record() -> Dict:
    """Perf record for BENCH_workloads.json: wall time + decode throughput
    per cell of the scenario matrix, plus the routed-fleet and
    disaggregated-fleet cells (matched by the gate on
    scenario/prefill/decode/backend like any other)."""
    grid = _workload_grid()
    router = _router_grid()
    disagg = _disagg_grid()
    paged = _paged_grid()
    cells = (
        list(grid["cells"]) + list(router["cells"]) + list(disagg["cells"])
        + list(paged["slot"]["cells"]) + list(paged["paged"]["cells"])
    )
    g = dict(grid["grid"])
    g["backends"] = (
        list(g["backends"])
        + list(router["grid"]["backends"])
        + list(disagg["grid"]["backends"])
        + list(paged["slot"]["grid"]["backends"])
    )
    g["router"] = dict(
        scenarios=router["grid"]["scenarios"],
        policy=router["config"]["router_policy"],
        replicas=router["config"]["router_replicas"],
        n_requests=router["config"]["n_requests"],
    )
    g["disagg"] = dict(
        scenarios=disagg["grid"]["scenarios"],
        pools="%d:%d" % (
            disagg["config"]["disagg_prefill"], disagg["config"]["disagg_decode"]
        ),
        deflect=disagg["config"]["deflect_policy"],
        n_requests=disagg["config"]["n_requests"],
    )
    g["paged"] = dict(
        scenarios=paged["paged"]["grid"]["scenarios"],
        page_size=paged["paged"]["config"]["page_size"],
        n_requests=paged["paged"]["config"]["n_requests"],
    )
    return dict(
        grid=g,
        n_requests=grid["config"]["n_requests"],
        total_wall_s=sum(c["wall_time_s"] for c in cells),
        cells=[_record_cell(c) for c in cells],
    )


def headline_gains() -> List[str]:
    """Paper abstract numbers: max gains of Kairos over DistServe."""
    sw = _sweep()
    best = dict(ttft=0.0, tpot=0.0, e2e=0.0, tput=0.0)
    bestp = dict(ttft=0.0, tpot=0.0, e2e=0.0, tput=0.0)
    for qps in QPS_GRID:
        d = sw[("distserve", qps)]
        k = sw[("kairos", qps)]
        p = sw[("kairos+", qps)]
        for m in ("ttft", "tpot", "e2e"):
            best[m] = max(best[m], 100 * (k[m] - d[m]))
            bestp[m] = max(bestp[m], 100 * (p[m] - d[m]))
        if d["decode_tput_p50"]:
            best["tput"] = max(best["tput"], 100 * (k["decode_tput_p50"] / d["decode_tput_p50"] - 1))
            bestp["tput"] = max(bestp["tput"], 100 * (p["decode_tput_p50"] / d["decode_tput_p50"] - 1))
    return [
        f"headline_ttft_gain_pp,{best['ttft']:.1f},paper:23.9 (kairos+: {bestp['ttft']:.1f})",
        f"headline_tpot_gain_pp,{best['tpot']:.1f},paper:27.1 (kairos+: {bestp['tpot']:.1f})",
        f"headline_e2e_gain_pp,{best['e2e']:.1f},paper:33.8 (kairos+: {bestp['e2e']:.1f})",
        f"headline_decode_tput_gain_%,{best['tput']:.1f},paper:19.3 (kairos+: {bestp['tput']:.1f})",
    ]
