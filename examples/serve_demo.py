"""End-to-end disaggregated serving on real JAX compute (CPU demo scale).

Serves a batch of requests through the chunked-prefill engine + slot-based
decode engine with Kairos scheduling, then repeats with the DistServe
baseline and prints per-request SLO outcomes. Both runs go through the
streaming `ServeSession` API (`submit` / `step` / per-token callbacks);
policies are constructed by name through the `repro.policies` registry.
Greedy tokens are verified identical across policies (scheduling changes
timing, never tokens), and a final section shows admission control shedding
requests when the queue depth is bounded.

    PYTHONPATH=src python examples/serve_demo.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.request import Phase, Request, SLOSpec
from repro.models import build_model
from repro.serving.engine import DisaggServer, EngineConfig
from repro.serving.session import ServeSession


def make_requests(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        # long-tail lengths at demo scale
        n_prompt = int(rng.choice([6, 9, 12, 40], p=[0.4, 0.3, 0.2, 0.1]))
        prompt = list(map(int, rng.integers(2, cfg.vocab_size, n_prompt)))
        reqs.append(
            (
                Request(
                    rid=i, arrival=0.05 * i, input_len=n_prompt, output_len=10,
                    slo=SLOSpec(ttft=30.0, tpot=3.0),  # CPU-scale SLOs
                ),
                prompt,
            )
        )
    return reqs


def main() -> None:
    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    results = {}
    for policy, dpolicy in [("kairos-urgency", "kairos-slack"), ("fcfs", "continuous")]:
        reqs = make_requests(cfg)
        ecfg = EngineConfig(
            max_slots=8, max_len=96, chunk_size=16,
            prefill_policy=policy, decode_policy=dpolicy,
        )
        server = DisaggServer(model, params, ecfg)
        n_streamed = [0]

        # the per-token callback is where a real frontend would flush
        # tokens to the client; run() is the canonical arrival-replay loop
        session = ServeSession(
            server, on_token=lambda req, tok, t: n_streamed.__setitem__(0, n_streamed[0] + 1)
        )
        outs = session.run(reqs)
        results[policy] = outs
        print(f"\n== {policy} + {dpolicy} ==")
        for r, _ in reqs:
            assert r.phase == Phase.DONE
            print(
                f"  rid={r.rid} in={r.input_len:3d} ttft={r.ttft():6.2f}s "
                f"mean_itl={r.mean_tpot()*1e3:7.1f}ms meets_e2e={r.meets_e2e()}"
            )
        assert n_streamed[0] == sum(len(v) for v in outs.values())
        print(f"  tokens streamed via on_token: {n_streamed[0]}, "
              f"LUT cells observed: {int(server.lut.count.sum())}, "
              f"mu_prefill={server.mu.mu:.0f} tok/s")

    same = all(
        results["kairos-urgency"][i] == results["fcfs"][i]
        for i in results["kairos-urgency"]
    )
    print(f"\ntokens identical across schedulers: {same}")
    assert same

    # admission control: bounded queue depth sheds the burst's tail
    reqs = make_requests(cfg)
    server = DisaggServer(
        model, params, EngineConfig(max_slots=8, max_len=96, chunk_size=16)
    )
    session = ServeSession(server, max_queue_depth=3)
    for req, prompt in reqs:
        session.submit(req, prompt)  # all at once: a burst
    while session.has_work:
        session.step()
    s = session.summary()
    print(f"burst of {s['submitted']} at queue depth 3: "
          f"served {s['completed']}, shed {s['rejected']} (rids {s['rejected_rids']})")
    assert s["rejected"] > 0 and s["completed"] == s["accepted"]


if __name__ == "__main__":
    main()
