"""Quickstart: reproduce the paper's headline comparison in one minute.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.sim.metrics import compare, summarize
from repro.sim.simulator import run_distserve, run_kairos, run_kairos_plus
from repro.sim.trace import TraceConfig, generate_trace, trace_stats


def main() -> None:
    trace = generate_trace(TraceConfig(n_requests=500, qps=3.0, seed=1))
    print("trace:", trace_stats(trace))

    kairos = run_kairos(trace)  # paper Alg. 1-3, faithful
    plus = run_kairos_plus(trace)  # + beyond-paper fixes (DESIGN.md §5)
    distserve = run_distserve(trace)  # FCFS + continuous batching baseline

    for name, res in [("kairos", kairos), ("kairos+", plus), ("distserve", distserve)]:
        s = summarize(res)
        print(
            f"{name:10s} TTFT={s['ttft']:.1%} TPOT={s['tpot']:.1%} "
            f"E2E={s['e2e']:.1%} decode_tput_p50={s['decode_tput_p50']:.1f} tok/s"
        )
    print("kairos  vs distserve:", compare(kairos, distserve))
    print("kairos+ vs distserve:", compare(plus, distserve))


if __name__ == "__main__":
    main()
