"""Train a ~100M-param model for a few hundred steps on CPU, with WSD
schedule, gradient accumulation and crash-safe checkpointing.

    PYTHONPATH=src python examples/train_demo.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, SyntheticDataset
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)  # CPU demo; use 300+ on real hw
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_demo")
    args = ap.parse_args()

    # demo-trimmed mamba2 (~7M params) so a single CPU core makes progress;
    # the same driver trains the full 130M+ configs on TPU via launch/train.py
    cfg = get_config("mamba2-130m").replace(
        num_layers=4, d_model=512, vocab_size=4096, ssm_chunk=64
    )
    model = build_model(cfg)
    print(f"model: {cfg.name} ~{cfg.count_params()/1e6:.1f}M params (demo-trimmed)")

    opt_cfg = OptimizerConfig(lr=3e-4, warmup_steps=20, stable_steps=200, decay_steps=80)
    ds = SyntheticDataset(cfg, DataConfig(seq_len=128, global_batch=4))
    step_fn = jax.jit(make_train_step(model, opt_cfg, n_micro=2))

    ck = CheckpointManager(args.ckpt_dir, keep=2)
    start = ck.latest_step() or 0
    if start:
        like = {"params": model.init(jax.random.key(0)), "opt": init_opt_state(model.init(jax.random.key(0)))}
        restored, start = ck.restore(like)
        params, opt = restored["params"], restored["opt"]
        print(f"resumed from step {start}")
    else:
        params = model.init(jax.random.key(0))
        opt = init_opt_state(params)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, ds.batch_at(step))
        params, opt, m = step_fn(params, opt, batch)
        if step % 25 == 0 or step == args.steps - 1:
            print(
                f"step {step:4d} loss={float(m['loss']):.4f} "
                f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f} "
                f"({(time.time()-t0):.0f}s)"
            )
        if step and step % 100 == 0:
            ck.save(step, {"params": params, "opt": opt}, async_=True)
    ck.save(args.steps, {"params": params, "opt": opt})
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
