"""Full QPS sweep (paper Figs. 3-6) with ablations + fault injection.

    PYTHONPATH=src python examples/sim_sweep.py [--n 500]
"""
import argparse

from repro.policies import available_policies
from repro.sim.metrics import summarize
from repro.sim.simulator import (
    FaultPlan,
    run_distserve,
    run_kairos,
    run_kairos_plus,
    run_policy,
)
from repro.sim.trace import TraceConfig, generate_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=500)
    args = ap.parse_args()

    print(f"{'qps':>4} | {'kairos':^24} | {'kairos+':^24} | {'distserve':^24}")
    print(f"{'':>4} | {'ttft tpot e2e  tput':^24} | {'ttft tpot e2e  tput':^24} | {'ttft tpot e2e  tput':^24}")
    for qps in (2.0, 2.4, 2.8, 3.0, 3.4, 4.0, 5.0):
        reqs = generate_trace(TraceConfig(n_requests=args.n, qps=qps, seed=1))
        cells = []
        for runner in (run_kairos, run_kairos_plus, run_distserve):
            s = summarize(runner(reqs))
            cells.append(f"{s['ttft']:.2f} {s['tpot']:.2f} {s['e2e']:.2f} {s['decode_tput_p50']:5.1f}")
        print(f"{qps:4.1f} | {cells[0]:^24} | {cells[1]:^24} | {cells[2]:^24}")

    # ablation: every registered prefill policy with continuous decode (the
    # registry enumeration means a newly registered policy joins the sweep)
    print("\nPrefill-policy ablation (QPS 3.0, continuous decode):")
    reqs = generate_trace(TraceConfig(n_requests=args.n, qps=3.0, seed=1))
    for pol in available_policies()["prefill"]:
        s = summarize(run_policy(reqs, pol, "continuous"))
        print(f"  {pol:22s} ttft={s['ttft']:.2f} e2e={s['e2e']:.2f}")

    # decode-policy ablation with urgency prefill
    print("\nDecode-policy ablation (QPS 3.0, kairos-urgency prefill):")
    for pol in available_policies()["decode"]:
        s = summarize(run_policy(reqs, "kairos-urgency", pol))
        print(f"  {pol:22s} tpot={s['tpot']:.2f} e2e={s['e2e']:.2f}")

    # fault tolerance: decode node dies at t=30s
    print("\nFault injection (decode node dies at t=30 s, 5 s recovery):")
    for name, runner in (("kairos", run_kairos), ("distserve", run_distserve)):
        s = summarize(runner(reqs, fault_plan=FaultPlan(decode_failures=(30.0,))))
        print(f"  {name:10s} e2e={s['e2e']:.2f} (all {int(s['n'])} requests completed)")


if __name__ == "__main__":
    main()
