"""Scenario-suite demo: sweep policies across workloads on sim + engine.

    PYTHONPATH=src python examples/workload_eval.py [--n 120] [--engine]

Prints one table per backend: e2e attainment / goodput / shed per scenario
and prefill policy, with the multi-tenant scenario broken down per tenant.
The engine backend (opt-in: it runs real JAX compute) applies a per-tenant
admission quota so shedding is visible.
"""
import argparse

from repro.workloads import HarnessConfig, available_scenarios, run_grid

SCENARIOS = [s for s in available_scenarios() if s != "replay"]
PREFILLS = ["kairos-urgency", "fcfs"]


def print_grid(report: dict) -> None:
    backend = report["grid"]["backends"][0]
    print(f"\n--- backend: {backend} ---")
    print(f"{'scenario':>15} {'prefill':>16} {'e2e':>6} {'goodput':>8} {'shed':>5}")
    for c in report["cells"]:
        att = c["attainment"]
        print(
            f"{c['scenario']:>15} {c['prefill']:>16} {att['e2e']:6.2f} "
            f"{c['goodput']:8.1f} {c['shed']['total']:5d}"
        )
    mt = [c for c in report["cells"] if c["scenario"] == "multi-tenant"]
    if mt:
        print("  multi-tenant per-tenant e2e (first prefill policy):")
        for tenant, att in sorted(mt[0]["per_tenant"].items()):
            print(f"    {tenant:>12}: e2e={att['e2e']:.2f} n={att['n']} shed={att['n_shed']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=120)
    ap.add_argument("--engine", action="store_true", help="also sweep the live engine")
    args = ap.parse_args()

    hcfg = HarnessConfig(n_requests=args.n, seed=1)
    print_grid(run_grid(SCENARIOS, PREFILLS, ["kairos-slack"], ["sim"], hcfg))

    if args.engine:
        hcfg = HarnessConfig(
            n_requests=min(args.n, 32), seed=1, tenant_quota=2, engine_arrival_scale=1e-3
        )
        print_grid(
            run_grid(["multi-tenant"], PREFILLS, ["kairos-slack-greedy"], ["engine"], hcfg)
        )


if __name__ == "__main__":
    main()
